"""Kernel microbenchmarks (interpret mode on CPU: correctness-representative
timings for the XLA-path oracle vs the blocked formulation; real TPU wall
times require hardware).  Emits name,us_per_call,derived rows."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench(fast: bool = True):
    rng = np.random.default_rng(0)
    rows = []

    # scheduler routing at fleet scale
    m, b = (4096, 512) if fast else (65536, 8192)
    wl = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile([0.5, 0.45, 0.25], (m, 1)), jnp.float32)
    sr = jnp.asarray(np.arange(m) // 64, jnp.int32)
    tl = jnp.sort(jnp.asarray(
        rng.integers(0, m, (b, 3)), jnp.int32), axis=1)
    us_ref = _time(jax.jit(lambda *a: ref.wwl_route(*a)), wl, er, sr, tl)
    rows.append(("wwl_route_ref_xla", us_ref, f"M={m},B={b}"))
    us_k = _time(lambda *a: ops.wwl_route(*a), wl, er, sr, tl)
    rows.append(("wwl_route_pallas_interp", us_k, f"M={m},B={b}"))

    q = jnp.asarray(rng.integers(0, 5, m), jnp.float32)
    ids = jnp.asarray(rng.choice(m, b, replace=False), jnp.int32)
    er2 = jnp.asarray(np.tile([0.5, 0.45, 0.25], (b, 1)), jnp.float32)
    us = _time(jax.jit(lambda *a: ref.maxweight_claim(*a)), q, sr, ids,
               sr[ids], er2)
    rows.append(("maxweight_ref_xla", us, f"N={m},B={b}"))

    # attention: XLA einsum vs flash (interpret)
    t = 1024 if fast else 4096
    qq = jnp.asarray(rng.normal(size=(1, 4, t, 64)), jnp.bfloat16)
    kk = jnp.asarray(rng.normal(size=(1, 2, t, 64)), jnp.bfloat16)
    vv = jnp.asarray(rng.normal(size=(1, 2, t, 64)), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b, c: ref.mha(a, b, c)), qq, kk, vv)
    rows.append(("attention_ref_xla", us, f"T={t}"))

    # ssd: chunked jnp vs sequential-scan oracle
    from repro.models.ssm_ops import ssd_chunked_jnp
    bt = 512 if fast else 4096
    x = jnp.asarray(rng.normal(size=(1, bt, 4, 32)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.01, 0.2, (1, bt, 4)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, bt, 32)) * 0.3, jnp.float32)
    cc = jnp.asarray(rng.normal(size=(1, bt, 32)) * 0.3, jnp.float32)
    us_seq = _time(jax.jit(lambda *z: ref.ssd(*z)[0]), x, a, bb, cc)
    rows.append(("ssd_sequential_scan", us_seq, f"T={bt}"))
    us_chk = _time(jax.jit(lambda *z: ssd_chunked_jnp(*z)[0]), x, a, bb, cc)
    rows.append(("ssd_chunked_dual", us_chk,
                 f"T={bt},speedup={us_seq / max(us_chk, 1e-9):.1f}x"))
    return rows
