"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and renders the per-(arch x shape x mesh) three-term table for
EXPERIMENTS.md §Roofline.  Run the dry-run first:

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path("experiments/dryrun")


def load_cells(mesh: str = "16_16"):
    cells = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def table(mesh: str = "16_16") -> str:
    cells = load_cells(mesh)
    if not cells:
        return f"(no dry-run artifacts for mesh {mesh}; run repro.launch.dryrun)"
    lines = [
        "| arch | shape | fits16GB | compute_s | memory_s | collective_s "
        "| dominant | useful_flops | roofline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | "
                         f"skip | — | — |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | FAILED | | | | | | |")
            continue
        r = c["roofline"]
        m = c["memory"]
        lines.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {'yes' if m['fits_16gb'] else 'NO'} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_flops_fraction']:.1%} "
            f"| {r['roofline_fraction']:.1%} |")
    return "\n".join(lines)


def bench(fast: bool = True):
    rows = []
    for mesh in ("16_16", "2_16_16"):
        for c in load_cells(mesh):
            if c.get("status") != "ok":
                continue
            r = c["roofline"]
            rows.append((f"roofline_{c['arch']}_{c['shape']}_{mesh}",
                         r["bound_s"] * 1e6,
                         f"dom={r['dominant']},roof={r['roofline_fraction']:.3f}"))
    return rows
