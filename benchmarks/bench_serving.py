"""Serving-level robustness benchmark: the paper's experiment transplanted to
the continuous-batching engine.

Three fleets are compared under each scheduler:
  exact     — router priors equal the true tier rates
  wrong     — priors off (the engine's blind EWMA must recover)
  straggler — one replica is 5x slow and the priors don't know

Reported: engine steps to drain a fixed request set (lower = better) and the
locality mix.  Balanced-PANDAS should degrade the least from `exact` to the
perturbed settings — the paper's conclusion, live on real model execution.

`bench_scenarios` adds the time-varying leg: scenario playback
(`repro.workloads`) drives BOTH request arrival times (the scenario's
lam_mult track, via `workloads.arrival_steps`) and replica slowdowns (the
engine's own playback), so a flash crowd arrives mid-straggler-window on
real model execution.  The scenario grid includes a recorded-trace replay
("trace": the bundled flash-crowd day compiled by `repro.workloads.trace`),
and every scenario run is re-recorded through the engine's trace export
hook (`ServingEngine.recorded_trace`) so it can be replayed
deterministically — `replay_trace` is the standalone replay driver.
"""

from __future__ import annotations

from pathlib import Path
from typing import Optional

import numpy as np


def bench(fast: bool = True, tracer=None):
    """Per-(scheduler, setting) drain-time rows plus wall-clock request
    latency percentiles (``*_lat_p50/p95/p99``, milliseconds from submit
    to final token).  `tracer` (repro.telemetry.EventRecorder) threads
    into every engine for structured route/admit/decode event traces."""
    import jax
    from repro.configs import registry
    from repro.core.policy import available_routers
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]

    rows = []
    # every registered router rides along automatically (pandas_po2 included)
    for scheduler in available_routers():
        for setting, kw in (
            ("exact", {}),
            ("wrong_priors", {"rate_local": 0.2, "rate_rack": 0.9,
                              "rate_remote": 0.9}),
            ("straggler", {"slow": {1: 5.0}}),
        ):
            slow = kw.pop("slow", None)
            ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                                slots_per_replica=2, max_len=64,
                                prefill_buckets=(16,), scheduler=scheduler,
                                tracer=tracer, **kw)
            eng = ServingEngine(cfg, prm, ecfg, slow_replicas=slow)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=4,
                            prefix_id=i % 5)
                    for i, p in enumerate(prompts)]
            eng.run_until_drained(reqs, max_steps=600)
            rows.append((f"serve_{scheduler}_{setting}",
                         float(eng.steps),
                         f"tiers={eng.assign_tiers}"))
            lat_ms = np.sort([(r.finish_time - r.arrival) * 1e3
                              for r in reqs])
            for q in (50, 95, 99):
                rows.append((f"serve_{scheduler}_{setting}_lat_p{q}",
                             float(np.percentile(lat_ms, q)),
                             "ms, wall-clock submit -> final token"))
    return rows


def _run_scenario_once(cfg, prm, prompts, scheduler, spec, label,
                       max_steps=800):
    """One engine run with scenario-timed arrivals; returns (row, engine)."""
    from repro.serve.engine import EngineConfig, Request, ServingEngine
    from repro.workloads import arrival_steps

    ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,), scheduler=scheduler,
                        scenario=spec, scenario_horizon=200)
    eng = ServingEngine(cfg, prm, ecfg)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4, prefix_id=i % 5)
            for i, p in enumerate(prompts)]
    # The scenario's arrival track times the submissions; its fault track
    # (engine playback) inflates observed service times.
    when = arrival_steps(eng.playback, len(reqs),
                         base_per_step=len(reqs) / 60.0)
    nxt = 0
    while any(r.finish_time == 0.0 for r in reqs):
        while nxt < len(reqs) and when[nxt] <= eng.steps:
            eng.submit(reqs[nxt])
            nxt += 1
        eng.step()
        if eng.steps > max_steps:
            raise RuntimeError(
                f"scenario bench did not drain ({scheduler}, {label})")
    row = (f"serve_{scheduler}_scn_{label}", float(eng.steps),
           f"tiers={eng.assign_tiers}")
    return row, eng


def bench_scenarios(fast: bool = True,
                    export_dir: Optional[str] = "experiments/traces"):
    """Scenario playback on the live engine: timed arrivals + slowdowns.

    The grid covers synthetic drift plus a recorded-trace replay
    ("trace": the bundled flash-crowd day).  With `export_dir` set, every
    run is re-recorded through the engine's trace export hook and written
    as ``<scheduler>_<scenario>.jsonl`` — load any of them back with
    ``scenario=ScenarioConfig("trace", {"path": ...})`` to replay the
    exact observed traffic.
    """
    import jax
    from repro.configs import registry
    from repro.models import params as P
    from repro.workloads import ScenarioConfig, save_trace

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]

    grid = (("static", "static"), ("flash_crowd", "flash_crowd"),
            ("stragglers", "stragglers"),
            ("trace", ScenarioConfig("trace", {"name": "flash_day",
                                               "max_segments": 32})))
    rows = []
    for scheduler in ("balanced_pandas", "jsq_maxweight"):
        for label, spec in grid:
            row, eng = _run_scenario_once(cfg, prm, prompts, scheduler,
                                          spec, label)
            rows.append(row)
            if export_dir is not None:
                out = Path(export_dir)
                out.mkdir(parents=True, exist_ok=True)
                save_trace(eng.recorded_trace(name=f"{scheduler}_{label}"),
                           out / f"{scheduler}_{label}.jsonl")
    return rows


def bench_control(fast: bool = True, tracer=None):
    """Host control plane on the live engine: drain steps + sojourn p95
    per control arm (none / admission / autoscale), plus a closed-loop
    client-driven run (steps to serve a fixed completion budget with N
    think-time users).  The `none` arm is the pre-control reference —
    control hooks off the hot path must cost nothing there.
    """
    import jax
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]
    base = dict(num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
                max_len=64, prefill_buckets=(16,), tracer=tracer)

    rows = []
    arms = (
        ("none", None),
        ("admission", {"name": "token_bucket",
                       "options": {"rate": 0.25, "burst": 8.0}}),
        ("autoscale", {"name": "autoscale",
                       "options": {"p95_high": 1e9, "p95_low": 1e8,
                                   "down_after": 2, "cooldown": 2,
                                   "min_servers": 1, "step_frac": 0.5}}),
    )
    for label, control in arms:
        eng = ServingEngine(cfg, prm, EngineConfig(**base, control=control))
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4, prefix_id=i % 5)
                for i, p in enumerate(prompts)]
        eng.run_until_drained(reqs, max_steps=600)
        shed = 0 if eng.control is None else eng.control.shed
        rows.append((f"serve_control_{label}", float(eng.steps),
                     f"completed={eng.completed},shed={shed}"))
        p95 = float(eng.sojourn_percentiles((0.95,))[0])
        rows.append((f"serve_control_{label}_sojourn_p95", p95,
                     "engine steps, submit -> finish, upper bin edge"))

    # Closed loop: N users with think time drive the engine until a fixed
    # completion budget is served; reported as steps to serve the budget.
    budget = n_req
    eng = ServingEngine(cfg, prm, EngineConfig(
        **base, control={"name": "closed_loop",
                         "options": {"users": 8, "think_time": 4.0}}))
    clients = eng.control.clients
    rid = 0
    while eng.completed < budget and eng.steps < 600:
        for _ in range(clients.poll(eng.steps, eng.completed)):
            eng.submit(Request(
                rid=rid, prompt=prompts[rid % len(prompts)],
                max_new_tokens=4, prefix_id=rid % 5))
            rid += 1
        eng.step()
    rows.append(("serve_control_closed_loop", float(eng.steps),
                 f"steps to {budget} completions with 8 think-time users"))
    return rows


def replay_trace(spec=None, scheduler: str = "balanced_pandas",
                 fast: bool = True, export_path: Optional[str] = None):
    """Replay one trace-compiled Scenario through the live engine.

    `spec` is anything `make_scenario` accepts (default: the bundled
    "diurnal_week" trace).  The scenario times request submission and
    inflates observed service times; with `export_path` the run is
    re-recorded and saved, closing the record -> replay loop.  Returns
    the bench rows.
    """
    import jax
    from repro.configs import registry
    from repro.models import params as P
    from repro.workloads import ScenarioConfig, make_scenario, save_trace

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 12 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]
    scn = make_scenario(spec if spec is not None
                        else ScenarioConfig("trace", {"max_segments": 32}))
    row, eng = _run_scenario_once(cfg, prm, prompts, scheduler, scn,
                                  scn.name.replace(":", "_"),
                                  max_steps=1200)
    if export_path is not None:
        Path(export_path).parent.mkdir(parents=True, exist_ok=True)
        save_trace(eng.recorded_trace(name=scn.name), export_path)
    return [row]
