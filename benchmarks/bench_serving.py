"""Serving-level robustness benchmark: the paper's experiment transplanted to
the continuous-batching engine.

Three fleets are compared under each scheduler:
  exact     — router priors equal the true tier rates
  wrong     — priors off (the engine's blind EWMA must recover)
  straggler — one replica is 5x slow and the priors don't know

Reported: engine steps to drain a fixed request set (lower = better) and the
locality mix.  Balanced-PANDAS should degrade the least from `exact` to the
perturbed settings — the paper's conclusion, live on real model execution.

`bench_scenarios` adds the time-varying leg: scenario playback
(`repro.workloads`) drives BOTH request arrival times (the scenario's
lam_mult track, via `workloads.arrival_steps`) and replica slowdowns (the
engine's own playback), so a flash crowd arrives mid-straggler-window on
real model execution.
"""

from __future__ import annotations

import numpy as np


def bench(fast: bool = True):
    import jax
    from repro.configs import registry
    from repro.core.policy import available_routers
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]

    rows = []
    # every registered router rides along automatically (pandas_po2 included)
    for scheduler in available_routers():
        for setting, kw in (
            ("exact", {}),
            ("wrong_priors", {"rate_local": 0.2, "rate_rack": 0.9,
                              "rate_remote": 0.9}),
            ("straggler", {"slow": {1: 5.0}}),
        ):
            slow = kw.pop("slow", None)
            ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                                slots_per_replica=2, max_len=64,
                                prefill_buckets=(16,), scheduler=scheduler,
                                **kw)
            eng = ServingEngine(cfg, prm, ecfg, slow_replicas=slow)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=4,
                            prefix_id=i % 5)
                    for i, p in enumerate(prompts)]
            eng.run_until_drained(reqs, max_steps=600)
            rows.append((f"serve_{scheduler}_{setting}",
                         float(eng.steps),
                         f"tiers={eng.assign_tiers}"))
    return rows


def bench_scenarios(fast: bool = True):
    """Scenario playback on the live engine: timed arrivals + slowdowns."""
    import jax
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine
    from repro.workloads import arrival_steps

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]

    rows = []
    for scheduler in ("balanced_pandas", "jsq_maxweight"):
        for scenario in ("static", "flash_crowd", "stragglers"):
            ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                                slots_per_replica=2, max_len=64,
                                prefill_buckets=(16,), scheduler=scheduler,
                                scenario=scenario, scenario_horizon=200)
            eng = ServingEngine(cfg, prm, ecfg)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=4,
                            prefix_id=i % 5)
                    for i, p in enumerate(prompts)]
            # The scenario's arrival track times the submissions; its fault
            # track (engine playback) inflates observed service times.
            when = arrival_steps(eng.playback, len(reqs),
                                 base_per_step=len(reqs) / 60.0)
            nxt = 0
            while any(r.finish_time == 0.0 for r in reqs):
                while nxt < len(reqs) and when[nxt] <= eng.steps:
                    eng.submit(reqs[nxt])
                    nxt += 1
                eng.step()
                if eng.steps > 800:
                    raise RuntimeError(
                        f"scenario bench did not drain ({scheduler}, "
                        f"{scenario})")
            rows.append((f"serve_{scheduler}_scn_{scenario}",
                         float(eng.steps),
                         f"tiers={eng.assign_tiers}"))
    return rows
