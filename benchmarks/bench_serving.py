"""Serving-level robustness benchmark: the paper's experiment transplanted to
the continuous-batching engine.

Three fleets are compared under each scheduler:
  exact     — router priors equal the true tier rates
  wrong     — priors off (the engine's blind EWMA must recover)
  straggler — one replica is 5x slow and the priors don't know

Reported: engine steps to drain a fixed request set (lower = better) and the
locality mix.  Balanced-PANDAS should degrade the least from `exact` to the
perturbed settings — the paper's conclusion, live on real model execution.
"""

from __future__ import annotations

import numpy as np


def bench(fast: bool = True):
    import jax
    from repro.configs import registry
    from repro.core.policy import available_routers
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
               for _ in range(n_req)]

    rows = []
    # every registered router rides along automatically (pandas_po2 included)
    for scheduler in available_routers():
        for setting, kw in (
            ("exact", {}),
            ("wrong_priors", {"rate_local": 0.2, "rate_rack": 0.9,
                              "rate_remote": 0.9}),
            ("straggler", {"slow": {1: 5.0}}),
        ):
            slow = kw.pop("slow", None)
            ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                                slots_per_replica=2, max_len=64,
                                prefill_buckets=(16,), scheduler=scheduler,
                                **kw)
            eng = ServingEngine(cfg, prm, ecfg, slow_replicas=slow)
            reqs = [Request(rid=i, prompt=p, max_new_tokens=4,
                            prefix_id=i % 5)
                    for i, p in enumerate(prompts)]
            eng.run_until_drained(reqs, max_steps=600)
            rows.append((f"serve_{scheduler}_{setting}",
                         float(eng.steps),
                         f"tiers={eng.assign_tiers}"))
    return rows
