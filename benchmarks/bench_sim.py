"""Simulator throughput bench: slots/sec per policy, 3-tier vs 4-tier.

The tier-generic refactor makes the tier count a parameter of every hot
path (policy state shapes, kernel tier derivation, schedule compilation),
so this bench tracks what that generality costs: for each registered
policy, the wall-clock rate (simulated slots per second, compile time
excluded) of one jit-compiled run on the classic flat-rack topology and
on a 4-tier pod topology of the same fleet size.

Rows come back in the orchestrator's ``(name, value, derived)`` format;
``benchmarks/run.py --json`` additionally serializes them into the
machine-readable perf record CI uploads (the bench trajectory's seed).
Every arm reports BOTH a steady-state rate row (``sim_slots_per_sec_*``,
min-of-3 on the already-compiled executable) and a compile-time row
(``sim_compile_sec_*``, the XLA lowering+compile step timed separately
via AOT compilation) — so a compile-time regression can't hide inside a
throughput number or vice versa.  Pass an
`repro.telemetry.EventRecorder` as ``tracer`` to additionally wrap the
compile and dispatch phases in Chrome-trace spans
(``benchmarks/run.py --trace``).
"""

from __future__ import annotations

import time

import numpy as np


def _timed(run, args) -> float:
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(run(*args))
    return time.perf_counter() - t0


def _compile_split(run, args, tracer=None, label=""):
    """(compile_sec, steady_sec): AOT-split timings of a jitted callable.

    Compile time is the real XLA compile (``.lower().compile()``), not a
    first-call-minus-steady estimate; steady time is min-of-3 on the
    compiled executable after one warm call (a single sample is dominated
    by run-to-run noise, which would drown any real regression in the CI
    trajectory).
    """
    import jax
    from repro.telemetry import maybe_span

    with maybe_span(tracer, f"compile:{label}", cat="compile"):
        lowered = run.lower(*args)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    jax.block_until_ready(compiled(*args))  # warm: allocs, autotuning
    with maybe_span(tracer, f"steady:{label}", cat="kernel"):
        dt = min(_timed(compiled, args) for _ in range(3))
    return t_compile, dt


def bench(fast: bool = True, tracer=None):
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.core.policy import PolicyConfig, available_policies

    horizon = 2_000 if fast else 20_000
    grids = (
        ("3tier", loc.Topology(24, 6), loc.Rates()),
        ("4tier", loc.Topology(24, (6, 12)), loc.Rates((0.5, 0.45, 0.35,
                                                        0.25))),
    )
    rows = []
    for label, topo, rates in grids:
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=24, horizon=horizon,
                            warmup=horizon // 4)
        cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
        est = sim.make_estimates(cfg, "network", 0.0, -1)
        for name in available_policies():
            policy = PolicyConfig(name, {"prior": rates.values}) \
                if name == "blind_pandas" else name
            run = jax.jit(sim._build_run(policy, cfg))
            args = (np.float32(0.8 * cap), est.astype(np.float32),
                    np.uint32(0))
            t_compile, dt = _compile_split(run, args, tracer,
                                           f"{name}_{label}")
            derived = (f"policy={name},topology={label},K={topo.num_tiers},"
                       f"M={topo.num_servers},horizon={horizon}")
            rows.append((f"sim_slots_per_sec_{name}_{label}",
                         horizon / dt, derived))
            rows.append((f"sim_compile_sec_{name}_{label}", t_compile,
                         derived))
    return rows


def bench_scaling(fast: bool = True, tracer=None):
    """Fleet-scale throughput: simulated slots/sec of the fleet fast path
    (sharding.sim) at M=2400 and M=10008 servers, plus the dense
    reference arm at M=2400.

    These are the headline rows of the fast-fleet-path work: the dense
    `lax.scan` body is dispatch-bound (a sequential `fori_loop` of
    O(M) argmins per arrival), while the fleet path routes the whole
    arrival batch against a workload snapshot in O(M*depth + B) — see
    docs/scaling.md for the performance model.  The dense M=2400 row is
    the "before" curve; `sim_slots_per_sec_scaling_kernel_M10008` is the
    acceptance metric for 10k-server studies.
    """
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.sharding.sim import FleetConfig, _build_fleet_chunk

    rows = []
    horizon = 512 if fast else 2_048
    fleet_ms = (2_400, 10_008) if fast else (2_400, 10_008, 24_000)
    rates = loc.Rates()

    def fleet_arm(m):
        topo = loc.Topology(m, 6)
        cap = loc.capacity_hot_rack(topo, rates, 0.5)
        lam = 0.8 * cap
        batch = int(2.05 * lam)
        fc = FleetConfig()
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=batch, horizon=horizon,
                            warmup=horizon // 4)
        est = loc.per_server_rates(rates.as_array(), m).astype(np.float32)
        init, chunk = _build_fleet_chunk("balanced_pandas", cfg, fc)
        run = jax.jit(chunk)  # no donation: _compile_split reuses args
        args = (init(), np.int32(0), np.float32(lam), est, np.uint32(0))
        t_compile, dt = _compile_split(run, args, tracer,
                                       f"scaling_kernel_M{m}")
        derived = (f"path=fleet,policy=balanced_pandas,M={m},"
                   f"chunk={fc.chunk},rounds={fc.rounds},"
                   f"batch={batch},horizon={horizon}")
        rows.append((f"sim_slots_per_sec_scaling_kernel_M{m}",
                     fc.chunk / dt, derived))
        rows.append((f"sim_compile_sec_scaling_kernel_M{m}", t_compile,
                     derived))

    def dense_arm(m, dense_horizon):
        topo = loc.Topology(m, 6)
        cap = loc.capacity_hot_rack(topo, rates, 0.5)
        lam = 0.8 * cap
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=int(2.05 * lam),
                            horizon=dense_horizon,
                            warmup=dense_horizon // 4)
        est = loc.per_server_rates(rates.as_array(), m).astype(np.float32)
        run = jax.jit(sim._build_run("balanced_pandas", cfg))
        args = (np.float32(lam), est, np.uint32(0))
        t_compile, dt = _compile_split(run, args, tracer,
                                       f"scaling_dense_M{m}")
        derived = (f"path=dense,policy=balanced_pandas,M={m},"
                   f"horizon={dense_horizon}")
        rows.append((f"sim_slots_per_sec_scaling_dense_M{m}",
                     dense_horizon / dt, derived))
        rows.append((f"sim_compile_sec_scaling_dense_M{m}", t_compile,
                     derived))

    for m in fleet_ms:
        fleet_arm(m)
    dense_arm(2_400, 64 if fast else 256)
    return rows


def bench_placement(fast: bool = True, tracer=None):
    """Placement-sampler throughput: simulator slots/sec of the default
    policy under every registered replica placement, 3-tier and 4-tier.

    The placement seam swaps the arrival-type sampler inside the
    `lax.scan`; this bench tracks what each compiled sampler costs
    relative to the bitwise-pinned uniform draw (the §Placement
    throughput record of the CI bench artifact).
    """
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.placement import available_placements

    horizon = 2_000 if fast else 20_000
    grids = (
        ("3tier", loc.Topology(24, 6), loc.Rates()),
        ("4tier", loc.Topology(24, (6, 12)), loc.Rates((0.5, 0.45, 0.35,
                                                        0.25))),
    )
    rows = []
    for label, topo, rates in grids:
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=24, horizon=horizon,
                            warmup=horizon // 4)
        cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
        est = sim.make_estimates(cfg, "network", 0.0, -1)
        args = (np.float32(0.7 * cap), est.astype(np.float32), np.uint32(0))
        for plc in available_placements():
            run = jax.jit(sim._build_run("balanced_pandas", cfg,
                                         placement=plc))
            t_compile, dt = _compile_split(run, args, tracer,
                                           f"placement_{plc}_{label}")
            derived = (f"placement={plc},policy=balanced_pandas,"
                       f"topology={label},K={topo.num_tiers},"
                       f"M={topo.num_servers},horizon={horizon}")
            rows.append((f"sim_slots_per_sec_placement_{plc}_{label}",
                         horizon / dt, derived))
            rows.append((f"sim_compile_sec_placement_{plc}_{label}",
                         t_compile, derived))
    return rows


def bench_control(fast: bool = True, tracer=None):
    """Control-plane throughput: simulator slots/sec of the default policy
    with each control arm compiled into the scan — no control (the
    bitwise-pinned reference), token-bucket admission, closed-loop load
    generation, proactive autoscaling, and the full stack with the
    SLO-conditioned scheduler + telemetry (the §SLO control study
    configuration).  Tracks what each per-slot hook costs relative to the
    zero-cost ``control=None`` baseline.
    """
    import jax
    from repro.core import locality as loc, simulator as sim

    horizon = 2_000 if fast else 20_000
    topo, rates = loc.Topology(24, 6), loc.Rates()
    cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                        max_arrivals=24, horizon=horizon,
                        warmup=horizon // 4)
    cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    args = (np.float32(0.9 * cap), est.astype(np.float32), np.uint32(0))
    bucket = {"name": "token_bucket",
              "options": {"rate": 0.93 * cap, "burst": 8.0 * cap}}
    arms = [
        ("none", "balanced_pandas", None, None),
        ("admission", "balanced_pandas", bucket, None),
        ("closed_loop", "balanced_pandas",
         {"name": "closed_loop", "options": {"users": 64}}, None),
        ("autoscale", "balanced_pandas", "autoscale", None),
        ("full_slo", "slo_pandas", (bucket, "autoscale"), True),
    ]
    rows = []
    for label, pol, control, telemetry in arms:
        run = jax.jit(sim._build_run(pol, cfg, control=control,
                                     telemetry=telemetry))
        t_compile, dt = _compile_split(run, args, tracer,
                                       f"control_{label}")
        derived = (f"control={label},policy={pol},K={topo.num_tiers},"
                   f"M={topo.num_servers},horizon={horizon},"
                   f"telemetry={bool(telemetry)}")
        rows.append((f"sim_slots_per_sec_control_{label}", horizon / dt,
                     derived))
        rows.append((f"sim_compile_sec_control_{label}", t_compile,
                     derived))
    return rows


def bench_replication(fast: bool = True, tracer=None):
    """Replication-lifecycle throughput: simulator slots/sec of the default
    policy under every registered replication controller, with the
    server_loss scenario engaged so the lifecycle machinery (chunk
    catalogue, migration lanes, repair scans) is actually in the scan body.

    The `fixed`+static row is the bitwise-pinned passthrough (no lifecycle
    state in the carry at all), included as the zero-cost reference.
    """
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.replication import available_replications

    horizon = 2_000 if fast else 20_000
    topo, rates = loc.Topology(24, 6), loc.Rates()
    cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                        max_arrivals=24, horizon=horizon,
                        warmup=horizon // 4)
    cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    args = (np.float32(0.7 * cap), est.astype(np.float32), np.uint32(0))
    arms = [("fixed", "static")]
    arms += [(ctrl, "server_loss") for ctrl in available_replications()]
    rows = []
    for ctrl, scen in arms:
        run = jax.jit(sim._build_run("balanced_pandas", cfg, scenario=scen,
                                     replication=ctrl))
        t_compile, dt = _compile_split(run, args, tracer,
                                       f"replication_{ctrl}_{scen}")
        derived = (f"replication={ctrl},scenario={scen},"
                   f"policy=balanced_pandas,K={topo.num_tiers},"
                   f"M={topo.num_servers},horizon={horizon}")
        rows.append((f"sim_slots_per_sec_replication_{ctrl}_{scen}",
                     horizon / dt, derived))
        rows.append((f"sim_compile_sec_replication_{ctrl}_{scen}",
                     t_compile, derived))
    return rows
