"""Simulator throughput bench: slots/sec per policy, 3-tier vs 4-tier.

The tier-generic refactor makes the tier count a parameter of every hot
path (policy state shapes, kernel tier derivation, schedule compilation),
so this bench tracks what that generality costs: for each registered
policy, the wall-clock rate (simulated slots per second, compile time
excluded) of one jit-compiled run on the classic flat-rack topology and
on a 4-tier pod topology of the same fleet size.

Rows come back in the orchestrator's ``(name, value, derived)`` format;
``benchmarks/run.py --json`` additionally serializes them into the
machine-readable perf record CI uploads (the bench trajectory's seed).
"""

from __future__ import annotations

import time

import numpy as np


def _timed(run, args) -> float:
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(run(*args))
    return time.perf_counter() - t0


def bench(fast: bool = True):
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.core.policy import PolicyConfig, available_policies

    horizon = 2_000 if fast else 20_000
    grids = (
        ("3tier", loc.Topology(24, 6), loc.Rates()),
        ("4tier", loc.Topology(24, (6, 12)), loc.Rates((0.5, 0.45, 0.35,
                                                        0.25))),
    )
    rows = []
    for label, topo, rates in grids:
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=24, horizon=horizon,
                            warmup=horizon // 4)
        cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
        est = sim.make_estimates(cfg, "network", 0.0, -1)
        for name in available_policies():
            policy = PolicyConfig(name, {"prior": rates.values}) \
                if name == "blind_pandas" else name
            run = jax.jit(sim._build_run(policy, cfg))
            args = (np.float32(0.8 * cap), est.astype(np.float32),
                    np.uint32(0))
            jax.block_until_ready(run(*args))  # compile
            # min-of-3: a single sample is dominated by run-to-run noise,
            # which would drown any real regression in the CI trajectory
            dt = min(_timed(run, args) for _ in range(3))
            rows.append((f"sim_slots_per_sec_{name}_{label}",
                         horizon / dt,
                         f"policy={name},topology={label},K={topo.num_tiers},"
                         f"M={topo.num_servers},horizon={horizon}"))
    return rows


def bench_placement(fast: bool = True):
    """Placement-sampler throughput: simulator slots/sec of the default
    policy under every registered replica placement, 3-tier and 4-tier.

    The placement seam swaps the arrival-type sampler inside the
    `lax.scan`; this bench tracks what each compiled sampler costs
    relative to the bitwise-pinned uniform draw (the §Placement
    throughput record of the CI bench artifact).
    """
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.placement import available_placements

    horizon = 2_000 if fast else 20_000
    grids = (
        ("3tier", loc.Topology(24, 6), loc.Rates()),
        ("4tier", loc.Topology(24, (6, 12)), loc.Rates((0.5, 0.45, 0.35,
                                                        0.25))),
    )
    rows = []
    for label, topo, rates in grids:
        cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                            max_arrivals=24, horizon=horizon,
                            warmup=horizon // 4)
        cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
        est = sim.make_estimates(cfg, "network", 0.0, -1)
        args = (np.float32(0.7 * cap), est.astype(np.float32), np.uint32(0))
        for plc in available_placements():
            run = jax.jit(sim._build_run("balanced_pandas", cfg,
                                         placement=plc))
            jax.block_until_ready(run(*args))  # compile
            dt = min(_timed(run, args) for _ in range(3))
            rows.append((f"sim_slots_per_sec_placement_{plc}_{label}",
                         horizon / dt,
                         f"placement={plc},policy=balanced_pandas,"
                         f"topology={label},K={topo.num_tiers},"
                         f"M={topo.num_servers},horizon={horizon}"))
    return rows


def bench_replication(fast: bool = True):
    """Replication-lifecycle throughput: simulator slots/sec of the default
    policy under every registered replication controller, with the
    server_loss scenario engaged so the lifecycle machinery (chunk
    catalogue, migration lanes, repair scans) is actually in the scan body.

    The `fixed`+static row is the bitwise-pinned passthrough (no lifecycle
    state in the carry at all), included as the zero-cost reference.
    """
    import jax
    from repro.core import locality as loc, simulator as sim
    from repro.replication import available_replications

    horizon = 2_000 if fast else 20_000
    topo, rates = loc.Topology(24, 6), loc.Rates()
    cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                        max_arrivals=24, horizon=horizon,
                        warmup=horizon // 4)
    cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    args = (np.float32(0.7 * cap), est.astype(np.float32), np.uint32(0))
    arms = [("fixed", "static")]
    arms += [(ctrl, "server_loss") for ctrl in available_replications()]
    rows = []
    for ctrl, scen in arms:
        run = jax.jit(sim._build_run("balanced_pandas", cfg, scenario=scen,
                                     replication=ctrl))
        jax.block_until_ready(run(*args))  # compile
        dt = min(_timed(run, args) for _ in range(3))
        rows.append((f"sim_slots_per_sec_replication_{ctrl}_{scen}",
                     horizon / dt,
                     f"replication={ctrl},scenario={scen},"
                     f"policy=balanced_pandas,K={topo.num_tiers},"
                     f"M={topo.num_servers},horizon={horizon}"))
    return rows
