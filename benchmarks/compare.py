"""Compare two bench artifacts (schema-2 ``BENCH_*.json``) and flag
throughput regressions.

    PYTHONPATH=src python -m benchmarks.compare BASELINE.json CURRENT.json \
        [--threshold 0.20] [--fail-on-regression]

Rows are matched by record name.  Only throughput-style rows (names
containing ``slots_per_sec``, ``tokens_per_sec``, ``us_per_call`` or
``_per_sec``) participate in regression gating; for ``us_per_call`` /
``_sec_`` rows *higher is worse*, for ``per_sec`` rows *lower is worse*.
A row regresses when it is more than ``--threshold`` (default 20%) worse
than the baseline.  Everything is printed either way — the CI job runs
warn-only (no ``--fail-on-regression``), so a noisy container can't block
a merge, but the deltas land in the job log and the artifact trail.

``--update`` refreshes the committed baseline in place: after printing
the old-vs-new diff, CURRENT's artifact replaces BASELINE on disk.  Use
it when a PR intentionally moves a number (new bench rows, a real
speedup) so the next comparison measures against the new normal:

    PYTHONPATH=src python -m benchmarks.run --json /tmp/BENCH_new.json
    PYTHONPATH=src python -m benchmarks.compare \
        benchmarks/BENCH_sim.json /tmp/BENCH_new.json --update
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

# name-substring -> direction ("up" = bigger is better)
_GATED = (
    ("slots_per_sec", "up"),
    ("tokens_per_sec", "up"),
    ("_per_sec", "up"),
    ("us_per_call", "down"),
    ("compile_sec", "down"),
)


def _direction(name: str) -> str | None:
    for sub, direction in _GATED:
        if sub in name:
            return direction
    return None


def load_records(path: str) -> Dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 2:
        raise SystemExit(f"{path}: expected schema 2 artifact, got "
                         f"{doc.get('schema')!r}")
    return {r["name"]: float(r["value"]) for r in doc["records"]}


def compare(base: Dict[str, float], cur: Dict[str, float],
            threshold: float) -> Tuple[list, list, list]:
    """(regressions, improvements, other) rows: (name, base, cur, ratio).

    ratio > 1 means better than baseline, < 1 worse, regardless of the
    row's direction.
    """
    regressions, improvements, other = [], [], []
    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        direction = _direction(name)
        if direction is None or b <= 0 or c <= 0:
            other.append((name, b, c, float("nan")))
            continue
        ratio = c / b if direction == "up" else b / c
        row = (name, b, c, ratio)
        if ratio < 1.0 - threshold:
            regressions.append(row)
        elif ratio > 1.0 + threshold:
            improvements.append(row)
        else:
            other.append(row)
    return regressions, improvements, other


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n\n")[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="relative slowdown that counts as a regression "
                         "(default 0.20 = 20%%)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 if any gated row regressed (CI default "
                         "is warn-only)")
    ap.add_argument("--update", action="store_true",
                    help="after printing the diff, overwrite BASELINE "
                         "with CURRENT (refresh the committed baseline)")
    args = ap.parse_args()

    base = load_records(args.baseline)
    cur = load_records(args.current)
    regressions, improvements, other = compare(base, cur, args.threshold)

    missing = sorted(set(base) - set(cur))
    new = sorted(set(cur) - set(base))

    def show(title, rows):
        if not rows:
            return
        print(f"## {title}")
        for name, b, c, ratio in rows:
            pct = "" if ratio != ratio else f"  ({(ratio - 1) * 100:+.1f}%)"
            print(f"  {name}: {b:.4g} -> {c:.4g}{pct}")

    show(f"REGRESSIONS (> {args.threshold:.0%} worse)", regressions)
    show(f"improvements (> {args.threshold:.0%} better)", improvements)
    if missing:
        print(f"## rows only in baseline: {', '.join(missing)}")
    if new:
        print(f"## rows only in current: {', '.join(new)}")
    print(f"# {len(regressions)} regressions, {len(improvements)} "
          f"improvements, {len(other)} within threshold, "
          f"{len(missing)} missing, {len(new)} new")

    if args.update:
        # verbatim copy (not a re-dump) so the refreshed baseline is
        # byte-identical to the artifact CI would have uploaded
        with open(args.current) as f:
            payload = f.read()
        with open(args.baseline, "w") as f:
            f.write(payload)
        print(f"# baseline updated: {args.baseline} <- {args.current}")

    if regressions and args.fail_on_regression:
        sys.exit(1)


if __name__ == "__main__":
    main()
