"""Paper-figure benchmarks (Figures 1-6 of Daghighi & Chen 2020).

Each function runs the corresponding experiment on the discrete-time
simulator and returns tidy rows; run.py prints them and writes CSVs under
experiments/figures/.

fig1  all four algorithms, exact parameters, load sweep
fig2  high-load closeup: Balanced-PANDAS vs JSQ-MaxWeight
fig3  delay under parameters LOWER than real (eps in 5..30%)
fig4  sensitivity (relative delay change) for fig3
fig5  delay under parameters HIGHER than real
fig6  sensitivity for fig5
drift (beyond-paper) fixed-prior vs blind-EWMA Balanced-PANDAS under the
      registered time-varying scenarios — the experiment the paper
      motivates ("the change of traffic over time") but never runs
"""

from __future__ import annotations

import numpy as np

from repro.core import locality as loc, robustness as rb, simulator as sim


def _study(fast: bool) -> rb.StudyConfig:
    if fast:
        return rb.StudyConfig(
            sim=sim.default_config(horizon=6_000, warmup=1_500),
            loads=(0.6, 0.8, 0.9, 0.95), high_loads=(0.9, 0.95),
            eps_grid=(0.1, 0.2, 0.3), seeds=(0,))
    return rb.StudyConfig(
        sim=sim.default_config(horizon=30_000, warmup=8_000),
        loads=(0.5, 0.6, 0.7, 0.8, 0.9, 0.95),
        eps_grid=rb.EPS_GRID, seeds=(0, 1))


def fig1_precise(fast: bool = True):
    """All four algorithms with exact rate knowledge."""
    cfg = _study(fast)
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates,
                                cfg.sim.p_hot)
    lam = np.asarray(cfg.loads, np.float32) * cap
    exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]
    rows = []
    for algo in rb.RATE_AWARE + rb.RATE_OBLIVIOUS:
        res = sim.sweep(algo, cfg.sim, lam, exact, np.asarray(cfg.seeds))
        d = res["mean_delay"].mean(axis=(1, 2))
        for load, delay in zip(cfg.loads, d):
            rows.append({"figure": "fig1", "algo": algo, "load": load,
                         "eps": 0.0, "sign": 0, "mean_delay": float(delay)})
    return rows


def fig2_highload(fast: bool = True):
    cfg = _study(fast)
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates,
                                cfg.sim.p_hot)
    lam = np.asarray(cfg.high_loads, np.float32) * cap
    exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]
    rows = []
    for algo in ("balanced_pandas", "jsq_maxweight"):
        res = sim.sweep(algo, cfg.sim, lam, exact, np.asarray(cfg.seeds))
        d = res["mean_delay"].mean(axis=(1, 2))
        for load, delay in zip(cfg.high_loads, d):
            rows.append({"figure": "fig2", "algo": algo, "load": load,
                         "eps": 0.0, "sign": 0, "mean_delay": float(delay)})
    return rows


def _fig_err(fig: str, sign: int, fast: bool):
    """figs 3/5 (delay) + 4/6 (sensitivity) share one sweep."""
    cfg = _study(fast)
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates,
                                cfg.sim.p_hot)
    loads = cfg.high_loads if fast else cfg.loads[-4:]
    lam = np.asarray(loads, np.float32) * cap
    ests = [sim.make_estimates(cfg.sim, "network", 0.0, -1)]
    for eps in cfg.eps_grid:
        ests.append(sim.make_estimates(cfg.sim, cfg.error_mode, eps, sign))
    est_stack = np.stack(ests)
    rows = []
    for algo in rb.RATE_AWARE:
        res = sim.sweep(algo, cfg.sim, lam, est_stack, np.asarray(cfg.seeds))
        d = res["mean_delay"].mean(-1)  # (L, E)
        for li, load in enumerate(loads):
            rows.append({"figure": fig, "algo": algo, "load": load,
                         "eps": 0.0, "sign": sign,
                         "mean_delay": float(d[li, 0])})
            for ei, eps in enumerate(cfg.eps_grid):
                rows.append({"figure": fig, "algo": algo, "load": load,
                             "eps": eps, "sign": sign,
                             "mean_delay": float(d[li, ei + 1]),
                             "sensitivity": float(
                                 (d[li, ei + 1] - d[li, 0]) / d[li, 0])})
    # rate-oblivious baselines appear once (their decisions ignore rates)
    exact = est_stack[:1]
    for algo in rb.RATE_OBLIVIOUS:
        res = sim.sweep(algo, cfg.sim, lam, exact, np.asarray(cfg.seeds))
        d = res["mean_delay"].mean(-1)
        for li, load in enumerate(loads):
            rows.append({"figure": fig, "algo": algo, "load": load,
                         "eps": 0.0, "sign": sign,
                         "mean_delay": float(d[li, 0])})
    return rows


def fig34_under(fast: bool = True):
    return _fig_err("fig3_4", -1, fast)


def fig56_over(fast: bool = True):
    return _fig_err("fig5_6", +1, fast)


def fig_drift(fast: bool = True, scenarios=None):
    """Drift study rows: mean delay of the fixed-prior vs blind-EWMA arms
    under each scenario (see `robustness.drift_study`)."""
    cfg = _study(fast)
    study = rb.drift_study(cfg, scenarios=scenarios or rb.DRIFT_SCENARIOS)
    rows = []
    for scen in study["scenarios"]:
        for arm in study["arms"]:
            rows.append({"figure": "drift", "algo": arm, "scenario": scen,
                         "load": study["load"], "eps": 0.0, "sign": 0,
                         "mean_delay":
                             float(study["delay"][scen][arm].mean())})
    return rows


def headline_claims(rows) -> dict:
    """The paper's central claims, checked on the generated data.

    (1) fig1/2: PANDAS delay <= JSQ-MW delay at high load (the paper's
        headline comparison; the Priority deviation is reported separately
        in EXPERIMENTS.md §Reproduction).
    (2) figs 3-6: PANDAS dominates JSQ-MW at EVERY error setting, and its
        absolute delay deviation band (slots) is narrower.  Relative
        sensitivity would punish the algorithm with the lower baseline, so
        absolute deviation is compared — same quantity the paper's figs
        4/6 plot.
    """
    import collections
    by = collections.defaultdict(list)
    for r in rows:
        by[(r["figure"], r["algo"])].append(r)

    out = {}
    for fig in ("fig1", "fig2"):
        f = {a: max(r["mean_delay"] for r in by[(fig, a)])
             for a in ("balanced_pandas", "jsq_maxweight")
             if (fig, a) in by}
        if len(f) == 2:
            out[f"{fig}_pandas_beats_jsq_mw"] = (
                f["balanced_pandas"] <= f["jsq_maxweight"])
    for fig in ("fig3_4", "fig5_6"):
        if ("fig3_4", "balanced_pandas") not in by and \
                (fig, "balanced_pandas") not in by:
            continue
        bp = {(r["load"], r["eps"]): r["mean_delay"]
              for r in by[(fig, "balanced_pandas")]}
        mw = {(r["load"], r["eps"]): r["mean_delay"]
              for r in by[(fig, "jsq_maxweight")]}
        common = sorted(set(bp) & set(mw))
        if not common:
            continue
        out[f"{fig}_pandas_dominates_jsq_mw"] = all(
            bp[k] <= mw[k] for k in common)
        band = lambda d: (max(d[k] for k in common)
                          - min(d[k] for k in common))
        out[f"{fig}_pandas_narrower_band"] = band(bp) <= band(mw)
    # (3) drift: under at least one time-varying scenario the blind EWMA
    #     estimator beats the (initially exact) fixed prior — the scenario
    #     subsystem's headline experiment.
    fix = {r["scenario"]: r["mean_delay"] for r in by[("drift", "fixed_prior")]}
    bl = {r["scenario"]: r["mean_delay"] for r in by[("drift", "blind_ewma")]}
    moving = sorted((set(fix) & set(bl)) - {"static"})
    if moving:
        out["drift_blind_beats_fixed_somewhere"] = any(
            bl[s] < fix[s] for s in moving)
    return out
