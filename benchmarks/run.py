"""Benchmark orchestrator: one section per paper table/figure plus kernel,
serving, and roofline benches.  Prints ``name,us_per_call,derived`` CSV and
writes figure data to experiments/figures/*.csv.

    PYTHONPATH=src python -m benchmarks.run [--full]

``--help`` lists every registered scenario and policy with its one-line
description (the registries are self-describing; see
`workloads.scenario_descriptions` / `core.policy.policy_descriptions`).
"""

from __future__ import annotations

import argparse
import csv
import sys
import time
from pathlib import Path


def _registry_epilog() -> str:
    """Render the scenario/policy/placement registries for --help."""
    from repro import control as ctl, placement as plc, replication as rep
    from repro import workloads as wl
    from repro.core import policy as pol

    def block(title, entries):
        lines = [f"{title}:"]
        for name, desc in entries.items():
            lines.append(f"  {name:18s} {desc}")
        return lines

    lines = block("registered scenarios", wl.scenario_descriptions())
    lines += block("registered policies (simulator)",
                   pol.policy_descriptions())
    lines += block("registered routers (serving engine / data pipeline)",
                   pol.router_descriptions())
    lines += block("registered replica placements (simulator / engine / "
                   "pipeline)", plc.placement_descriptions())
    lines += block("registered replication controllers (lifecycle: "
                   "migration / repair)", rep.replication_descriptions())
    lines += block("registered control-plane controllers (load generation / "
                   "admission / autoscaling)", ctl.controller_descriptions())
    return "\n".join(lines)


def main() -> None:
    # the epilog imports every registry module — only pay that for --help
    wants_help = any(a in ("-h", "--help") for a in sys.argv[1:])
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n\n")[0],
        epilog=_registry_epilog() if wants_help else None,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig34,fig56,drift,kernels,"
                         "sim_throughput,scaling,placement,replication,"
                         "control,serving,serving_scenarios,serving_control,"
                         "trace_replay,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="additionally write every bench row as a "
                         "machine-readable JSON perf record (the artifact "
                         "CI uploads, e.g. BENCH_sim.json; schema 2: "
                         "records + per-section wall times)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(section spans, per-arm compile/steady spans, "
                         "engine route/admit/decode events) — load it at "
                         "https://ui.perfetto.dev")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None

    from repro.utils.cache import enable_persistent_cache

    cache_dir = enable_persistent_cache()
    if cache_dir:
        print(f"# persistent compilation cache: {cache_dir}",
              file=sys.stderr)

    from benchmarks import bench_kernels, bench_roofline, bench_serving
    from benchmarks import bench_sim, figures

    tracer = None
    if args.trace:
        from repro.telemetry import EventRecorder
        tracer = EventRecorder()
        tracer.metadata("process_name", name="benchmarks.run")

    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    csv_rows = []
    fig_rows = []
    section_times = {}

    def section(name, fn):
        if only and name not in only:
            return
        t0 = time.time()
        if tracer is None:
            rows = fn()
        else:
            with tracer.span(f"section:{name}", cat="section"):
                rows = fn()
        dt = time.time() - t0
        section_times[name] = dt
        print(f"# {name} ({dt:.1f}s)", file=sys.stderr)
        if rows and isinstance(rows[0], dict):
            fig_rows.extend(rows)
            # summarize per figure/algo: worst-case delay
            import collections
            worst = collections.defaultdict(float)
            for r in rows:
                worst[(r["figure"], r["algo"])] = max(
                    worst[(r["figure"], r["algo"])], r["mean_delay"])
            for (fig, algo), d in sorted(worst.items()):
                csv_rows.append((f"{fig}_{algo}_worst_delay_slots",
                                 d * 1e6, "delay(slots)*1e6=us@1us-slot"))
        else:
            csv_rows.extend(rows)

    section("fig1", lambda: figures.fig1_precise(fast))
    section("fig2", lambda: figures.fig2_highload(fast))
    section("fig34", lambda: figures.fig34_under(fast))
    section("fig56", lambda: figures.fig56_over(fast))
    section("drift", lambda: figures.fig_drift(fast))
    section("kernels", lambda: bench_kernels.bench(fast))
    section("sim_throughput", lambda: bench_sim.bench(fast, tracer=tracer))
    section("scaling", lambda: bench_sim.bench_scaling(fast, tracer=tracer))
    section("placement",
            lambda: bench_sim.bench_placement(fast, tracer=tracer))
    section("replication",
            lambda: bench_sim.bench_replication(fast, tracer=tracer))
    section("control", lambda: bench_sim.bench_control(fast, tracer=tracer))
    section("serving", lambda: bench_serving.bench(fast, tracer=tracer))
    section("serving_scenarios", lambda: bench_serving.bench_scenarios(fast))
    section("serving_control",
            lambda: bench_serving.bench_control(fast, tracer=tracer))
    section("trace_replay", lambda: bench_serving.replay_trace(
        fast=fast, export_path="experiments/traces/replayed.jsonl"))
    section("roofline", lambda: bench_roofline.bench(fast))

    if fig_rows:
        keys = sorted({k for r in fig_rows for k in r})
        with open(outdir / "figures.csv", "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(fig_rows)
        claims = figures.headline_claims(fig_rows)
        for k, v in claims.items():
            csv_rows.append((f"claim_{k}", 1.0 if v else 0.0, str(v)))
        print(f"# wrote {outdir / 'figures.csv'} ({len(fig_rows)} rows); "
              f"claims: {claims}", file=sys.stderr)

    if args.json:
        import json
        import platform
        record = {
            # schema 2: adds "sections" (per-section wall seconds) and the
            # sim_compile_sec_* rows split out of the throughput numbers
            "schema": 2,
            "suite": "benchmarks.run",
            "full": bool(args.full),
            "python": platform.python_version(),
            "machine": platform.machine(),
            "sections": {k: round(v, 3) for k, v in section_times.items()},
            "records": [{"name": name, "value": float(val),
                         "derived": str(derived)}
                        for name, val, derived in csv_rows],
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(csv_rows)} records)",
              file=sys.stderr)

    if tracer is not None:
        tracer.save(args.trace)
        print(f"# wrote {args.trace} ({len(tracer.events())} events, "
              f"{tracer.dropped} dropped)", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
