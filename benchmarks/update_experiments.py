"""Inject the generated roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.update_experiments
"""

from __future__ import annotations

import re
from pathlib import Path

from benchmarks.bench_roofline import table


def main() -> None:
    path = Path("EXPERIMENTS.md")
    text = path.read_text()
    single = table("16_16")
    multi = table("2_16_16")
    text = re.sub(
        r"<!-- ROOFLINE_SINGLE -->(?:.|\n)*?(?=\n### Multi-pod)",
        f"<!-- ROOFLINE_SINGLE -->\n\n{single}\n",
        text)
    text = re.sub(
        r"<!-- ROOFLINE_MULTI -->(?:.|\n)*?(?=\n## §Perf)",
        f"<!-- ROOFLINE_MULTI -->\n\n{multi}\n",
        text)
    path.write_text(text)
    print("EXPERIMENTS.md roofline tables updated")


if __name__ == "__main__":
    main()
