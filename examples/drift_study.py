"""Drift study: fixed-prior vs blind-EWMA Balanced-PANDAS under the
registered time-varying scenarios (the experiment the paper motivates —
"the change of traffic over time in addition to estimation errors" — but
never runs).

    PYTHONPATH=src python examples/drift_study.py [--full | --smoke]
    PYTHONPATH=src python examples/drift_study.py --scenarios stragglers,mmpp
    PYTHONPATH=src python examples/drift_study.py --topology k4

Both arms start from the exact static rates, so the fixed prior is the best
possible frozen estimate; any blind win is pure drift-tracking.  Writes
experiments/figures/drift_study{,_k4}.csv and prints the per-scenario
table.  ``--topology k4`` runs the same study on the pod topology
(Topology(24, (6, 12)), 4-tier rates) — the K=4 robustness sweep behind
EXPERIMENTS.md §Tier-generic.  ``--smoke`` is the CI job: 2 scenarios x 2
policies at a tiny horizon, asserting only that every run stays stable
(throughput tracks arrivals).
"""

import argparse
import csv
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 2 scenarios x 2 policies, tiny horizon")
    ap.add_argument("--scenarios", default=None,
                    help="comma list (default: all registered drift scenarios)")
    ap.add_argument("--topology", default="k3", choices=("k3", "k4"),
                    help="k3: the paper's flat racks; k4: pods "
                         "(Topology(24, (6, 12)), 4-tier rates)")
    args = ap.parse_args()

    from repro.core import locality as loc, robustness as rb, simulator as sim

    def sim_cfg(horizon, warmup):
        if args.topology == "k4":
            return sim.SimConfig(topo=loc.Topology(24, (6, 12)),
                                 true_rates=loc.Rates((0.5, 0.45, 0.35,
                                                       0.25)),
                                 max_arrivals=24, horizon=horizon,
                                 warmup=warmup)
        return sim.default_config(horizon=horizon, warmup=warmup)

    if args.smoke:
        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
        scenarios = ("stragglers", "rack_congestion")  # 2 x 2 arms in CI
    elif args.full:
        cfg = rb.StudyConfig(sim=sim_cfg(30_000, 8_000), seeds=(0, 1))
        scenarios = rb.DRIFT_SCENARIOS
    else:
        cfg = rb.StudyConfig(sim=sim_cfg(8_000, 2_000), seeds=(0,))
        scenarios = rb.DRIFT_SCENARIOS
    if args.scenarios:
        scenarios = tuple(s.strip() for s in args.scenarios.split(","))

    study = rb.drift_study(cfg, scenarios=scenarios)
    print(rb.summarize_drift(study))

    if args.smoke:
        # Stability gate for CI: every arm must keep up with the offered
        # load (no divergence under any smoke scenario).
        lam = study["load"] * study["capacity"]
        for scen in scenarios:
            for arm in study["arms"]:
                thr = float(study["throughput"][scen][arm].mean())
                assert thr > 0.9 * lam, (scen, arm, thr, lam)
        print("scenario smoke OK")
        return

    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = "" if args.topology == "k3" else f"_{args.topology}"
    with open(outdir / f"drift_study{suffix}.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scenario", "arm", "seed", "mean_delay", "throughput",
                    "final_n"])
        for scen in study["scenarios"]:
            for arm in study["arms"]:
                for si, seed in enumerate(cfg.seeds):
                    w.writerow([
                        scen, arm, seed,
                        float(study["delay"][scen][arm][si]),
                        float(study["throughput"][scen][arm][si]),
                        float(study["final_n"][scen][arm][si]),
                    ])
    print(f"wrote {outdir / f'drift_study{suffix}.csv'}")


if __name__ == "__main__":
    main()
