"""Drift study: fixed-prior vs blind-EWMA Balanced-PANDAS under the
registered time-varying scenarios (the experiment the paper motivates —
"the change of traffic over time in addition to estimation errors" — but
never runs).

    PYTHONPATH=src python examples/drift_study.py [--full | --smoke]
    PYTHONPATH=src python examples/drift_study.py --scenarios stragglers,mmpp

Both arms start from the exact static rates, so the fixed prior is the best
possible frozen estimate; any blind win is pure drift-tracking.  Writes
experiments/figures/drift_study.csv and prints the per-scenario table.
``--smoke`` is the CI job: 2 scenarios x 2 policies at a tiny horizon,
asserting only that every run stays stable (throughput tracks arrivals).
"""

import argparse
import csv
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 2 scenarios x 2 policies, tiny horizon")
    ap.add_argument("--scenarios", default=None,
                    help="comma list (default: all registered drift scenarios)")
    args = ap.parse_args()

    from repro.core import locality as loc, robustness as rb, simulator as sim

    if args.smoke:
        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
        scenarios = ("stragglers", "rack_congestion")  # 2 x 2 arms in CI
    elif args.full:
        cfg = rb.StudyConfig(sim=sim.default_config(horizon=30_000,
                                                    warmup=8_000),
                             seeds=(0, 1))
        scenarios = rb.DRIFT_SCENARIOS
    else:
        cfg = rb.StudyConfig(sim=sim.default_config(horizon=8_000,
                                                    warmup=2_000),
                             seeds=(0,))
        scenarios = rb.DRIFT_SCENARIOS
    if args.scenarios:
        scenarios = tuple(s.strip() for s in args.scenarios.split(","))

    study = rb.drift_study(cfg, scenarios=scenarios)
    print(rb.summarize_drift(study))

    if args.smoke:
        # Stability gate for CI: every arm must keep up with the offered
        # load (no divergence under any smoke scenario).
        lam = study["load"] * study["capacity"]
        for scen in scenarios:
            for arm in study["arms"]:
                thr = float(study["throughput"][scen][arm].mean())
                assert thr > 0.9 * lam, (scen, arm, thr, lam)
        print("scenario smoke OK")
        return

    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    with open(outdir / "drift_study.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scenario", "arm", "seed", "mean_delay", "throughput",
                    "final_n"])
        for scen in study["scenarios"]:
            for arm in study["arms"]:
                for si, seed in enumerate(cfg.seeds):
                    w.writerow([
                        scen, arm, seed,
                        float(study["delay"][scen][arm][si]),
                        float(study["throughput"][scen][arm][si]),
                        float(study["final_n"][scen][arm][si]),
                    ])
    print(f"wrote {outdir / 'drift_study.csv'}")


if __name__ == "__main__":
    main()
