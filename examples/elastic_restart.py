"""Fault tolerance demo: train, 'lose' half the data axis, replan the mesh,
restore the atomic checkpoint with new shardings, and keep training.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile


def main() -> None:
    import numpy as np
    from repro.configs import registry, runtime
    from repro.launch import mesh as mesh_lib
    from repro.launch.elastic import plan_elastic_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get_smoke_config("mamba2_13b")
    plan = runtime.plan_for(cfg, "train_4k", "train", dp_axes=("data",))
    ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")

    mesh1 = mesh_lib.make_test_mesh((4, 2), ("data", "model"))
    print(f"phase 1: mesh {dict(mesh1.shape)} — 6 steps, checkpoint every 3")
    tr1 = Trainer(cfg, TrainerConfig(seq_len=64, global_batch=8, steps=6,
                                     ckpt_dir=ckpt, ckpt_every=3,
                                     log_every=2), mesh1, plan)
    h1 = tr1.run()
    print(f"  loss {h1[0]['loss']:.3f} -> {h1[-1]['loss']:.3f}")

    # --- simulate losing 4 of 8 chips --------------------------------------
    surviving = 4
    shape, names = plan_elastic_mesh(surviving, model_axis=2,
                                     pod_size=10**9)
    print(f"phase 2: lost 4 chips; replanned mesh {shape} axes {names}")
    mesh2 = mesh_lib.make_test_mesh(shape, names)
    tr2 = Trainer(cfg, TrainerConfig(seq_len=64, global_batch=8, steps=4,
                                     ckpt_dir=ckpt, log_every=2),
                  mesh2, plan)
    start = tr2.restore_or_init()
    print(f"  restored step {start} from the atomic checkpoint, resuming")
    h2 = tr2.run()
    print(f"  loss continues {h2[0]['loss']:.3f} -> {h2[-1]['loss']:.3f}")
    assert h2[-1]["loss"] < h1[0]["loss"]
    print("elastic restart OK")


if __name__ == "__main__":
    main()
