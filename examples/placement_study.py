"""Placement x policy study: what hierarchy-aware replica placement buys
each scheduler, at K=3 (flat racks) and K=4 (pods).

The uniform model hard-codes the one knob Hadoop operators actually turn:
where the 3 replicas of each chunk live.  This study sweeps the registered
placements (uniform / hdfs / spread / hot_aware) against one policy per
family (full-scan PANDAS, blind-EWMA PANDAS, MaxWeight) under the
scenarios that move locality and network structure (hot_shift,
rack_congestion), at the same offered load — `0.7 x` the uniform static
fluid capacity — so every delta is a placement effect.

    PYTHONPATH=src python examples/placement_study.py [--full | --smoke]
    PYTHONPATH=src python examples/placement_study.py --topology k4

Writes experiments/figures/placement_study_{k3,k4}.csv and prints the
per-scenario tables (the numbers behind EXPERIMENTS.md §Placement).
``--smoke`` is the CI job: one topology, one scenario, tiny horizon, with
a stability gate (every arm's throughput tracks the offered load) and a
bitwise gate (placement="uniform" reproduces the default sample path).
"""

import argparse
import csv
from pathlib import Path


def _topologies(which: str):
    from repro.core import locality as loc
    k3 = ("k3", loc.Topology(24, 6), loc.Rates())
    k4 = ("k4", loc.Topology(24, (6, 12)), loc.Rates((0.5, 0.45, 0.35,
                                                      0.25)))
    return {"k3": (k3,), "k4": (k4,), "both": (k3, k4)}[which]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one topology/scenario, tiny horizon")
    ap.add_argument("--topology", default="both", choices=("k3", "k4",
                                                           "both"))
    ap.add_argument("--load", type=float, default=0.7)
    args = ap.parse_args()

    import numpy as np
    from repro.core import locality as loc, robustness as rb, simulator as sim

    if args.smoke:
        # bitwise gate: the uniform placement IS the default sample path
        cfg_s = sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=400, warmup=100)
        est = sim.make_estimates(cfg_s, "network", 0.0, -1)
        base = sim.simulate("balanced_pandas", cfg_s, 3.0, est, seed=0)
        unif = sim.simulate("balanced_pandas", cfg_s, 3.0, est, seed=0,
                            placement="uniform")
        assert base == unif, (base, unif)

        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
        study = rb.placement_study(cfg, scenarios=("hot_shift",),
                                   load=args.load, capacity_samples=500)
        print(rb.summarize_placement(study))
        lam = study["load"] * study["capacity_uniform"]
        for plc in study["placements"]:
            for pol in study["policies"]:
                thr = float(study["throughput"][plc]["hot_shift"][pol].mean())
                assert thr > 0.9 * lam, (plc, pol, thr, lam)
        print("placement smoke OK")
        return

    horizon, warmup = (30_000, 8_000) if args.full else (8_000, 2_000)
    seeds = (0, 1) if args.full else (0,)
    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    for label, topo, rates in _topologies(args.topology):
        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                              max_arrivals=24, horizon=horizon,
                              warmup=warmup),
            seeds=seeds)
        study = rb.placement_study(cfg, load=args.load)
        print(f"== {label}: M={topo.num_servers}, K={topo.num_tiers} ==")
        print(rb.summarize_placement(study))
        path = outdir / f"placement_study_{label}.csv"
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["topology", "placement", "fluid_capacity",
                        "scenario", "policy", "seed", "mean_delay",
                        "throughput", "final_n"])
            for plc in study["placements"]:
                cap = study["capacity"][plc]
                for scen in study["scenarios"]:
                    for pol in study["policies"]:
                        for si, seed in enumerate(seeds):
                            w.writerow([
                                label, plc,
                                "" if cap is None else f"{cap:.4f}",
                                scen, pol, seed,
                                float(study["delay"][plc][scen][pol][si]),
                                float(study["throughput"][plc][scen][pol][si]),
                                float(study["final_n"][plc][scen][pol][si]),
                            ])
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
