"""Quickstart: the paper's result in 60 seconds, on all three layers.

1. Queueing layer — Balanced-PANDAS vs JSQ-MaxWeight under rate
   mis-estimation (the paper's core experiment, reduced horizon).
2. Kernel layer — the batched routing kernel vs its oracle.
3. Framework layer — 20 training steps of a small LM fed by the
   locality-aware data pipeline.  The pipeline synthesizes Zipf-skewed
   tokens (`token_skew`) and the optimizer warms up within the run, so
   the loss drop is a real signal, not noise: uniform tokens have no
   learnable statistics (cross-entropy is already at ln(V)), which is
   why the original uniform-token assertion flaked.

    PYTHONPATH=src python examples/quickstart.py [--fast]

``--fast`` is the CI examples-smoke setting: reduced horizons, 12
training steps, same assertions.
"""

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: reduced horizons, same assertions")
    args = ap.parse_args()

    # --- 1. the paper's robustness experiment (reduced) --------------------
    from repro.core import locality as loc, simulator as sim
    horizon, warmup = (2500, 600) if args.fast else (8000, 2000)
    cfg = sim.default_config(horizon=horizon, warmup=warmup)
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    lam = 0.95 * cap
    print(f"== queueing: M={cfg.topo.num_servers}, capacity={cap:.1f} "
          f"tasks/slot, load=0.95 ==")
    for algo in ("balanced_pandas", "pandas_po2", "jsq_maxweight"):
        row = [algo]
        for mode, eps, sign in (("network", 0.0, -1),
                                ("per_server", 0.3, -1),
                                ("per_server", 0.3, +1)):
            est = sim.make_estimates(cfg, mode, eps, sign, seed=7)
            out = sim.simulate(algo, cfg, lam, est, seed=0)
            row.append(f"{out['mean_delay']:6.2f}")
        print(f"  {row[0]:16s} delay: exact={row[1]} -30%={row[2]} "
              f"+30%={row[3]}  (slots)")
    print("  -> Balanced-PANDAS holds its delay under mis-estimated rates.")

    # --- 2. the routing kernel ----------------------------------------------
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    m, b = 1024, 128
    wl = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile([0.5, 0.45, 0.25], (m, 1)), jnp.float32)
    sr = jnp.asarray(np.arange(m) // 32, jnp.int32)
    tl = jnp.sort(jnp.asarray(rng.integers(0, m, (b, 3)), jnp.int32), 1)
    s_k, t_k, _ = ops.wwl_route(wl, er, sr, tl)
    s_r, t_r, _ = ref.wwl_route(wl, er, sr, tl)
    assert (np.asarray(s_k) == np.asarray(s_r)).all()
    print(f"== kernel: wwl_route({b} tasks x {m} servers) matches oracle; "
          f"locality mix {np.bincount(np.asarray(t_k), minlength=3)} ==")

    # --- 3. training through the locality-aware pipeline --------------------
    import dataclasses
    from repro.configs import registry, runtime
    from repro.data.pipeline import DataPipeline, PipelineConfig
    from repro.launch import mesh as mesh_lib
    from repro.train.trainer import Trainer, TrainerConfig
    cfg_m = registry.get_smoke_config("granite_moe_1b")
    mesh = mesh_lib.make_test_mesh((1, 1), ("data", "model"))
    plan = runtime.plan_for(cfg_m, "train_4k", "train", dp_axes=("data",))
    # quickstart-sized optimizer: the production plan warms up over 100
    # steps, which would leave the LR (and the loss) flat for this run
    plan = dataclasses.replace(plan, opt=dataclasses.replace(
        plan.opt, warmup_steps=5, decay_steps=200))
    steps = 12 if args.fast else 20
    pipe = DataPipeline(PipelineConfig(vocab_size=cfg_m.vocab_size,
                                       seq_len=64, global_batch=4, seed=0,
                                       token_skew=1.2))
    tr = Trainer(cfg_m, TrainerConfig(seq_len=64, global_batch=4,
                                      steps=steps, log_every=5), mesh, plan,
                 pipeline=pipe)
    hist = tr.run()
    print("== training (granite-moe smoke config, locality-aware pipeline) ==")
    for h in hist:
        print(f"  step {h['step']:3d} loss {h['loss']:.3f} "
              f"locality(l/r/rem)={tuple(round(x, 2) for x in h['data_locality'])}")
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2, \
        (hist[0]["loss"], hist[-1]["loss"])
    print("done.")


if __name__ == "__main__":
    main()
