"""Replication-lifecycle study: what adaptive replication and failure
repair buy (and cost) when the scenario actually kills servers.

PR 5 made *where* the replicas start a policy choice; the replication
lifecycle makes what happens to them afterwards one too.  This study
sweeps the registered controllers (fixed / popularity / repair) against
the failure scenarios (server_loss, rack_loss) for the two schedulers
whose robustness gap the paper cares about (Balanced-PANDAS vs JSQ-MW),
at rho in {0.7, 0.95} of the *healthy* static fluid capacity — so the
delay deltas decompose into capacity lost to dead servers and foreground
slots consumed by the re-replication storm.

    PYTHONPATH=src python examples/replication_study.py [--full | --smoke]

Writes experiments/figures/replication_study.csv and prints the
per-scenario tables (the numbers behind EXPERIMENTS.md §Replication).
``--smoke`` is the CI job: one scenario, tiny horizon, with a bitwise
gate (replication="fixed" under a static scenario reproduces the default
sample path) and a repair gate (the repair controller actually restores
the replication factor the loss window destroyed).
"""

import argparse
import csv
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one scenario, tiny horizon")
    ap.add_argument("--loads", type=float, nargs="+", default=(0.7, 0.95))
    args = ap.parse_args()

    from repro.core import locality as loc, robustness as rb, simulator as sim

    if args.smoke:
        # bitwise gate: fixed + static IS the default sample path
        cfg_s = sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=400, warmup=100)
        est = sim.make_estimates(cfg_s, "network", 0.0, -1)
        base = sim.simulate("balanced_pandas", cfg_s, 3.0, est, seed=0)
        fixed = sim.simulate("balanced_pandas", cfg_s, 3.0, est, seed=0,
                             replication="fixed")
        assert base == fixed, (base, fixed)

        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1200, warmup=300),
            seeds=(0,))
        study = rb.replication_study(cfg, scenarios=("server_loss",),
                                     policies=("balanced_pandas",),
                                     loads=(args.loads[0],))
        print(rb.summarize_replication(study))
        # repair gate: the repair controller ends the run back at factor 3,
        # the no-repair control arm does not
        rep = study["mean_replication"]["server_loss"]
        fix_r = float(rep["fixed"]["balanced_pandas"][0].mean())
        rep_r = float(rep["repair"]["balanced_pandas"][0].mean())
        assert rep_r > fix_r, (fix_r, rep_r)
        mv = study["repair_moves"]["server_loss"]
        assert float(mv["repair"]["balanced_pandas"][0].mean()) > 0
        assert float(mv["fixed"]["balanced_pandas"][0].mean()) == 0
        print("replication smoke OK")
        return

    horizon, warmup = (30_000, 8_000) if args.full else (8_000, 2_000)
    seeds = (0, 1) if args.full else (0,)
    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    topo, rates = loc.Topology(24, 6), loc.Rates()
    cfg = rb.StudyConfig(
        sim=sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                          max_arrivals=24, horizon=horizon, warmup=warmup),
        seeds=seeds)
    study = rb.replication_study(cfg, loads=tuple(args.loads))
    print(rb.summarize_replication(study))
    path = outdir / "replication_study.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scenario", "controller", "policy", "load", "seed",
                    "mean_delay", "throughput", "availability",
                    "data_loss_frac", "mean_replication", "repair_moves"])
        for scen in study["scenarios"]:
            for ctrl in study["replications"]:
                for pol in study["policies"]:
                    for li, rho in enumerate(study["loads"]):
                        for si, seed in enumerate(seeds):
                            cell = [study[m][scen][ctrl][pol]
                                    for m in ("delay", "throughput",
                                              "availability", "data_loss",
                                              "mean_replication",
                                              "repair_moves")]
                            w.writerow([scen, ctrl, pol, float(rho), seed]
                                       + [float(c[li][si]) for c in cell])
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
