"""Full reproduction of the paper's Figures 1-6 (robustness of scheduling
algorithms to processing-rate estimation errors).

    PYTHONPATH=src python examples/robustness_study.py [--full]

Writes experiments/figures/robustness_study.csv and prints the per-figure
summaries plus the headline-claims check.  --full uses paper-scale horizons
(slow on one CPU core); the default is a reduced but qualitatively faithful
sweep.
"""

import argparse
import csv
import sys
from pathlib import Path

# `benchmarks` lives at the repo root, which is not on sys.path when this
# file is run as a script (sys.path[0] is examples/).
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import figures
    rows = []
    for name, fn in (("fig1", figures.fig1_precise),
                     ("fig2", figures.fig2_highload),
                     ("fig3/4", figures.fig34_under),
                     ("fig5/6", figures.fig56_over)):
        out = fn(fast)
        rows.extend(out)
        print(f"-- {name}: {len(out)} points")
        algos = sorted({r["algo"] for r in out})
        for algo in algos:
            sub = [r for r in out if r["algo"] == algo]
            worst = max(r["mean_delay"] for r in sub)
            sens = max((abs(r.get("sensitivity", 0.0)) for r in sub),
                       default=0.0)
            print(f"   {algo:16s} worst delay {worst:8.2f} slots"
                  f"   max sensitivity {sens:6.1%}")
    claims = figures.headline_claims(rows)
    print("headline claims:", claims)

    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    keys = sorted({k for r in rows for k in r})
    with open(outdir / "robustness_study.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {outdir / 'robustness_study.csv'}")


if __name__ == "__main__":
    main()
