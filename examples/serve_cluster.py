"""End-to-end driver (the paper's kind is scheduling/serving): serve a small
model with batched requests through the continuous-batching engine, comparing
schedulers under a straggling replica.

    PYTHONPATH=src python examples/serve_cluster.py [--requests 24]

A 4-replica / 2-pod fleet serves real greedy decoding; replica 1 is 5x slow
and the routers only learn it through observed service times (blind
estimation).  Balanced-PANDAS keeps latency flat; FIFO (Hadoop default)
pays the full straggler cost.
"""

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=6)
    args = ap.parse_args()

    import jax
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(args.requests)]

    print(f"serving {args.requests} requests x {args.new_tokens} new tokens "
          f"on 4 replicas (2 pods), replica 1 is 5x slow\n")
    results = {}
    for scheduler in ("balanced_pandas", "pandas_po2", "jsq_maxweight",
                      "fifo"):
        ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                            slots_per_replica=2, max_len=64,
                            prefill_buckets=(16,), scheduler=scheduler)
        eng = ServingEngine(cfg, prm, ecfg, slow_replicas={1: 5.0})
        reqs = [Request(rid=i, prompt=p, max_new_tokens=args.new_tokens,
                        prefix_id=i % 6) for i, p in enumerate(prompts)]
        t0 = time.monotonic()
        out = eng.run_until_drained(reqs, max_steps=1500)
        wall = time.monotonic() - t0
        lat = np.mean([r.finish_time - r.arrival for r in out])
        spread = np.bincount([r.replica for r in out], minlength=4)
        results[scheduler] = eng.steps
        print(f"{scheduler:16s} engine_steps={eng.steps:4d} "
              f"wall={wall:5.1f}s mean_latency={lat * 1e3:7.0f}ms "
              f"replica spread={spread.tolist()} "
              f"tier mix={eng.assign_tiers}")
    print("\n(sample output tokens, request 0:",
          out[0].generated[:8], ")")


if __name__ == "__main__":
    main()
