"""SLO control study: what each control-plane lever buys at the tail.

The paper compares schedulers under a FIXED offered load; a production
cluster also gets to refuse and reshape that load.  This study runs the
control-plane arms {none, admission, autoscale, both} for the
mean-optimal scheduler (``balanced_pandas``) and its SLO-conditioned
variant (``slo_pandas``) at rho in {0.90, 0.95, 0.99} of the static
fluid capacity, telemetry on (EXPERIMENTS.md §SLO control):

  * **admission** — a token bucket refilling at 93% of capacity: at
    rho = 0.99 it sheds the few percent of arrivals that push the system
    past the stability knee, collapsing the p99;
  * **autoscale** — the proactive headroom planner: a no-op at the knee
    (everything stays on) but the descale floor shows up at moderate rho;
  * **slo_pandas** — scheduling-only control: drains the longest queues
    while the live p99 estimate breaches the SLO, shedding nothing.

Means use the MEASURED admitted rate as the Little's-law denominator, so
they stay comparable across arms.

    PYTHONPATH=src python examples/slo_control_study.py [--full | --smoke]

Writes experiments/figures/slo_control.csv and prints the per-load
table.  ``--smoke`` is the CI job: a tiny horizon with a bitwise gate
(``control=None`` compiles NOTHING — every metric of every registered
policy is bitwise identical to the pre-control simulator) and a
shed-rate sanity gate (the admission arm sheds at rho = 0.99).
"""

import argparse
import csv
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny horizon, bitwise + shed gates")
    ap.add_argument("--loads", type=float, nargs="+",
                    default=(0.90, 0.95, 0.99))
    args = ap.parse_args()

    from repro.core import locality as loc, robustness as rb, simulator as sim

    if args.smoke:
        # Bitwise gate: control=None must compile to the exact
        # pre-control program for every registered policy — the scan
        # carry gains no slots, the RNG consumes nothing.  (slo_pandas
        # without telemetry is included: signals are absent, so it IS
        # balanced_pandas by construction.)
        from repro.core.policy import available_policies
        cfg_s = sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=400, warmup=100)
        est = sim.make_estimates(cfg_s, "network", 0.0, -1)
        for pol in available_policies():
            off = sim.simulate(pol, cfg_s, 3.0, est, seed=0)
            on = sim.simulate(pol, cfg_s, 3.0, est, seed=0, control=None)
            for k, v in off.items():
                assert np.array_equal(np.asarray(v), np.asarray(on[k])), \
                    (pol, k)

        # Shed gate: one overloaded arm with the study's token bucket
        # must shed and stay conserved (offered == admitted + shed).
        cap = loc.capacity_hot_rack(cfg_s.topo, cfg_s.true_rates, cfg_s.p_hot)
        res = sim.simulate(
            "balanced_pandas", cfg_s, 1.2 * cap, est, seed=0,
            control=rb.control_arm_spec("admission", cap))
        shed = float(res["ctl_shed_rate"])
        assert 0.0 < shed < 1.0, shed
        assert int(res["ctl_offered"]) == \
            int(res["ctl_admitted"]) + int(res["ctl_shed"])

        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
        study = rb.control_study(cfg, loads=(0.99,))
        print(rb.summarize_control(study))
        adm = study["shed_rate"]["balanced_pandas"]["admission"]
        assert float(np.mean(adm)) > 0.0, "admission arm shed nothing"
        print("slo-control smoke OK")
        return

    horizon, warmup = (40_000, 10_000) if args.full else (12_000, 3_000)
    seeds = (0, 1) if args.full else (0,)
    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    cfg = rb.StudyConfig(
        sim=sim.default_config(horizon=horizon, warmup=warmup),
        seeds=seeds)
    study = rb.control_study(cfg, loads=tuple(args.loads))
    print(rb.summarize_control(study))
    path = outdir / "slo_control.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["policy", "arm", "load", "seed", "mean_delay",
                    "delay_p50", "delay_p95", "delay_p99", "shed_rate",
                    "throughput"])
        for pol in study["policies"]:
            for arm in study["arms"]:
                for li, rho in enumerate(study["loads"]):
                    for si, seed in enumerate(seeds):
                        w.writerow(
                            [pol, arm, float(rho), seed]
                            + [float(study[m][pol][arm][li][si])
                               for m in ("mean", "p50", "p95", "p99",
                                         "shed_rate", "throughput")])
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
