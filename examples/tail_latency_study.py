"""Tail-latency study: p50/p95/p99 sojourn vs the Little's-law mean in
heavy traffic.

The paper's comparison is stated in mean delay, but a production SLO is a
percentile — and mean ordering between schedulers need not match tail
ordering.  The in-scan telemetry recorder (`repro.telemetry`) measures
per-task sojourns inside the `lax.scan` via an FCFS-coupled arrival-slot
ring and a fixed-bin histogram, so this study sweeps
rho in {0.90, 0.95, 0.99} of the static fluid capacity for
Balanced-PANDAS vs JSQ-MaxWeight vs FIFO at K=3 and reports where the
p99 winner diverges from the mean winner (EXPERIMENTS.md §Tail latency).

    PYTHONPATH=src python examples/tail_latency_study.py [--full | --smoke]

Writes experiments/figures/tail_latency.csv and prints the per-load
table.  ``--smoke`` is the CI job: a tiny horizon with a bitwise gate
(the telemetry recorder is pure observation — every metric the plain run
produces is bitwise identical with telemetry on) and a percentile sanity
gate (p99 >= p95 >= p50 > 0 on a stable arm).
"""

import argparse
import csv
from pathlib import Path

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny horizon, bitwise + sanity gates")
    ap.add_argument("--loads", type=float, nargs="+",
                    default=(0.90, 0.95, 0.99))
    args = ap.parse_args()

    from repro.core import locality as loc, robustness as rb, simulator as sim

    if args.smoke:
        # Bitwise gate: telemetry is pure observation (consumes no RNG,
        # mutates no policy state) — the plain run's every metric must be
        # bitwise identical with the recorder compiled in, for every
        # registered policy except the ones that OPT IN to reading the
        # live signals (`uses_signals`, e.g. slo_pandas — the documented
        # exception, pinned separately in tests/test_control.py).
        from repro.core.policy import available_policies, get_policy_cls
        cfg_s = sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=400, warmup=100)
        est = sim.make_estimates(cfg_s, "network", 0.0, -1)
        for pol in available_policies():
            if getattr(get_policy_cls(pol), "uses_signals", False):
                continue
            off = sim.simulate(pol, cfg_s, 3.0, est, seed=0)
            on = sim.simulate(pol, cfg_s, 3.0, est, seed=0, telemetry=True)
            for k, v in off.items():
                assert np.array_equal(np.asarray(v), np.asarray(on[k])), \
                    (pol, k)
            assert "delay_p99" in on and "delay_p99" not in off

        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
        study = rb.tail_study(cfg, loads=(0.9,))
        print(rb.summarize_tail(study))
        # Percentile sanity on the delay-optimal arm: finite, ordered,
        # positive, and the p50 brackets the mean's order of magnitude.
        p50, p95, p99 = (float(study[m]["balanced_pandas"][0].mean())
                         for m in ("p50", "p95", "p99"))
        assert 0.0 < p50 <= p95 <= p99 < float("inf"), (p50, p95, p99)
        assert float(study["unmatched"]["balanced_pandas"][0].mean()) == 0.0
        print("tail-latency smoke OK")
        return

    horizon, warmup = (40_000, 10_000) if args.full else (12_000, 3_000)
    seeds = (0, 1) if args.full else (0,)
    outdir = Path("experiments/figures")
    outdir.mkdir(parents=True, exist_ok=True)
    cfg = rb.StudyConfig(
        sim=sim.default_config(horizon=horizon, warmup=warmup),
        seeds=seeds)
    study = rb.tail_study(cfg, loads=tuple(args.loads))
    print(rb.summarize_tail(study))
    path = outdir / "tail_latency.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["policy", "load", "seed", "mean_delay", "delay_p50",
                    "delay_p95", "delay_p99"])
        for pol in study["policies"]:
            for li, rho in enumerate(study["loads"]):
                for si, seed in enumerate(seeds):
                    w.writerow([pol, float(rho), seed]
                               + [float(study[m][pol][li][si])
                                  for m in ("mean", "p50", "p95", "p99")])
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
