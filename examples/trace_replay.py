"""Trace replay: one recorded cluster trace drives every layer of the
stack — the discrete-time simulator, the live serving engine, and the
bench_serving harness — from a single compiled Scenario.

    PYTHONPATH=src python examples/trace_replay.py [--smoke | --full]
    PYTHONPATH=src python examples/trace_replay.py --trace flash_day
    PYTHONPATH=src python examples/trace_replay.py --trace path/to/my.jsonl

The pipeline:

  1. load a bundled (or user-supplied JSONL/CSV) trace and compile it to a
     piecewise `Scenario` (`repro.workloads.trace`: unit-mean arrival
     normalization + change-point merging);
  2. simulator leg — the paper's drift experiment on recorded traffic:
     fixed-prior vs blind-EWMA Balanced-PANDAS replaying the trace
     (`robustness.drift_study`), results to
     experiments/figures/trace_replay.csv;
  3. serving leg — the same Scenario times request submission and replica
     slowdowns on the live continuous-batching engine
     (`bench_serving.replay_trace`), and the run is re-recorded through
     the engine's trace export hook;
  4. the re-recorded trace is loaded back and compiled again, closing the
     record -> replay -> re-record loop deterministically.

``--smoke`` is the CI gate: tiny horizons, plus assertions that every arm
stays stable and that the export hook round-trips bit-for-bit.
"""

import argparse
import csv
import sys
from pathlib import Path

# the serving leg reuses the bench harness, which lives outside src/
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="diurnal_week",
                    help="bundled trace name, or a path to a .jsonl/.csv "
                         "trace file")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (slow on 1 CPU core)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny horizons + determinism assertions")
    ap.add_argument("--max-segments", type=int, default=64)
    args = ap.parse_args()

    from repro import workloads as wl
    from repro.core import locality as loc, robustness as rb, simulator as sim

    # -- 1. one Scenario for every layer ----------------------------------
    if args.trace in wl.bundled_traces():
        trace = wl.load_bundled(args.trace)
    else:
        trace = wl.load_trace(args.trace)
    scn = wl.trace_to_scenario(trace, max_segments=args.max_segments)
    print(f"trace {trace.name!r}: {trace.num_intervals} intervals "
          f"({trace.duration / 3600.0:.1f} h) -> {len(scn.segments)} "
          f"segments, mean lam_mult {scn.mean_lam_mult:.4f}")

    # -- 2. simulator: fixed prior vs blind EWMA on recorded traffic ------
    if args.smoke:
        cfg = rb.StudyConfig(
            sim=sim.SimConfig(topo=loc.Topology(12, 4),
                              true_rates=loc.Rates(), max_arrivals=16,
                              horizon=1500, warmup=400),
            seeds=(0,))
    elif args.full:
        cfg = rb.StudyConfig(sim=sim.default_config(horizon=30_000,
                                                    warmup=8_000),
                             seeds=(0, 1))
    else:
        cfg = rb.StudyConfig(sim=sim.default_config(horizon=8_000,
                                                    warmup=2_000),
                             seeds=(0,))
    study = rb.drift_study(cfg, scenarios={"static": "static",
                                           scn.name: scn})
    print(rb.summarize_drift(study))

    # -- 3. serving engine + bench harness on the same Scenario -----------
    outdir = Path("experiments")
    export = outdir / "traces" / "replay_rerecorded.jsonl"
    from benchmarks import bench_serving
    rows = bench_serving.replay_trace(scn, fast=not args.full,
                                      export_path=str(export))
    for name, steps, derived in rows:
        print(f"{name}: drained in {steps:.0f} engine steps ({derived})")

    # -- 4. the re-recorded run replays deterministically ------------------
    rerec = wl.load_trace(export)
    rescn = wl.trace_to_scenario(rerec, max_segments=args.max_segments)
    again = wl.load_trace(export)
    assert again == rerec, "trace export must round-trip bit-for-bit"
    assert wl.trace_to_scenario(again, max_segments=args.max_segments) \
        == rescn, "recompiling the same trace must be deterministic"
    print(f"re-recorded {rerec.num_intervals} intervals "
          f"({int(rerec.arrivals.sum())} arrivals) -> "
          f"{len(rescn.segments)} segments; replay round-trip OK")

    if args.smoke:
        lam = study["load"] * study["capacity"]
        for scen in study["scenarios"]:
            for arm in study["arms"]:
                thr = float(study["throughput"][scen][arm].mean())
                assert thr > 0.9 * lam, (scen, arm, thr, lam)
        print("trace-replay smoke OK")
        return

    figdir = outdir / "figures"
    figdir.mkdir(parents=True, exist_ok=True)
    csv_path = figdir / f"trace_replay_{trace.name}.csv"
    with open(csv_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scenario", "arm", "seed", "mean_delay", "throughput",
                    "final_n"])
        for scen in study["scenarios"]:
            for arm in study["arms"]:
                for si, seed in enumerate(cfg.seeds):
                    w.writerow([
                        scen, arm, seed,
                        float(study["delay"][scen][arm][si]),
                        float(study["throughput"][scen][arm][si]),
                        float(study["final_n"][scen][arm][si]),
                    ])
    print(f"wrote {csv_path}")


if __name__ == "__main__":
    main()
