"""Train a ~100M-parameter dense LM for a few hundred steps through the full
stack (locality-aware pipeline -> FSDP/TP sharded train step -> atomic
checkpoints).

    PYTHONPATH=src python examples/train_100m.py --steps 300      # full run
    PYTHONPATH=src python examples/train_100m.py --steps 20       # smoke

On this 1-core CPU container a full 300-step run takes hours; the default is
sized to finish in minutes while exercising every component.  On a TPU fleet
the same script runs the production mesh via --mesh.
"""

import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="experiments/train_100m_ckpt")
    args = ap.parse_args()

    from repro.configs import runtime
    from repro.launch import mesh as mesh_lib
    from repro.models.config import (LayerSpec, ModelConfig, param_count,
                                     uniform_stages)
    from repro.train.trainer import Trainer, TrainerConfig

    # ~100M params: 12L, d=768, 12 heads, ff=2048, 32k vocab.
    cfg = ModelConfig(
        name="lm-100m", family="dense", d_model=768, num_heads=12,
        num_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=32_000,
        stages=uniform_stages(12, LayerSpec(kind="attn")),
        tie_embeddings=True, dtype="float32")
    print(f"model: {param_count(cfg) / 1e6:.1f}M parameters")

    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = mesh_lib.make_test_mesh(shape, ("data", "model"))
    plan = runtime.plan_for(cfg, "train_4k", "train",
                            dp_axes=mesh_lib.dp_axes(mesh))
    tr = Trainer(cfg, TrainerConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 3, 10), log_every=5), mesh, plan)
    hist = tr.run()
    for h in hist:
        print(f"step {h['step']:4d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} {h['wall_s']:.1f}s/step")
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f}); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
