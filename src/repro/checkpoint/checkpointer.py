"""Sharded checkpointing: npz-shard files + JSON manifest, atomic commits,
restore with resharding, background writes, retention policy.

Layout:
    <dir>/step_000123/
        manifest.json     tree structure, shapes, dtypes, step, extra metadata
        arrays.npz        flattened keypath -> array
    <dir>/LATEST          text file naming the last committed step dir

Commits are atomic (write to step_xxx.tmp, fsync, rename), so a crash
mid-write never corrupts the latest checkpoint — the restart path of the
fault-tolerance story depends on this.  `restore(..., shardings=...)`
device_puts every leaf straight to its (possibly different) target sharding,
which is how an elastic re-mesh resumes from a checkpoint written on a
different topology.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3,
                 background: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        if background:
            self._q = queue.Queue(maxsize=2)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any,
             metadata: Optional[Dict] = None) -> None:
        """Host-blocking (or queued, if background=True) checkpoint save."""
        flat = _flatten(jax.device_get(tree))
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in flat.items()},
            "metadata": metadata or {},
        }
        if self._q is not None:
            self._q.put((step, flat, manifest))
        else:
            self._write(step, flat, manifest)

    def wait(self) -> None:
        if self._q is not None:
            self._q.join()

    def _drain(self) -> None:
        while True:
            step, flat, manifest = self._q.get()
            try:
                self._write(step, flat, manifest)
            finally:
                self._q.task_done()

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               manifest: Dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        os.sync()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(final.name)
        latest_tmp.rename(self.dir / "LATEST")
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_????????"))
        for old in steps[:-self.keep_last]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        return int(latest.read_text().strip().split("_")[-1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `template`; if `shardings` is given
        (pytree of NamedSharding matching template), every leaf is placed
        directly onto its target sharding (works across mesh changes)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for path, leaf in leaves_t:
            key = "/".join(_path_str(p) for p in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            out.append(arr)
        tree = jax.tree.unflatten(jax.tree.structure(template), out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    def manifest(self, step: Optional[int] = None) -> Dict:
        step = self.latest_step() if step is None else step
        d = self.dir / f"step_{step:08d}"
        return json.loads((d / "manifest.json").read_text())
