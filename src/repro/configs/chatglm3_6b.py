"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE applied to half the head dims ("2d" RoPE), GQA.
[arXiv:2406.12793; hf THUDM/chatglm3-6b]
"""

from repro.models.config import LayerSpec, ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    stages=uniform_stages(28, LayerSpec(kind="attn")),
    rope_theta=10_000.0,
    rope_fraction=0.5,   # chatglm rotary on half of head_dim
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.0625, layers=4 / 28, vocab=256)
