"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32 => MHA) d_ff=13440
vocab=92416 — qwen1.5 architecture (qkv bias, 64k context rope).
[hf:Qwen/CodeQwen1.5-7B]
"""

from repro.models.config import LayerSpec, ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    stages=uniform_stages(32, LayerSpec(kind="attn")),
    rope_theta=1_000_000.0,
    attn_bias=True,
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.0625, layers=4 / 32, vocab=256)
