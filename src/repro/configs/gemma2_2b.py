"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — alternating local(4096):global attention, logit softcaps,
GeGLU, tied embeddings, post-norms.  [arXiv:2408.00118]
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

_LOCAL = LayerSpec(kind="attn", window=4096)
_GLOBAL = LayerSpec(kind="attn", window=0)

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    stages=(Stage((_LOCAL, _GLOBAL), 13),),
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    embed_scale=True,
    post_norm=True,
    norm="rmsnorm",
    act="geglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.125, layers=2 / 13, vocab=512)
