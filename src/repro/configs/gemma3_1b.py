"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local(512-window):global pattern, qk-norm, tied
embeddings, GeGLU.  [hf:google/gemma-3-1b-pt]
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

_LOCAL = LayerSpec(kind="attn", window=512, rope_theta=10_000.0)
_GLOBAL = LayerSpec(kind="attn", window=0, rope_theta=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    # 26 layers: 4 full (5 local + 1 global) cycles + 2 trailing local.
    stages=(Stage((_LOCAL,) * 5 + (_GLOBAL,), 4), Stage((_LOCAL,), 2)),
    qk_norm=True,
    tie_embeddings=True,
    embed_scale=True,
    post_norm=True,
    norm="rmsnorm",
    act="geglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.25, layers=1 / 4, vocab=512)
