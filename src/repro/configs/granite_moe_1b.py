"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
(per-expert) vocab=49155, MoE 32 experts top-8, tied embeddings.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

32 experts % 16 model shards == 0 -> true expert parallelism (EP).
"""

from repro.models.config import LayerSpec, MoEConfig, ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    stages=uniform_stages(24, LayerSpec(kind="attn", moe=True)),
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=32, top_k=8),
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.25, layers=4 / 24, vocab=256)
