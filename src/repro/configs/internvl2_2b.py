"""internvl2-2b [vlm]: InternViT frontend (STUB: precomputed patch
embeddings) + InternLM2-1.8B backbone: 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92553.  [arXiv:2404.16821]
"""

from repro.models.config import LayerSpec, ModelConfig, uniform_stages

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    stages=uniform_stages(24, LayerSpec(kind="attn")),
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="swiglu",
    frontend="vision",
    num_frontend_tokens=256,  # 448px / 14 patches, 0.25x pixel shuffle
)


def smoke_config():
    return CONFIG.scaled(width=0.125, layers=4 / 24, vocab=256)
