"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2 — Mamba:attention 7:1
interleave (one attention layer per 8), MoE every other layer.
[arXiv:2403.19887]

Layer block (8 sub-layers, scanned 9x): Mamba at positions 0,2,4(attn),6 ...
attention at position 4; MoE MLP at odd positions, dense MLP at even.
Jamba's Mamba-1 layers are modeled with Mamba-2 SSD blocks of matching
state size (TPU-native dual form; see DESIGN.md §Arch-applicability).
"""

from repro.models.config import (LayerSpec, MoEConfig, ModelConfig,
                                 SSMConfig, Stage)

def _sub(i: int) -> LayerSpec:
    kind = "attn" if i == 4 else "mamba"
    return LayerSpec(kind=kind, moe=(i % 2 == 1))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    stages=(Stage(tuple(_sub(i) for i in range(8)), 9),),
    rope_theta=10_000.0,
    rope_fraction=0.0,   # jamba attention uses no positional encoding
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4),
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=1 / 64, layers=1 / 9, vocab=256)
