"""mamba2-1.3b [ssm]: 48L d_model=2048, attention-free, ssm_state=128,
vocab=50280 — SSD (state-space duality).  [arXiv:2405.21060]

d_inner = 2*d_model = 4096, head_dim 64 -> 64 SSD heads/layer.
"""

from repro.models.config import LayerSpec, ModelConfig, SSMConfig, uniform_stages

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    stages=uniform_stages(48, LayerSpec(kind="mamba")),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4),
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=0.125, layers=4 / 48, vocab=256)
