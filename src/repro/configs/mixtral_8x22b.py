"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (4096, per the
assignment sheet).  [arXiv:2401.04088]

Stage grouping (7 blocks of 8 scanned layers) doubles as the sqrt-remat
granularity for the 141B training memory budget.
"""

from repro.models.config import LayerSpec, MoEConfig, ModelConfig, Stage

_L = LayerSpec(kind="attn", window=4096, moe=True)

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    stages=(Stage((_L,) * 8, 7),),
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    norm="rmsnorm",
    act="swiglu",
)


def smoke_config():
    return CONFIG.scaled(width=1 / 48, layers=2 / 7, vocab=256)
