"""Assigned-architecture registry: exact public configs, selectable via
``--arch <id>``.  Sources per the assignment sheet (hf / arXiv tiers).

Each <id>.py module defines ``CONFIG`` (exact) and ``smoke_config()``
(reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = (
    "chatglm3_6b",
    "gemma3_1b",
    "codeqwen15_7b",
    "gemma2_2b",
    "internvl2_2b",
    "jamba15_large",
    "whisper_medium",
    "mixtral_8x22b",
    "granite_moe_1b",
    "mamba2_13b",
)

# Canonical external names <-> module ids.
ALIASES = {
    "chatglm3-6b": "chatglm3_6b",
    "gemma3-1b": "gemma3_1b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "gemma2-2b": "gemma2_2b",
    "internvl2-2b": "internvl2_2b",
    "jamba-1.5-large-398b": "jamba15_large",
    "whisper-medium": "whisper_medium",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "mamba2-1.3b": "mamba2_13b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_resolve(arch)}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def _resolve(arch: str) -> str:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return arch
