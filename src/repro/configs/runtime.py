"""Per-(arch x shape) runtime plans: parallelism policy + memory knobs.

The defaults implement the memory policy of DESIGN.md §6:
  - training always FSDPs parameters over the data axis (ZeRO-3) — per-layer
    all-gathers amortize inside the stage scans;
  - the >=100B archs (jamba, mixtral) keep optimizer moments (and jamba's
    grad-accumulator) in bf16 and use deep microbatching;
  - serving FSDPs weights only where TP-only would not fit 16 GB/chip;
  - long_500k turns on KV-cache sequence sharding (SP) — the batch=1 cell
    leaves the DP axes idle, the half-million-token cache does not.
"""

from __future__ import annotations

from repro.launch.steps import RuntimePlan
from repro.models.config import ModelConfig, param_count
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import ShardingPolicy

_BIG = ("jamba-1.5-large-398b", "mixtral-8x22b")


def plan_for(cfg: ModelConfig, shape_name: str, kind: str,
             dp_axes=("data",)) -> RuntimePlan:
    big = cfg.name in _BIG
    moment_dtype = "bfloat16" if big else "float32"
    accum_dtype = "bfloat16" if cfg.name == _BIG[0] else "float32"

    if kind == "train":
        micro = {"jamba-1.5-large-398b": 8, "mixtral-8x22b": 8}.get(
            cfg.name, 4)
        return RuntimePlan(
            policy=ShardingPolicy(fsdp=True, dp_axes=tuple(dp_axes)),
            microbatches=micro,
            accum_dtype=accum_dtype,
            opt=AdamWConfig(moment_dtype=moment_dtype,
                            update_dtype=("bfloat16" if big
                                          else "float32")),
            remat=True,
            pin_gathers=big)  # jamba/mixtral: keep FSDP gathers in-loop

    # serving: weights 2D-sharded only when TP-only exceeds ~12 GB/chip
    tp_bytes = 2 * param_count(cfg) / 16
    fsdp = tp_bytes > 12e9
    seq_shard = shape_name == "long_500k"
    return RuntimePlan(
        policy=ShardingPolicy(fsdp=fsdp, seq_shard_cache=seq_shard,
                              dp_axes=tuple(dp_axes)),
        microbatches=1,
        opt=AdamWConfig(moment_dtype=moment_dtype),
        remat=(kind == "prefill"))
