"""Assigned input-shape sets and ShapeDtypeStruct input specs.

Every LM architecture is paired with four shapes:
  train_4k     seq 4,096   x global_batch 256   -> train_step
  prefill_32k  seq 32,768  x global_batch 32    -> prefill_step
  decode_32k   cache 32,768 x global_batch 128  -> serve_step (1 new token)
  long_500k    cache 524,288 x global_batch 1   -> serve_step; requires a
               sub-quadratic/bounded-cache family (SSM / hybrid / windowed)

`applicable()` encodes the mandated skips (full-attention archs skip
long_500k; enc-dec/VLM notes in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class RunShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", "train", 4_096, 256),
    "prefill_32k": RunShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": RunShape("decode_32k", "decode", 32_768, 128),
    "long_500k": RunShape("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: RunShape) -> Optional[str]:
    """None if runnable; otherwise the (documented) skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 500k decode needs an unbounded "
                "KV cache and quadratic prefill; skipped per assignment "
                "(see DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: RunShape,
                dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train: {tokens, labels [, frontend|frames]}
    prefill: {tokens [, frontend|frames]}
    decode: {tokens (B,1), lengths (B,)} (+ caches, built separately).
    Modality frontends are stubs: precomputed embeddings arrive as inputs.
    """
    b = shape.global_batch
    t = shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)

    if shape.kind in ("train", "prefill"):
        n_text = t
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend == "vision":
            n_text = t - cfg.num_frontend_tokens
            specs["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.num_frontend_tokens, cfg.d_model), f32)
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.num_audio_frames, cfg.d_model), f32)
        specs["tokens"] = tok((b, n_text))
        if shape.kind == "train":
            specs["labels"] = tok((b, n_text))
        return specs

    # decode: one new token against a seq_len cache
    specs = {"tokens": tok((b, 1)),
             "lengths": jax.ShapeDtypeStruct((b,), jnp.int32)}
    return specs
