"""whisper-medium [audio]: encoder-decoder, 24L+24L d_model=1024 16H
(kv=16) d_ff=4096 vocab=51865 — conv audio frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings), LayerNorm,
GELU MLP, learned positions, decoder cross-attention.
[arXiv:2212.04356]

Note: whisper's real decoder context is 448; the assigned decode_32k
shape lowers a 32k-position decoder as specified (positional table sized
accordingly) — flagged in DESIGN.md.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    enc_stages=(Stage((LayerSpec(kind="attn", causal=False),), 24),),
    stages=(Stage((LayerSpec(kind="attn", cross=True),), 24),),
    rope_fraction=0.0,
    learned_pos=33024,       # covers the assigned decode_32k cache length
    norm="layernorm",
    act="gelu",
    frontend="audio",
    num_audio_frames=1500,
)


def smoke_config():
    return CONFIG.scaled(width=0.125, layers=2 / 24, vocab=256)
