"""Control plane: load generation, admission control, autoscaling, and
the registry behind the ``control=`` seam (see `repro.control.plane`)."""

from repro.control.plane import (
    ControlConfig,
    ControlLike,
    ControlPlane,
    Controller,
    AdmissionController,
    AutoscaleController,
    LoadGenController,
    available_controllers,
    controller_descriptions,
    get_controller_cls,
    make_controller,
    register_controller,
    resolve_control,
    scale_priority,
)
from repro.control.simproj import CONTROL_METRIC_KEYS, CtlState, SimControl
from repro.control.host import ClosedLoopClients, HostControl

__all__ = [
    "ControlConfig",
    "ControlLike",
    "ControlPlane",
    "Controller",
    "AdmissionController",
    "AutoscaleController",
    "LoadGenController",
    "available_controllers",
    "controller_descriptions",
    "get_controller_cls",
    "make_controller",
    "register_controller",
    "resolve_control",
    "scale_priority",
    "CONTROL_METRIC_KEYS",
    "CtlState",
    "SimControl",
    "ClosedLoopClients",
    "HostControl",
]
