"""Built-in controllers: open/closed-loop load generation, token-bucket
and queue-threshold admission, and headroom/hysteresis autoscaling.

All sim-side hooks are deterministic functions of the carry (zero RNG),
so engaging a controller never consumes extra PRNG draws — the common-
random-number coupling across policy arms survives control (the same
`fold_in(base, t)` keys drive arrivals/routing with or without a plane).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.control.plane import (
    AdmissionController,
    AutoscaleController,
    LoadGenController,
    register_controller,
)


@register_controller
@dataclasses.dataclass(frozen=True)
class OpenLoopLoadGen(LoadGenController):
    """Open-loop load generator: replay the scenario's rate track
    untouched (rate-driven arrivals, no completion feedback).

    This is the explicit spelling of the default traffic model — useful
    as the identity arm of a study and as the seam where a custom track
    would plug in.  `extra_mult` rescales the whole track (a study-level
    rho knob that leaves the scenario object untouched)."""

    name = "open_loop"
    extra_mult: float = 1.0

    def __post_init__(self):
        if self.extra_mult < 0.0:
            raise ValueError("extra_mult must be >= 0")

    def sim_offered(self, in_flight, lam_total, knobs):
        return lam_total * knobs.lam_mult * self.extra_mult, None


@register_controller
@dataclasses.dataclass(frozen=True)
class ClosedLoopLoadGen(LoadGenController):
    """Closed-loop load generator: N think-time users, arrivals gated on
    completions (in-system never exceeds the user count).

    The load-tester model: each of ``users`` clients holds at most one
    task in the system and thinks for a mean of ``think_time`` slots
    between completion and next submission.  Per slot, the thinking
    population is ``max(users_t - in_flight, 0)`` and the offered rate is
    ``thinking / think_time``; admitted arrivals are additionally capped
    at the thinking count so ``in_flight <= users_t`` holds exactly.  The
    scenario's ``users_mult`` track scales ``users_t`` over time (the
    closed-loop analogue of ``lam_mult`` — the configured ``lam_total``
    is intentionally ignored, and `simulate`'s Little's-law denominator
    switches to the measured admitted rate)."""

    name = "closed_loop"
    users: int = 64
    think_time: float = 8.0

    def __post_init__(self):
        if self.users < 1:
            raise ValueError("users must be >= 1")
        if self.think_time <= 0.0:
            raise ValueError("think_time must be > 0")

    def _users_t(self, knobs):
        mult = getattr(knobs, "users_mult", None)
        if mult is None:
            return jnp.asarray(self.users, jnp.int32)
        return jnp.maximum(jnp.round(self.users * mult), 1.0).astype(jnp.int32)

    def sim_offered(self, in_flight, lam_total, knobs):
        users_t = self._users_t(knobs)
        thinking = jnp.maximum(users_t - in_flight, 0)
        lam = thinking.astype(jnp.float32) / jnp.float32(self.think_time)
        return lam, thinking

    def host_clients(self, seed: int = 0):
        from repro.control.host import ClosedLoopClients
        return ClosedLoopClients(users=self.users, think_time=self.think_time,
                                 seed=seed)


@register_controller
@dataclasses.dataclass(frozen=True)
class TokenBucketAdmission(AdmissionController):
    """Token-bucket admission: refill ``rate`` tokens/slot up to
    ``burst``; arrivals beyond the bucket are shed (or deferred).

    The classic rate limiter: long-run admitted throughput is capped at
    ``rate`` while bursts up to ``burst`` pass unhindered.  With
    ``defer=True`` rejected arrivals join a bounded backlog
    (``backlog_cap``) and re-enter on later slots as spare fixed-shape
    arrival lanes free up; past the cap they are shed.  The bucket starts
    full."""

    name = "token_bucket"
    rate: float = 1.0
    burst: float = 16.0
    defer: bool = False
    backlog_cap: float = 256.0

    def __post_init__(self):
        if self.rate < 0.0:
            raise ValueError("rate must be >= 0")
        if self.burst < 1.0:
            raise ValueError("burst must be >= 1")
        if self.backlog_cap < 0.0:
            raise ValueError("backlog_cap must be >= 0")

    def sim_init(self):
        return float(self.burst), 0.0

    def sim_admit(self, tokens, backlog, n_arr, n_sys, spare):
        tokens = jnp.minimum(tokens + self.rate, self.burst)
        n_admit = jnp.minimum(n_arr, jnp.floor(tokens).astype(jnp.int32))
        tokens = tokens - n_admit.astype(jnp.float32)
        rejected = n_arr - n_admit
        if not self.defers:
            return tokens, backlog, n_admit, jnp.int32(0), rejected
        # Deferred arrivals re-enter through spare lanes, still paying
        # tokens; whatever exceeds the backlog cap is shed.
        n_release = jnp.minimum(
            jnp.minimum(jnp.floor(backlog).astype(jnp.int32), spare),
            jnp.floor(tokens).astype(jnp.int32))
        tokens = tokens - n_release.astype(jnp.float32)
        backlog = backlog - n_release + rejected
        overflow = jnp.maximum(backlog - self.backlog_cap, 0.0)
        backlog = backlog - overflow
        n_shed = jnp.round(overflow).astype(jnp.int32)
        return tokens, backlog, n_admit, n_release, n_shed

    @property
    def defers(self) -> bool:
        return self.defer

    def host_init(self) -> dict:
        return {"tokens": float(self.burst), "last_step": None}

    def host_admit(self, state: dict, step: int, n_sys: int) -> bool:
        last = state["last_step"]
        if last is None:
            last = step
        state["tokens"] = min(state["tokens"] + self.rate * (step - last),
                              self.burst)
        state["last_step"] = step
        if state["tokens"] >= 1.0:
            state["tokens"] -= 1.0
            return True
        return False


@register_controller
@dataclasses.dataclass(frozen=True)
class QueueThresholdAdmission(AdmissionController):
    """Queue-threshold admission: shed arrivals whenever in-system work
    already meets ``threshold`` (a hard cap on total backlog).

    The simplest overload guard — admitted arrivals per slot are
    ``clip(threshold - n_sys, 0, n_arr)``, so the post-admission system
    size never exceeds ``threshold`` by more than the service lag."""

    name = "queue_threshold"
    threshold: int = 128

    def __post_init__(self):
        if self.threshold < 1:
            raise ValueError("threshold must be >= 1")

    def sim_admit(self, tokens, backlog, n_arr, n_sys, spare):
        room = jnp.maximum(jnp.int32(self.threshold) - n_sys, 0)
        n_admit = jnp.minimum(n_arr, room)
        return tokens, backlog, n_admit, jnp.int32(0), n_arr - n_admit

    def host_admit(self, state: dict, step: int, n_sys: int) -> bool:
        return n_sys < self.threshold


@register_controller
@dataclasses.dataclass(frozen=True)
class HeadroomAutoscale(AutoscaleController):
    """Autoscaler: keep ``headroom`` x the offered load in active local
    service capacity (sim: planned from the rate track; host: reactive
    p95 thresholds with hysteresis + cooldown via `launch.elastic`).

    The sim projection is proactive — inside the scan the offered-rate
    track is known, so the active count each slot is
    ``clip(ceil(headroom * lam_eff / rate0), min_servers, M)``: enough
    tier-0 capacity to absorb the load times a safety factor.  The host
    projection cannot see the future, so it reacts to the engine's
    measured sojourn p95: ``up_after`` consecutive breaches of
    ``p95_high`` grow the fleet by ``step_frac``, ``down_after``
    consecutive readings under ``p95_low`` shrink it, with ``cooldown``
    steps between actions (see `launch.elastic.Autoscaler`).  Descaled
    servers drain: routing stops sending them work (scores masked to
    +inf) but queued tasks keep serving — distinct from the PR 6 `alive`
    track, where dead servers stop serving AND lose replicas."""

    name = "autoscale"
    headroom: float = 1.35
    min_servers: Optional[int] = None
    p95_high: float = 64.0
    p95_low: float = 16.0
    up_after: int = 2
    down_after: int = 8
    cooldown: int = 16
    step_frac: float = 0.25

    def __post_init__(self):
        if self.headroom <= 0.0:
            raise ValueError("headroom must be > 0")
        if self.min_servers is not None and self.min_servers < 1:
            raise ValueError("min_servers must be >= 1")
        if not (0.0 < self.step_frac <= 1.0):
            raise ValueError("step_frac must be in (0, 1]")

    def _min_servers(self, num_servers: int, floor: int) -> int:
        lo = self.min_servers if self.min_servers is not None else floor
        return max(1, min(lo, num_servers))

    def sim_target(self, lam_eff, num_servers: int, rate0: float):
        need = jnp.ceil(self.headroom * lam_eff / jnp.float32(rate0))
        lo = self._min_servers(num_servers, 1)
        return jnp.clip(need.astype(jnp.int32), lo, num_servers)

    def host_autoscaler(self, num_servers: int, min_servers: int):
        from repro.launch.elastic import Autoscaler
        return Autoscaler(
            min_servers=self._min_servers(num_servers, min_servers),
            max_servers=num_servers,
            p95_high=self.p95_high, p95_low=self.p95_low,
            up_after=self.up_after, down_after=self.down_after,
            cooldown=self.cooldown, step_frac=self.step_frac)
