"""Host-clock projection of a control plane: the serving engine /
bench_serving counterpart of `repro.control.simproj`.

Same controllers, different substrate: admission runs per submitted
request on the engine step clock, autoscaling is the reactive
`launch.elastic.Autoscaler` fed by the engine's measured sojourn p95,
and closed-loop load generation is a deterministic client pool
(`ClosedLoopClients`) that gates submissions on completions.
"""

from __future__ import annotations

import heapq
from typing import List, Optional

import numpy as np

from repro.control.plane import ControlPlane


class ClosedLoopClients:
    """N think-time users on the host step clock.

    Each user holds at most one request in the system.  After their
    request completes they think for Exp(think_time) steps (seeded numpy
    RNG — deterministic per seed) before submitting the next one.
    Initial submissions are staggered uniformly over one think time so
    the run does not open with an N-wide burst.

    Drive it with `poll(step, completed_total)`: report the engine's
    cumulative completion count and get back how many fresh requests to
    submit at this step.
    """

    def __init__(self, users: int, think_time: float, seed: int = 0):
        if users < 1:
            raise ValueError("users must be >= 1")
        if think_time <= 0.0:
            raise ValueError("think_time must be > 0")
        self.users = int(users)
        self.think_time = float(think_time)
        self._rng = np.random.default_rng(seed)
        stagger = self._rng.uniform(0.0, think_time, size=self.users)
        self._ready: List[float] = sorted(stagger)
        heapq.heapify(self._ready)
        self._last_completed = 0
        self.in_flight = 0

    def poll(self, step: int, completed_total: int) -> int:
        """Number of new requests to submit at ``step``."""
        newly_done = completed_total - self._last_completed
        self._last_completed = completed_total
        for _ in range(max(newly_done, 0)):
            # A completion frees its user into a think period.
            self.in_flight -= 1
            think = self._rng.exponential(self.think_time)
            heapq.heappush(self._ready, step + think)
        n_new = 0
        while self._ready and self._ready[0] <= step:
            heapq.heappop(self._ready)
            n_new += 1
        self.in_flight += n_new
        return n_new

    @property
    def done(self) -> bool:
        """True when every user is idle with nothing queued to submit —
        only meaningful if the caller stops polling."""
        return self.in_flight == 0 and not self._ready


class HostControl:
    """Resolved host-side control plane for one engine run."""

    def __init__(self, plane: ControlPlane, spec, rate0: float,
                 seed: int = 0):
        self.plane = plane
        self.clients: Optional[ClosedLoopClients] = None
        if plane.loadgen is not None:
            self.clients = plane.loadgen.host_clients(seed=seed)
        self._adm = plane.admission
        self._adm_state = self._adm.host_init() if self._adm else None
        self.autoscaler = None
        if plane.autoscale is not None:
            num_servers = int(spec.num_servers)
            min_servers = max(int(getattr(spec, "num_racks", 1)), 1)
            self.autoscaler = plane.autoscale.host_autoscaler(
                num_servers, min_servers)
        self.shed = 0
        self.admitted = 0

    def admit(self, step: int, n_sys: int) -> bool:
        """Admission decision for one request arriving at ``step`` with
        ``n_sys`` requests currently in the system."""
        if self._adm is None:
            self.admitted += 1
            return True
        ok = self._adm.host_admit(self._adm_state, step, n_sys)
        if ok:
            self.admitted += 1
        else:
            self.shed += 1
        return ok

    def observe(self, step: int, p95: float) -> Optional[int]:
        """Feed the autoscaler one sojourn-p95 reading; returns the new
        active-server target when it changes, else None."""
        if self.autoscaler is None:
            return None
        return self.autoscaler.observe(step, p95)

    def metrics(self) -> dict:
        offered = self.admitted + self.shed
        out = {"ctl_admitted": self.admitted, "ctl_shed": self.shed,
               "ctl_shed_rate": self.shed / max(offered, 1)}
        if self.autoscaler is not None:
            out["ctl_active"] = self.autoscaler.current
        return out
