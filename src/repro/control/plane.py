"""Control-plane contract + registry: load generation, admission control,
and autoscaling as first-class, composable controllers.

PR 7 made the tail *measurable* (in-scan p50/p95/p99); this subsystem makes
it *actionable*.  A `Controller` is one closed-loop actuator with a declared
``kind``:

  * ``loadgen``   -- shapes the offered traffic itself: ``open_loop``
                     replays the scenario's rate track untouched,
                     ``closed_loop`` gates arrivals on completions
                     (N think-time users, the load-tester model);
  * ``admission`` -- sheds or defers arrivals *before* routing
                     (``token_bucket``, ``queue_threshold``);
  * ``autoscale`` -- grows/shrinks the serving fleet mid-run on the
                     Topology seam (``autoscale``).

Like placement (PR 5) and replication (PR 6), every controller projects
onto BOTH substrates: a fixed-shape `lax.scan` projection
(`repro.control.simproj`) threaded through the simulator carry, and a
host-clock projection (`repro.control.host`) for the serving engine and
`bench_serving`.  Controllers compose: ``control=`` on
`simulate`/`sweep`/`EngineConfig` accepts one controller or a sequence
(at most one per kind), which is exactly how the SLO study builds its
{no control, admission only, autoscale only, both} arms.

With ``control=None`` (the default) NOTHING is compiled — the simulator
step is the exact pre-control program and every sample path stays bitwise
(pinned in tests/test_control.py).  Registration mirrors the PR 1/5/6
idiom: `@register_controller`, `ControlConfig`, `make_controller`,
`controller_descriptions` (surfaced by ``benchmarks/run.py --help``).
"""

from __future__ import annotations

import abc
import dataclasses
import importlib
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Type, Union

import numpy as np

KINDS = ("loadgen", "admission", "autoscale")


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Name + per-controller constructor options, e.g.
    ``ControlConfig("token_bucket", {"rate": 3.0, "burst": 24})`` — the
    control analogue of `PolicyConfig`."""

    name: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


class Controller(abc.ABC):
    """One control-plane actuator (see module docstring for the kinds).

    Subclasses declare ``name`` (registry key) and ``kind`` and implement
    the hook surface of their kind — the sim projection consumes the
    ``sim_*`` hooks inside the `lax.scan`, the host projection the
    ``host_*`` hooks on the engine/bench clock.  Controllers are
    stateless objects over immutable options; all mutable state lives in
    the scan carry (`simproj.CtlState`) or the host-side objects they
    build.
    """

    name: str = ""
    kind: str = ""


class LoadGenController(Controller):
    """Base for ``kind == "loadgen"``: shapes the offered arrival rate."""

    kind = "loadgen"

    @abc.abstractmethod
    def sim_offered(self, in_flight, lam_total, knobs):
        """Traced offered rate for this slot -> (lam, cap).

        ``in_flight`` is the controller-tracked tasks in system (i32),
        ``lam_total`` the configured base rate, ``knobs`` the scenario's
        `SlotKnobs`.  ``cap`` bounds the admitted arrivals this slot
        (i32) or is None for no bound (open loop)."""

    def host_clients(self, seed: int = 0):
        """Host projection: a closed-loop client pool driving request
        submission (see `repro.control.host.ClosedLoopClients`), or None
        for open-loop (the bench's existing `arrival_steps` track)."""
        return None


class AdmissionController(Controller):
    """Base for ``kind == "admission"``: shed/defer arrivals pre-routing."""

    kind = "admission"

    #: whether this controller can re-admit deferred arrivals later
    defers: bool = False

    def sim_init(self) -> Tuple[float, float]:
        """Initial (tokens, backlog) carry values."""
        return 0.0, 0.0

    @abc.abstractmethod
    def sim_admit(self, tokens, backlog, n_arr, n_sys, spare):
        """One slot of admission (all args/results traced scalars).

        n_arr  -- candidate arrivals this slot (i32)
        n_sys  -- tasks in system before this slot (i32)
        spare  -- free arrival lanes available for re-admitting deferred
                  work (i32; the fixed-shape batch minus n_arr)
        Returns (tokens, backlog, n_admit, n_release, n_shed): admit the
        first ``n_admit`` of the candidates, re-activate ``n_release``
        deferred arrivals, shed ``n_shed`` outright."""

    @abc.abstractmethod
    def host_admit(self, state: dict, step: int, n_sys: int) -> bool:
        """Host projection: admit one request arriving at ``step`` with
        ``n_sys`` requests currently in the system.  ``state`` is the
        mutable per-run dict initialized by `host_init`."""

    def host_init(self) -> dict:
        return {"tokens": 0.0, "last_step": None}


class AutoscaleController(Controller):
    """Base for ``kind == "autoscale"``: grow/shrink the active fleet."""

    kind = "autoscale"

    @abc.abstractmethod
    def sim_target(self, lam_eff, num_servers: int, rate0: float):
        """Traced active-server count for a slot offering ``lam_eff``
        tasks/slot, given the fleet size and the tier-0 (local) service
        rate — the planned/proactive projection (the scenario's rate
        track is known ahead of time inside the scan)."""

    @abc.abstractmethod
    def host_autoscaler(self, num_servers: int, min_servers: int):
        """Host projection: a reactive `launch.elastic.Autoscaler` driven
        by the engine's measured sojourn p95 (hysteresis + cooldown)."""


_OneController = Union[str, ControlConfig, Controller, Mapping[str, Any]]
ControlLike = Union[None, _OneController, Sequence[_OneController]]


# ---------------------------------------------------------------------------
# Registry (mirrors core/policy.py / replication/lifecycle.py)
# ---------------------------------------------------------------------------

_CONTROLLERS: Dict[str, Type[Controller]] = {}
_BUILTIN_MODULES = ("repro.control.controllers",)
_builtins_loaded = False


def _load_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    _builtins_loaded = True


def register_controller(cls: Type[Controller]) -> Type[Controller]:
    """Class decorator: add a Controller to the registry under
    ``cls.name``."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"controller class {cls.__name__} has no `name`")
    if getattr(cls, "kind", "") not in KINDS:
        raise ValueError(f"controller {name!r} has kind "
                         f"{getattr(cls, 'kind', '')!r}; must be one of "
                         f"{KINDS}")
    if name in _CONTROLLERS:
        raise ValueError(f"duplicate controller registration: {name!r}")
    _CONTROLLERS[name] = cls
    return cls


def available_controllers() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_CONTROLLERS))


def controller_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered controller,
    from the first sentence of each class docstring — the self-describing
    registry surface behind ``benchmarks/run.py --help``."""
    from repro.utils.doc import first_doc_line
    _load_builtins()
    return {n: f"[{c.kind}] {first_doc_line(c)}"
            for n, c in sorted(_CONTROLLERS.items())}


def get_controller_cls(name: str) -> Type[Controller]:
    _load_builtins()
    try:
        return _CONTROLLERS[name]
    except KeyError:
        raise ValueError(f"unknown controller {name!r}; "
                         f"registered: {available_controllers()}") from None


def make_controller(spec: _OneController) -> Controller:
    """Resolve a name / ControlConfig / mapping / instance to a
    Controller (mappings are ``{"name": ..., "options": {...}}``, the
    JSON-friendly spelling)."""
    if isinstance(spec, Controller):
        return spec
    if isinstance(spec, str):
        spec = ControlConfig(spec)
    elif isinstance(spec, Mapping):
        spec = ControlConfig(**spec)
    if not isinstance(spec, ControlConfig):
        raise TypeError(f"cannot resolve a controller from {spec!r}")
    return get_controller_cls(spec.name)(**dict(spec.options))


# ---------------------------------------------------------------------------
# The composed plane
# ---------------------------------------------------------------------------


class ControlPlane:
    """A stack of controllers, at most one per kind, resolved from the
    ``control=`` seam.  Holds no mutable state — it is the compile-time
    description both projections are built from."""

    def __init__(self, controllers: Sequence[Controller]):
        if not controllers:
            raise ValueError("a control plane needs at least one controller")
        self.by_kind: Dict[str, Controller] = {}
        for c in controllers:
            if c.kind in self.by_kind:
                raise ValueError(
                    f"duplicate {c.kind!r} controllers in one control "
                    f"plane: {self.by_kind[c.kind].name!r} and {c.name!r}")
            self.by_kind[c.kind] = c

    @property
    def loadgen(self) -> Optional[LoadGenController]:
        return self.by_kind.get("loadgen")

    @property
    def admission(self) -> Optional[AdmissionController]:
        return self.by_kind.get("admission")

    @property
    def autoscale(self) -> Optional[AutoscaleController]:
        return self.by_kind.get("autoscale")

    def describe(self) -> str:
        return "+".join(f"{c.name}" for _, c in sorted(self.by_kind.items()))

    def build_sim(self, topo, cfg, sched, rate0: float):
        """Compiled `lax.scan` projection (`repro.control.simproj`)."""
        from repro.control.simproj import SimControl
        return SimControl(self, topo, cfg, sched, rate0)

    def build_host(self, spec, rate0: float, seed: int = 0):
        """Host-clock projection (`repro.control.host`) for the serving
        engine / bench_serving."""
        from repro.control.host import HostControl
        return HostControl(self, spec, rate0, seed=seed)


def resolve_control(spec: ControlLike) -> Optional[ControlPlane]:
    """The ``control=`` seam: None -> None (NOTHING is compiled — the
    bitwise pre-control paths); a name / config / instance -> a one-
    controller plane; a sequence -> a composed plane (one per kind)."""
    if spec is None:
        return None
    if isinstance(spec, ControlPlane):
        return spec
    if isinstance(spec, (str, ControlConfig, Controller, Mapping)):
        return ControlPlane([make_controller(spec)])
    if isinstance(spec, Sequence):
        return ControlPlane([make_controller(s) for s in spec])
    raise TypeError(f"control must be None, a controller name/config/"
                    f"instance, or a sequence of them; got {spec!r}")


def scale_priority(topo) -> np.ndarray:
    """(M,) descale rank per server: rank r is the r-th server kept when
    the fleet shrinks.  Servers are ranked round-robin across racks
    (position-within-rack major, rack minor), so any prefix of the order
    spans the racks as evenly as possible — the locality-aware descale
    order (a shrunken fleet keeps replica-holding racks reachable rather
    than evacuating whole racks first)."""
    rack_of = np.asarray(topo.rack_of)
    pos = np.zeros_like(rack_of)
    seen: Dict[int, int] = {}
    for i, r in enumerate(rack_of):
        pos[i] = seen.get(int(r), 0)
        seen[int(r)] = pos[i] + 1
    order = np.lexsort((rack_of, pos))  # sort by (pos, rack)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    return rank.astype(np.int32)
