"""`lax.scan` projection of a control plane: fixed-shape, zero-RNG
per-slot hooks threaded through the simulator carry.

The step seam (see `core/simulator._build_run`) is three hooks around
the existing arrival -> route -> serve slot:

  1. `offered_lam` (pre-arrival): the loadgen shapes the offered rate —
     closed-loop derives it from the thinking population, open-loop
     replays the scenario track — and optionally caps admitted count;
  2. `pre` (post-arrival, pre-routing): the loadgen cap and the
     admission controller trim the fixed-shape `active` lane mask
     (shedding/deferring BEFORE routing, so a shed task never touches a
     queue or the telemetry sojourn pairing), and the autoscaler turns
     the slot's offered rate into a boolean (M,) active-server mask via
     the locality-aware `scale_priority` rank;
  3. `post`-accounting happens inside `pre` (window-gated counters), so
     the conservation invariant ``offered == admitted + shed + backlog``
     holds slot-by-slot by construction (property-tested in
     tests/test_control.py).

Deferred arrivals re-enter through spare fixed-shape lanes on later
slots; their task types are re-sampled at release time (the fixed-shape
reading of "the deferred user retries with a fresh request").  All hooks
are deterministic in the carry — no PRNG draws — so common random
numbers across arms survive engagement.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.control.plane import ControlPlane, scale_priority


class CtlState(NamedTuple):
    """Control-plane slice of the scan carry (all in-window counters
    except the bucket/backlog levels, which are live)."""

    offered: jnp.ndarray     # i32: candidate arrivals (post loadgen cap)
    admitted: jnp.ndarray    # i32: entered the system (incl. releases)
    shed: jnp.ndarray        # i32: rejected outright
    tokens: jnp.ndarray      # f32: token-bucket level
    backlog: jnp.ndarray     # f32: deferred arrivals awaiting release
    active_sum: jnp.ndarray  # f32: sum of active-server counts
    active_n: jnp.ndarray    # f32: slots accumulated into active_sum
    active_min: jnp.ndarray  # f32: min active-server count seen


class SimControl:
    """Compiled control plane for one (topology, config, schedule)."""

    def __init__(self, plane: ControlPlane, topo, cfg, sched, rate0: float):
        self.plane = plane
        self.max_arrivals = int(cfg.max_arrivals)
        self.num_servers = int(topo.num_servers)
        self.rate0 = float(rate0)
        self.has_mask = plane.autoscale is not None
        # Rank r server is the r-th kept on shrink (round-robin across
        # racks, so a shrunken fleet still spans every rack).
        self._rank = jnp.asarray(scale_priority(topo), jnp.int32) \
            if self.has_mask else None

    # -- carry ------------------------------------------------------------

    def init(self) -> CtlState:
        adm = self.plane.admission
        tokens, backlog = adm.sim_init() if adm is not None else (0.0, 0.0)
        m = float(self.num_servers)
        return CtlState(
            offered=jnp.int32(0), admitted=jnp.int32(0), shed=jnp.int32(0),
            tokens=jnp.float32(tokens), backlog=jnp.float32(backlog),
            active_sum=jnp.float32(0.0), active_n=jnp.float32(0.0),
            active_min=jnp.float32(m))

    # -- per-slot hooks ---------------------------------------------------

    def offered_lam(self, n_prev, lam_total, knobs):
        """Slot's offered rate (traced f32) + optional admitted-count cap
        (traced i32 or None).  Stateless: gates on the POLICY's in-system
        count, so closed-loop stays exact even for policies that drop
        internally (FIFO's cap)."""
        lg = self.plane.loadgen
        if lg is None:
            return lam_total * knobs.lam_mult, None
        return lg.sim_offered(n_prev, lam_total, knobs)

    def pre(self, st: CtlState, active, cap, n_prev, lam_eff, in_window
            ) -> Tuple[CtlState, jnp.ndarray, Optional[jnp.ndarray]]:
        """Trim the lane mask (loadgen cap + admission) and compute the
        slot's active-server mask (autoscale).  Returns
        (state', active', server_mask-or-None)."""
        lanes = jnp.arange(self.max_arrivals)
        n_arr = jnp.sum(active).astype(jnp.int32)
        if cap is not None:
            # Closed loop: a thinking user who hasn't finished thinking
            # cannot submit — excess Poisson draws are never offered.
            n_arr = jnp.minimum(n_arr, cap.astype(jnp.int32))
        adm = self.plane.admission
        tokens, backlog = st.tokens, st.backlog
        if adm is not None:
            spare = jnp.int32(self.max_arrivals) - n_arr
            tokens, backlog, n_admit, n_release, n_shed = adm.sim_admit(
                tokens, backlog, n_arr, n_prev, spare)
        else:
            n_admit = n_arr
            n_release = jnp.int32(0)
            n_shed = jnp.int32(0)
        n_new = jnp.minimum(n_admit + n_release, self.max_arrivals)
        active = lanes < n_new
        in_w = in_window.astype(jnp.int32)
        st = st._replace(
            offered=st.offered + n_arr * in_w,
            admitted=st.admitted + n_new * in_w,
            shed=st.shed + n_shed * in_w,
            tokens=tokens, backlog=backlog)
        mask = None
        if self.has_mask:
            count = self.plane.autoscale.sim_target(
                lam_eff, self.num_servers, self.rate0)
            mask = self._rank < count
            in_f = in_window.astype(jnp.float32)
            cnt_f = count.astype(jnp.float32)
            st = st._replace(
                active_sum=st.active_sum + cnt_f * in_f,
                active_n=st.active_n + in_f,
                active_min=jnp.where(in_window,
                                     jnp.minimum(st.active_min, cnt_f),
                                     st.active_min))
        return st, active, mask

    # -- outputs ----------------------------------------------------------

    def measured_rate(self, st: CtlState, n_meas):
        """Admitted tasks per in-window slot — the Little's-law
        denominator once control reshapes the arrival stream (the
        configured lam_total no longer equals what entered the system)."""
        return st.admitted.astype(jnp.float32) / jnp.maximum(n_meas, 1.0)

    def metrics(self, st: CtlState):
        out = {
            "ctl_offered": st.offered.astype(jnp.float32),
            "ctl_admitted": st.admitted.astype(jnp.float32),
            "ctl_shed": st.shed.astype(jnp.float32),
            "ctl_shed_rate": st.shed.astype(jnp.float32)
            / jnp.maximum(st.offered.astype(jnp.float32), 1.0),
        }
        adm = self.plane.admission
        if adm is not None and adm.defers:
            out["ctl_backlog"] = st.backlog
        if self.has_mask:
            out["ctl_active_mean"] = st.active_sum \
                / jnp.maximum(st.active_n, 1.0)
            out["ctl_active_min"] = st.active_min
        return out


CONTROL_METRIC_KEYS = ("ctl_offered", "ctl_admitted", "ctl_shed",
                       "ctl_shed_rate", "ctl_backlog", "ctl_active_mean",
                       "ctl_active_min")
