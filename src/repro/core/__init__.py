"""The paper's primary contribution: locality-aware load-balancing algorithms
(Balanced-PANDAS, JSQ-MaxWeight, Priority, FIFO, power-of-d Balanced-PANDAS),
their discrete-time queueing simulator, the robustness-under-rate-estimation-
error study, and the production-facing cluster routers used by the serving
engine / data pipeline — all behind the unified SchedulerPolicy API of
`core/policy.py`: one registry for the JAX slot-policies and the host-side
routers.
"""

from repro.core.locality import (  # noqa: F401
    LOCAL, RACK_LOCAL, REMOTE, Rates, Topology, Traffic, capacity_hot_rack,
    pair_tiers, server_tiers, tier_masks,
)
from repro.core.policy import (  # noqa: F401
    Claim, Decision, PolicyConfig, Router, SlotPolicy,
    available_policies, available_routers, get_policy_cls, get_router_cls,
    make_policy, make_router, register_policy, register_router,
)
from repro.core.simulator import (  # noqa: F401
    SimConfig, default_config, make_estimates, simulate, sweep,
)
from repro.core.cluster import (  # noqa: F401
    BalancedPandasRouter, ClusterSpec, FifoRouter, JsqMaxWeightRouter,
    PandasPoDRouter, tier_of,
)
from repro.core.estimator import (  # noqa: F401
    EwmaRateEstimator, ewma_time_update, ewma_update,
)
from repro.core.robustness import (  # noqa: F401
    DRIFT_SCENARIOS, StudyConfig, default_study, drift_study, run_study,
    sensitivity, summarize, summarize_drift,
)
