"""The paper's primary contribution: locality-aware load-balancing algorithms
(Balanced-PANDAS, JSQ-MaxWeight, Priority, FIFO), their discrete-time
queueing simulator, the robustness-under-rate-estimation-error study, and the
production-facing cluster router used by the serving engine / data pipeline.
"""

from repro.core.locality import (  # noqa: F401
    LOCAL, RACK_LOCAL, REMOTE, Rates, Topology, Traffic, capacity_hot_rack,
)
from repro.core.simulator import (  # noqa: F401
    ALGORITHMS, SimConfig, default_config, make_estimates, simulate, sweep,
)
from repro.core.cluster import (  # noqa: F401
    ClusterSpec, BalancedPandasRouter, JsqMaxWeightRouter, FifoRouter, ROUTERS,
)
from repro.core.estimator import EwmaRateEstimator, ewma_update  # noqa: F401
from repro.core.robustness import (  # noqa: F401
    StudyConfig, default_study, run_study, sensitivity, summarize,
)
