"""Balanced-PANDAS (paper §3.2; Xie et al. 2016, Yekkehkhany et al. 2018).

Queueing structure: K queues per server — one per locality tier, stored as
one (M, K) matrix ``q`` (column k holds tasks at tier k *to that server*;
the classic 3-tier instance is columns (local, rack-local, remote)).
Workload

    W_m = sum_k  q[m, k] / rates[m, k].

Routing: a type-``L`` arrival joins the queue of

    argmin_m  W_m / rate(m, L)

where ``rate(m, L)`` is the estimated rate at server m's tier for the
task, with random tie-breaking.  Scheduling: an idle server serves its
fastest-tier nonempty queue first (local > rack-local > ... > remote; the
class of the queue a task sits in is, by construction, its true service
class — PANDAS dynamics here are exact, unlike the (m,n)-proxy needed for
JSQ-MW).

Robustness experiment: the *scheduler* computes W and the routing rates with
estimated rates ``est`` of shape (M, K) — per-server per-tier, supporting
per-tier and per-server error models — while the *service* dynamics use the
true rates.

Scale-invariance note (beyond-paper analytical finding, see EXPERIMENTS.md):
if every estimate is scaled by one constant c, W scales by 1/c and the
routing score W/rate by 1/c^2, so the argmin — and hence the entire sample
path — is unchanged.  The same holds for MaxWeight (scores scale by c).  The
paper's robustness experiment is therefore only meaningful for errors that
are NOT a global rescaling (per-tier-subset or per-server errors).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import claiming, locality as loc
from repro.core.policy import SlotPolicy, register_policy


class PandasState(NamedTuple):
    q: jnp.ndarray        # (M, K) int32 waiting tasks per (server, tier)
    serving: jnp.ndarray  # (M,) int32 class in service (0 idle, 1..K)


def init_state(topo: loc.Topology) -> PandasState:
    m, k = topo.num_servers, topo.num_tiers
    return PandasState(jnp.zeros((m, k), jnp.int32),
                       jnp.zeros((m,), jnp.int32))


def num_in_system(s: PandasState) -> jnp.ndarray:
    return jnp.sum(s.q) + jnp.sum(s.serving > 0)


def telemetry_gauges(s: PandasState):
    """Per-tier queued counts + busy servers for the telemetry series —
    shared by every policy on the PANDAS (M, K) queue structure."""
    k = s.q.shape[1]
    out = {f"queued_tier{t}": s.q[:, t].sum().astype(jnp.float32)
           for t in range(k)}
    out["in_service"] = jnp.sum(s.serving > 0).astype(jnp.float32)
    return out


def workload(s: PandasState, est: jnp.ndarray) -> jnp.ndarray:
    """(M,) estimated weighted workload W_m (waiting + in-service share).

    est: (M, K) per-server estimated tier rates.  The in-service task
    contributes its expected residual 1/rate in the class it is being
    served at, matching the paper's W definition over queue contents (queues
    here exclude the in-service task, so we add it back).  The tier sum is
    accumulated left-associatively so the K=3 instance is bit-identical to
    the pre-refactor (q_local, q_rack, q_remote) formulation.
    """
    k = s.q.shape[1]
    w = s.q[:, 0] / est[:, 0]
    for t in range(1, k):
        w = w + s.q[:, t] / est[:, t]
    resid_rate = jnp.take_along_axis(
        est, jnp.clip(s.serving - 1, 0, k - 1)[:, None], axis=1)[:, 0]
    return w + jnp.where(s.serving > 0, 1.0 / resid_rate, 0.0)


def push_task(s: PandasState, m_star: jnp.ndarray, tier_m: jnp.ndarray,
              active: jnp.ndarray) -> PandasState:
    """Enqueue one (possibly inactive) arrival at server `m_star`, whose
    tier for this task is ``tier_m[m_star]``."""
    inc = active.astype(jnp.int32)
    return PandasState(
        q=s.q.at[m_star, tier_m[m_star]].add(inc),
        serving=s.serving,
    )


def route_one(s: PandasState, key: jax.Array, task: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray,
              ancestors: jnp.ndarray, server_mask=None) -> PandasState:
    """Route a single arrival against the live workloads (estimated rates).

    Tie-break: among minimal scores, prefer the faster tier (then random).
    The paper says "ties are broken randomly", but read literally that
    routes most arrivals REMOTE whenever workloads tie at 0 (any idle
    fleet), which no real scheduler does and which inverts the Fig. 1
    ordering at sub-critical load — see EXPERIMENTS.md §Reproduction.  The
    infinitesimal rate preference only discriminates exact ties.

    ``server_mask`` ((M,) bool, autoscaling seam) is a Python-level
    option: None compiles the exact classic program; a mask sends
    descaled servers' scores to +inf so they take no new work (their
    queues keep draining through the service phase).
    """
    tier_m = loc.server_tiers(task, ancestors)  # (M,) tier of each server
    est_rate = jnp.take_along_axis(est, tier_m[:, None], axis=1)[:, 0]
    score = workload(s, est) / est_rate - est_rate * 1e-6
    if server_mask is not None:
        score = jnp.where(server_mask, score, jnp.inf)
    m_star = loc.random_argmin(key, score)
    return push_task(s, m_star, tier_m, active)


def service_completions(s: PandasState, k_serve: jax.Array,
                        true_rates: jnp.ndarray):
    """Bernoulli service completions at the *true* rates.

    `true_rates` is the shared ``(K,)`` vector or a per-server ``(M, K)``
    matrix (scenario fault injection).  Returns (done (M,) bool,
    completions int32) — the per-server mask is what the blind policy's
    estimator consumes.
    """
    tmk = loc.per_server_rates(true_rates, s.serving.shape[0])
    done = jax.random.bernoulli(k_serve, claiming.tier_rates(s.serving, tmk))
    return done, jnp.sum(done).astype(jnp.int32)


def schedule_idle(s: PandasState, done: jnp.ndarray) -> PandasState:
    """Idle servers (post-completion) pick their fastest nonempty tier
    queue (local > rack-local > ... > remote, conflict-free)."""
    k = s.q.shape[1]
    serving = jnp.where(done, 0, s.serving)
    nonempty = s.q > 0                              # (M, K)
    first = jnp.argmax(nonempty, axis=1)            # fastest nonempty tier
    has_task = jnp.any(nonempty, axis=1)
    take = (serving == 0) & has_task
    dec = take[:, None] & (jnp.arange(k)[None, :] == first[:, None])
    return PandasState(
        q=s.q - dec.astype(jnp.int32),
        serving=jnp.where(take, first + 1, serving).astype(jnp.int32),
    )


def serve_and_schedule(s: PandasState, k_serve: jax.Array,
                       true_rates: jnp.ndarray):
    """Service completions (true rates) + idle-server scheduling.

    Shared by every PANDAS-queue-structure policy (full-scan, power-of-d
    and blind routing only differ in the arrival phase / rate source).
    Returns (state, completions).
    """
    done, completions = service_completions(s, k_serve, true_rates)
    return schedule_idle(s, done), completions


def slot_step(s: PandasState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              ancestors: jnp.ndarray, server_mask=None):
    """One time slot: arrivals -> service completions -> scheduling.

    Returns (state, completions_this_slot).  ``server_mask=None`` (the
    default) compiles the exact classic step; see `route_one`.
    """
    anc = loc.as_ancestors(ancestors)
    k_route, k_serve = jax.random.split(key)
    n_arr = types.shape[0]

    # Sequential routing of the slot's arrivals (workloads update in-slot).
    def body(i, st):
        return route_one(st, jax.random.fold_in(k_route, i), types[i],
                         active[i], est, anc, server_mask=server_mask)
    s = jax.lax.fori_loop(0, n_arr, body, s)

    return serve_and_schedule(s, k_serve, true_rates)


@register_policy
class BalancedPandasPolicy(SlotPolicy):
    """Balanced-PANDAS: weighted-workload routing over estimated per-tier
    rates — the paper's headline throughput- and heavy-traffic-optimal
    policy.  Arrivals go to the server minimizing workload W / rate over
    the K locality tiers; robust to rate mis-estimation (paper §4) and
    the reference point every other arm is compared to.
    """

    name = "balanced_pandas"
    supports_server_mask = True

    def init_state(self, topo: loc.Topology, **opts) -> PandasState:
        return init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors,
                  server_mask=None):
        return slot_step(s, key, types, active, est, true_rates, ancestors,
                         server_mask=server_mask)

    def num_in_system(self, s: PandasState) -> jnp.ndarray:
        return num_in_system(s)

    def telemetry_gauges(self, s: PandasState):
        return telemetry_gauges(s)
