"""Balanced-PANDAS (paper §3.2; Xie et al. 2016, Yekkehkhany et al. 2018).

Queueing structure: three queues per server, (Q^l, Q^k, Q^r) for tasks that
are local / rack-local / remote *to that server*.  Workload

    W_m = Q^l_m / alpha + Q^k_m / beta + Q^r_m / gamma.

Routing: a type-``L`` arrival joins the queue of

    argmin_m  W_m / (alpha*1{m local} + beta*1{m rack-local} + gamma*1{else})

with random tie-breaking.  Scheduling: an idle server serves its own local
queue first, then rack-local, then remote (and the class of the queue a task
sits in is, by construction, its true service class — PANDAS dynamics here
are exact, unlike the (m,n)-proxy needed for JSQ-MW).

Robustness experiment: the *scheduler* computes W and the routing rates with
estimated rates ``est`` of shape (M, 3) — per-server (alpha^, beta^, gamma^),
supporting per-tier and per-server error models — while the *service*
dynamics use the true ``true3``.

Scale-invariance note (beyond-paper analytical finding, see EXPERIMENTS.md):
if every estimate is scaled by one constant c, W scales by 1/c and the
routing score W/rate by 1/c^2, so the argmin — and hence the entire sample
path — is unchanged.  The same holds for MaxWeight (scores scale by c).  The
paper's robustness experiment is therefore only meaningful for errors that
are NOT a global rescaling (per-tier-subset or per-server errors).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import claiming, locality as loc
from repro.core.policy import SlotPolicy, register_policy


class PandasState(NamedTuple):
    q_local: jnp.ndarray   # (M,) int32 waiting local tasks
    q_rack: jnp.ndarray    # (M,) int32 waiting rack-local tasks
    q_remote: jnp.ndarray  # (M,) int32 waiting remote tasks
    serving: jnp.ndarray   # (M,) int32 class in service (0 idle, 1/2/3)


def init_state(topo: loc.Topology) -> PandasState:
    z = jnp.zeros((topo.num_servers,), jnp.int32)
    return PandasState(z, z, z, z)


def num_in_system(s: PandasState) -> jnp.ndarray:
    return (jnp.sum(s.q_local) + jnp.sum(s.q_rack) + jnp.sum(s.q_remote)
            + jnp.sum(s.serving > 0))


def workload(s: PandasState, est: jnp.ndarray) -> jnp.ndarray:
    """(M,) estimated weighted workload W_m (waiting + in-service share).

    est: (M, 3) per-server estimated (alpha^, beta^, gamma^).  The in-service
    task contributes its expected residual 1/rate in the class it is being
    served at, matching the paper's W definition over queue contents (queues
    here exclude the in-service task, so we add it back).
    """
    w = (s.q_local / est[:, 0] + s.q_rack / est[:, 1] + s.q_remote / est[:, 2])
    resid_rate = jnp.take_along_axis(
        est, jnp.clip(s.serving - 1, 0, 2)[:, None], axis=1)[:, 0]
    return w + jnp.where(s.serving > 0, 1.0 / resid_rate, 0.0)


def route_one(s: PandasState, key: jax.Array, task: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray,
              rack_of: jnp.ndarray) -> PandasState:
    """Route a single arrival against the live workloads (estimated rates).

    Tie-break: among minimal scores, prefer the faster tier (then random).
    The paper says "ties are broken randomly", but read literally that
    routes ~(M-M_R)/M of arrivals REMOTE whenever workloads tie at 0 (any
    idle fleet), which no real scheduler does and which inverts the Fig. 1
    ordering at sub-critical load — see EXPERIMENTS.md §Reproduction.  The
    infinitesimal rate preference only discriminates exact ties.
    """
    local, rack = loc.locality_masks(task, rack_of)
    est_rate = jnp.where(local, est[:, 0], jnp.where(rack, est[:, 1], est[:, 2]))
    score = workload(s, est) / est_rate - est_rate * 1e-6
    m_star = loc.random_argmin(key, score)
    cls = jnp.where(local[m_star], loc.LOCAL,
                    jnp.where(rack[m_star], loc.RACK_LOCAL, loc.REMOTE))
    inc = active.astype(jnp.int32)
    return PandasState(
        q_local=s.q_local.at[m_star].add(inc * (cls == loc.LOCAL)),
        q_rack=s.q_rack.at[m_star].add(inc * (cls == loc.RACK_LOCAL)),
        q_remote=s.q_remote.at[m_star].add(inc * (cls == loc.REMOTE)),
        serving=s.serving,
    )


def service_completions(s: PandasState, k_serve: jax.Array,
                        true_rates: jnp.ndarray):
    """Bernoulli service completions at the *true* rates.

    `true_rates` is the shared ``(3,)`` vector or a per-server ``(M, 3)``
    matrix (scenario fault injection).  Returns (done (M,) bool,
    completions int32) — the per-server mask is what the blind policy's
    estimator consumes.
    """
    tm3 = loc.per_server_rates(true_rates, s.serving.shape[0])
    done = jax.random.bernoulli(k_serve, claiming.tier_rates(s.serving, tm3))
    return done, jnp.sum(done).astype(jnp.int32)


def schedule_idle(s: PandasState, done: jnp.ndarray) -> PandasState:
    """Idle servers (post-completion) pick local > rack-local > remote
    (conflict-free)."""
    serving = jnp.where(done, 0, s.serving)
    next_cls = jnp.where(s.q_local > 0, loc.LOCAL,
                         jnp.where(s.q_rack > 0, loc.RACK_LOCAL,
                                   jnp.where(s.q_remote > 0, loc.REMOTE, 0)))
    take = (serving == 0) & (next_cls > 0)
    return PandasState(
        q_local=s.q_local - (take & (next_cls == loc.LOCAL)),
        q_rack=s.q_rack - (take & (next_cls == loc.RACK_LOCAL)),
        q_remote=s.q_remote - (take & (next_cls == loc.REMOTE)),
        serving=jnp.where(take, next_cls, serving).astype(jnp.int32),
    )


def serve_and_schedule(s: PandasState, k_serve: jax.Array,
                       true_rates: jnp.ndarray):
    """Service completions (true rates) + idle-server scheduling.

    Shared by every PANDAS-queue-structure policy (full-scan, power-of-d
    and blind routing only differ in the arrival phase / rate source).
    Returns (state, completions).
    """
    done, completions = service_completions(s, k_serve, true_rates)
    return schedule_idle(s, done), completions


def slot_step(s: PandasState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              rack_of: jnp.ndarray):
    """One time slot: arrivals -> service completions -> scheduling.

    Returns (state, completions_this_slot).
    """
    k_route, k_serve = jax.random.split(key)
    n_arr = types.shape[0]

    # Sequential routing of the slot's arrivals (workloads update in-slot).
    def body(i, st):
        return route_one(st, jax.random.fold_in(k_route, i), types[i],
                         active[i], est, rack_of)
    s = jax.lax.fori_loop(0, n_arr, body, s)

    return serve_and_schedule(s, k_serve, true_rates)


@register_policy
class BalancedPandasPolicy(SlotPolicy):
    """Balanced-PANDAS: weighted-workload routing over estimated per-tier
    rates — the paper's headline throughput- and heavy-traffic-optimal
    policy.  Arrivals go to the server minimizing workload W / rate over
    local / rack-local / remote tiers; robust to rate mis-estimation
    (paper §4) and the reference point every other arm is compared to.
    """

    name = "balanced_pandas"

    def init_state(self, topo: loc.Topology, **opts) -> PandasState:
        return init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, rack_of):
        return slot_step(s, key, types, active, est, true_rates, rack_of)

    def num_in_system(self, s: PandasState) -> jnp.ndarray:
        return num_in_system(s)
