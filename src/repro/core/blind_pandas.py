"""Blind Balanced-PANDAS: online rate learning inside the simulator
(Blind GB-PANDAS, Yekkehkhany & Nagi 2020 — the paper's "future work" arm).

Identical queueing structure and service dynamics to `balanced_pandas`, but
the *scheduler's* rates are not an input: the policy starts from a prior,
observes every completed task's (server, tier, service time) and maintains
per-(server, tier) EWMA estimates in its own `lax.scan` state — the JAX
counterpart of the host-side `EwmaRateEstimator` that the serving engine
and data pipeline already run.  The ``est`` argument of `slot_step` is
deliberately ignored: a blind scheduler has no oracle.

This is the second arm of the drift study (`robustness.drift_study`): under
time-varying scenarios (stragglers, rack congestion, hotspot migration) a
fixed prior — even one exactly right at t=0 — goes stale, while the blind
EWMA tracks the drift.  The estimate floor keeps routing finite while a
(server, tier) pair is unobserved; like the host estimator, the service
TIME is EWMA'd and inverted on read (1/E[T] is the consistent estimator).

The prior is a strictly-decreasing K-vector matching the topology's tier
count (checked at `init_state`); the classic 3-tier default is
``(0.5, 0.45, 0.25)``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import balanced_pandas as bp
from repro.core import locality as loc
from repro.core.estimator import ewma_time_update
from repro.core.policy import SlotPolicy, register_policy


class BlindPandasState(NamedTuple):
    core: bp.PandasState
    age: jnp.ndarray   # (M,) int32 completed slots of the in-service task
    tbar: jnp.ndarray  # (M, K) f32 EWMA'd service time per (server, tier)


@register_policy
class BlindPandasPolicy(SlotPolicy):
    """Blind GB-PANDAS: Balanced-PANDAS that starts from a prior and keeps
    per-(server, tier) EWMA rate estimates inside the scan state,
    re-learning online when the true rates drift.

    Options: ``prior`` — the (K,) tier rates the estimates start from;
    ``decay`` — EWMA decay per observation; ``floor`` — lower clamp on the
    read-side rate estimates.  Travel in
    ``PolicyConfig("blind_pandas", {"prior": (...), ...})``.
    """

    name = "blind_pandas"

    def __init__(self, prior: Sequence[float] = (0.5, 0.45, 0.25),
                 decay: float = 0.98, floor: float = 1e-3):
        prior = tuple(float(p) for p in prior)
        if len(prior) < 2 or any(not 0.0 < p <= 1.0 for p in prior):
            raise ValueError(f"prior must be >= 2 tier rates in (0, 1], "
                             f"got {prior}")
        if not 0.0 < decay < 1.0:
            raise ValueError(f"decay must be in (0, 1), got {decay}")
        self.prior: Tuple[float, ...] = prior
        self.decay = decay
        self.floor = floor

    def init_state(self, topo: loc.Topology, **opts) -> BlindPandasState:
        m = topo.num_servers
        if len(self.prior) != topo.num_tiers:
            raise ValueError(f"prior has {len(self.prior)} tiers but the "
                             f"topology has {topo.num_tiers}")
        tbar = jnp.tile(1.0 / jnp.asarray(self.prior, jnp.float32), (m, 1))
        return BlindPandasState(core=bp.init_state(topo),
                                age=jnp.zeros((m,), jnp.int32), tbar=tbar)

    def estimates(self, s: BlindPandasState) -> jnp.ndarray:
        """(M, K) current rate estimates the routing decisions use."""
        return jnp.clip(1.0 / jnp.maximum(s.tbar, 1e-9), self.floor, 1.0)

    def slot_step(self, s: BlindPandasState, key, types, active, est,
                  true_rates, ancestors):
        del est  # blind: the policy trusts only its own observations
        anc = loc.as_ancestors(ancestors)
        my_est = self.estimates(s)
        k_route, k_serve = jax.random.split(key)
        n_arr = types.shape[0]

        core = s.core

        def body(i, st):
            return bp.route_one(st, jax.random.fold_in(k_route, i), types[i],
                                active[i], my_est, anc)
        core = jax.lax.fori_loop(0, n_arr, body, core)

        # Exactly balanced_pandas's service/scheduling dynamics, via the
        # shared helpers — only the estimator bookkeeping is new.
        done, completions = bp.service_completions(core, k_serve, true_rates)

        # Observe: a task completing this slot took age+1 slots of service.
        k = s.tbar.shape[1]
        tier = jnp.clip(core.serving - 1, 0, k - 1)
        tbar = ewma_time_update(s.tbar, done, tier,
                                (s.age + 1).astype(jnp.float32), self.decay)

        new_core = bp.schedule_idle(core, done)
        # Tasks that survived the slot age one slot; completed / fresh /
        # idle servers reset to zero.
        age = jnp.where((core.serving > 0) & ~done, s.age + 1, 0)
        return BlindPandasState(new_core, age, tbar), completions

    def num_in_system(self, s: BlindPandasState) -> jnp.ndarray:
        return bp.num_in_system(s.core)

    def extra_metrics(self, s: BlindPandasState):
        """Mean learned local-tier rate — a cheap observability hook for the
        drift figures (tracks straggler windows opening and closing)."""
        return {"est_alpha_mean": jnp.mean(self.estimates(s)[:, 0])}

    def telemetry_gauges(self, s: BlindPandasState):
        gauges = bp.telemetry_gauges(s.core)
        gauges["est_alpha_mean"] = jnp.mean(self.estimates(s)[:, 0])
        return gauges
