"""Sequential task-claiming for single-queue-per-server policies.

JSQ-MaxWeight and Priority both schedule idle servers by scanning servers (in
a random order each slot, for fairness) and letting each idle server claim
the head task of some queue chosen by a policy-specific score.  Claims within
a slot must be sequential so two servers cannot take the same last task; the
loop carries the live queue vector.

All tier logic derives from the `core/locality.py` seam, so these helpers
are K-generic: they accept a (depth, M) ancestor table (or the legacy (M,)
rack map, normalized through `loc.as_ancestors`).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import locality as loc


def claim_loop(
    q: jnp.ndarray,                 # (M,) int32 waiting tasks per queue
    serving_tier: jnp.ndarray,      # (M,) int32; 0 == idle, else class 1..K
    key: jax.Array,
    score_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    tier_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
):
    """Each idle server m claims argmax_n score_fn(m, q) among nonempty queues.

    score_fn(m, q) -> (M,) float scores; entries for empty queues are masked
    here.  tier_fn(m, n) -> int32 service class (1..K) once m starts n's
    head task.  The CLASS is stored, not the numeric rate: the caller
    re-derives the rate from the current true rates every slot, so scenario
    fault injection (stragglers, congestion windows) applies to in-flight
    tasks too — matching the PANDAS-family dynamics.
    Returns (q, serving_tier).
    """
    m_total = q.shape[0]
    k_perm, k_tie = jax.random.split(key)
    order = jax.random.permutation(k_perm, m_total)

    def body(i, carry):
        q, serving_tier = carry
        m = order[i]
        idle = serving_tier[m] == 0
        score = jnp.where(q > 0, score_fn(m, q), -jnp.inf)
        any_task = jnp.any(q > 0)
        n_star = loc.random_argmax(jax.random.fold_in(k_tie, i), score)
        take = idle & any_task
        q = q.at[n_star].add(-take.astype(jnp.int32))
        new_tier = jnp.where(take, tier_fn(m, n_star), serving_tier[m])
        serving_tier = serving_tier.at[m].set(new_tier.astype(jnp.int32))
        return q, serving_tier

    return jax.lax.fori_loop(0, m_total, body, (q, serving_tier))


def pair_tier(m: jnp.ndarray, n: jnp.ndarray,
              ancestors: jnp.ndarray) -> jnp.ndarray:
    """(m,n)-relation service class 1..K: LOCAL if m == n, then one class
    per shared hierarchy level, REMOTE otherwise — the class analogue of
    `loc.pair_rate`, shared by the claim-based policies (JSQ-MaxWeight,
    Priority).  `ancestors` is a (depth, M) table or legacy (M,) rack map."""
    return (loc.pair_tiers(m, n, ancestors) + 1).astype(jnp.int32)


def tier_rates(serving_tier: jnp.ndarray, tmk: jnp.ndarray) -> jnp.ndarray:
    """(M,) current true service rate per server: row m of the (M, K) true
    rates at the in-service class, 0 where idle.  Looked up fresh each slot
    so the rate tracks the scenario's per-slot true-rate multipliers."""
    k = tmk.shape[1]
    rate = jnp.take_along_axis(
        tmk, jnp.clip(serving_tier - 1, 0, k - 1)[:, None], axis=1)[:, 0]
    return jnp.where(serving_tier > 0, rate, 0.0)


def jsq_route_one(q: jnp.ndarray, key: jax.Array, task: jnp.ndarray,
                  active: jnp.ndarray) -> jnp.ndarray:
    """Join-the-shortest-queue among the task's 3 local servers."""
    qlen = q[task]  # (3,)
    j = loc.random_argmin(key, qlen.astype(jnp.float32))
    return q.at[task[j]].add(active.astype(jnp.int32))


def telemetry_gauges(q: jnp.ndarray, serving_tier: jnp.ndarray):
    """Queued total + busy servers for the telemetry series, shared by the
    claim-based policies.  Waiting tasks have no tier until claim time
    (the (m, n) class is resolved when an idle server pulls), so only the
    totals are honest gauges here — per-tier queue breakdowns come from
    the PANDAS-structure policies, whose queues ARE tiered."""
    return {"queued": jnp.sum(q).astype(jnp.float32),
            "in_service": jnp.sum(serving_tier > 0).astype(jnp.float32)}
