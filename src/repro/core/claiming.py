"""Sequential task-claiming for single-queue-per-server policies.

JSQ-MaxWeight and Priority both schedule idle servers by scanning servers (in
a random order each slot, for fairness) and letting each idle server claim
the head task of some queue chosen by a policy-specific score.  Claims within
a slot must be sequential so two servers cannot take the same last task; the
loop carries the live queue vector.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import locality as loc


def claim_loop(
    q: jnp.ndarray,                 # (M,) int32 waiting tasks per queue
    serving_rate: jnp.ndarray,      # (M,) f32; 0 == idle
    key: jax.Array,
    score_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    true_rate_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
):
    """Each idle server m claims argmax_n score_fn(m, q) among nonempty queues.

    score_fn(m, q) -> (M,) float scores; entries for empty queues are masked
    here.  true_rate_fn(m, n) -> scalar true service rate once m starts n's
    head task.  Returns (q, serving_rate).
    """
    m_total = q.shape[0]
    k_perm, k_tie = jax.random.split(key)
    order = jax.random.permutation(k_perm, m_total)

    def body(i, carry):
        q, serving_rate = carry
        m = order[i]
        idle = serving_rate[m] == 0.0
        score = jnp.where(q > 0, score_fn(m, q), -jnp.inf)
        any_task = jnp.any(q > 0)
        n_star = loc.random_argmax(jax.random.fold_in(k_tie, i), score)
        take = idle & any_task
        q = q.at[n_star].add(-take.astype(jnp.int32))
        new_rate = jnp.where(take, true_rate_fn(m, n_star), serving_rate[m])
        serving_rate = serving_rate.at[m].set(new_rate)
        return q, serving_rate

    return jax.lax.fori_loop(0, m_total, body, (q, serving_rate))


def jsq_route_one(q: jnp.ndarray, key: jax.Array, task: jnp.ndarray,
                  active: jnp.ndarray) -> jnp.ndarray:
    """Join-the-shortest-queue among the task's 3 local servers."""
    qlen = q[task]  # (3,)
    j = loc.random_argmin(key, qlen.astype(jnp.float32))
    return q.at[task[j]].add(active.astype(jnp.int32))
