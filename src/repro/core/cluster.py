"""Production-facing cluster router: the paper's algorithms as an online,
host-side service (numpy, incremental) for the serving engine and the data
pipeline.

"Servers" here are abstract workers (model-replica groups, data hosts,
pipeline stages); "tasks" carry a set of local workers (where their
prefix-KV / data chunk lives).  Locality tiers: local (on-worker), rack-local
(same pod, ICI transfer), remote (cross-pod, DCN transfer).

The router mirrors `core/balanced_pandas.py` et al. exactly — unit tests
cross-check decisions against the JAX implementations — but maintains state
incrementally so it can sit on the critical path of a serving engine, and it
sources its rates from `EwmaRateEstimator` (blind mode) or fixed priors.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.estimator import EwmaRateEstimator
from repro.core.locality import LOCAL, RACK_LOCAL, REMOTE


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Worker fleet layout: `num_workers` workers in pods of `workers_per_pod`."""

    num_workers: int
    workers_per_pod: int

    @property
    def pod_of(self) -> np.ndarray:
        return np.arange(self.num_workers) // self.workers_per_pod


class BalancedPandasRouter:
    """Incremental Balanced-PANDAS over an abstract worker fleet."""

    name = "balanced_pandas"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator: Optional[EwmaRateEstimator] = None,
                 seed: int = 0):
        self.spec = spec
        self.pod_of = spec.pod_of
        self.prior = np.asarray(rates, np.float32)  # (3,) alpha,beta,gamma
        self.estimator = estimator
        self.q = np.zeros((spec.num_workers, 3), np.int64)  # per-tier queues
        self.rng = np.random.default_rng(seed)

    # -- estimated rates -----------------------------------------------------
    def _est(self) -> np.ndarray:  # (M,3)
        if self.estimator is not None:
            return self.estimator.rates
        return np.tile(self.prior, (self.spec.num_workers, 1))

    def tiers(self, locals_: Sequence[int]) -> np.ndarray:
        """(M,) tier index (0 local / 1 rack-local / 2 remote) of each worker."""
        m = self.spec.num_workers
        tier = np.full(m, 2, np.int64)
        local_pods = np.unique(self.pod_of[list(locals_)])
        tier[np.isin(self.pod_of, local_pods)] = 1
        tier[list(locals_)] = 0
        return tier

    def workload(self) -> np.ndarray:
        est = self._est()
        return (self.q / est).sum(axis=1)

    def route(self, locals_: Sequence[int]) -> int:
        """Assign a task with the given local workers; returns the worker.

        Ties (typically W == 0 on an idle fleet, where W/rate cannot
        discriminate) break toward the highest-rate tier: an idle local
        worker always wins over an idle remote one.  The discrete-time
        simulator keeps the paper's uniform-random tie-break; this is the
        production-sensible refinement (noted in EXPERIMENTS.md).
        """
        est = self._est()
        tier = self.tiers(locals_)
        rate = np.take_along_axis(est, tier[:, None], axis=1)[:, 0]
        score = self.workload() / rate
        mins = np.flatnonzero(score <= score.min() * (1 + 1e-9))
        best_rate = rate[mins].max()
        cand = mins[rate[mins] >= best_rate * (1 - 1e-9)]
        m_star = int(self.rng.choice(cand))
        self.q[m_star, tier[m_star]] += 1
        return m_star

    def next_task_tier(self, worker: int) -> Optional[int]:
        """Which tier the idle worker serves next (local>rack>remote), or None."""
        for t in range(3):
            if self.q[worker, t] > 0:
                self.q[worker, t] -= 1
                return t
        return None

    def on_complete(self, worker: int, tier: int, service_time: float) -> None:
        if self.estimator is not None:
            self.estimator.observe(worker, tier, service_time)


class JsqMaxWeightRouter:
    """Incremental JSQ-MaxWeight baseline over the same fleet abstraction."""

    name = "jsq_maxweight"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator: Optional[EwmaRateEstimator] = None, seed: int = 0):
        self.spec = spec
        self.pod_of = spec.pod_of
        self.prior = np.asarray(rates, np.float32)
        self.estimator = estimator
        self.q = np.zeros(spec.num_workers, np.int64)
        self.rng = np.random.default_rng(seed)

    def _est(self) -> np.ndarray:
        if self.estimator is not None:
            return self.estimator.rates
        return np.tile(self.prior, (self.spec.num_workers, 1))

    def route(self, locals_: Sequence[int]) -> int:
        locals_ = list(locals_)
        j = _rand_argmin(self.rng, self.q[locals_].astype(np.float64))
        self.q[locals_[j]] += 1
        return int(locals_[j])

    def claim(self, worker: int) -> Optional[int]:
        """Idle worker claims head task of argmax weighted queue; returns the
        queue (owning worker) claimed from, or None."""
        if not (self.q > 0).any():
            return None
        est = self._est()[worker]  # (3,)
        w = np.where(np.arange(self.spec.num_workers) == worker, est[0],
                     np.where(self.pod_of == self.pod_of[worker], est[1], est[2]))
        score = np.where(self.q > 0, w * self.q, -np.inf)
        n_star = _rand_argmax(self.rng, score)
        self.q[n_star] -= 1
        return int(n_star)

    def on_complete(self, worker: int, tier: int, service_time: float) -> None:
        if self.estimator is not None:
            self.estimator.observe(worker, tier, service_time)


class FifoRouter:
    """Global-FIFO baseline (Hadoop default)."""

    name = "fifo"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        self.spec = spec
        self.pod_of = spec.pod_of
        self.queue: List[List[int]] = []

    def route(self, locals_: Sequence[int]) -> int:
        self.queue.append(list(locals_))
        return -1  # assignment deferred to claim time

    def claim(self, worker: int) -> Optional[List[int]]:
        if not self.queue:
            return None
        return self.queue.pop(0)

    def on_complete(self, worker: int, tier: int, service_time: float) -> None:
        pass


def tier_of(spec: ClusterSpec, locals_: Sequence[int], worker: int) -> int:
    """0 local / 1 rack(pod)-local / 2 remote — shared helper."""
    if worker in set(locals_):
        return 0
    if spec.pod_of[worker] in set(spec.pod_of[list(locals_)]):
        return 1
    return 2


def _rand_argmin(rng, x: np.ndarray) -> int:
    mins = np.flatnonzero(x == x.min())
    return int(rng.choice(mins))


def _rand_argmax(rng, x: np.ndarray) -> int:
    maxs = np.flatnonzero(x == x.max())
    return int(rng.choice(maxs))


ROUTERS = {
    "balanced_pandas": BalancedPandasRouter,
    "jsq_maxweight": JsqMaxWeightRouter,
    "fifo": FifoRouter,
}
