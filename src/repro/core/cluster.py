"""Production-facing cluster routers: the paper's algorithms as an online,
host-side service (numpy, incremental) for the serving engine and the data
pipeline.

"Servers" here are abstract workers (model-replica groups, data hosts,
pipeline stages); "tasks" carry a set of local workers (where their
prefix-KV / data chunk lives).  Locality tiers: local (on-worker), rack-local
(same pod, ICI transfer), remote (cross-pod, DCN transfer).

Every router subclasses `repro.core.policy.Router` and speaks the uniform
``route(locals_) -> Decision`` / ``claim(worker) -> Claim | None`` surface,
so the serving engine and data pipeline drive any of them through one code
path.  Each mirrors its `core/*.py` JAX policy exactly — unit tests
cross-check decisions against the JAX implementations — but maintains state
incrementally so it can sit on the critical path of a serving engine, and
sources its rates from `EwmaRateEstimator` (blind mode) or fixed priors.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.policy import Claim, Decision, Router, register_router


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Worker fleet layout: `num_workers` workers in pods of `workers_per_pod`."""

    num_workers: int
    workers_per_pod: int

    @property
    def pod_of(self) -> np.ndarray:
        return np.arange(self.num_workers) // self.workers_per_pod


@register_router
class BalancedPandasRouter(Router):
    """Incremental Balanced-PANDAS over an abstract worker fleet: weighted
    workload / estimated rate argmin per arrival, with the production
    two-stage tie-break (minimal score, then fastest tier, then random).
    """

    name = "balanced_pandas"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        self.q = np.zeros((spec.num_workers, 3), np.int64)  # per-tier queues

    def tiers(self, locals_: Sequence[int]) -> np.ndarray:
        """(M,) tier index (0 local / 1 rack-local / 2 remote) of each worker."""
        m = self.spec.num_workers
        tier = np.full(m, 2, np.int64)
        local_pods = np.unique(self.pod_of[list(locals_)])
        tier[np.isin(self.pod_of, local_pods)] = 1
        tier[list(locals_)] = 0
        return tier

    def workload(self) -> np.ndarray:
        est = self._est()
        return (self.q / est).sum(axis=1)

    def route(self, locals_: Sequence[int]) -> Decision:
        """Assign a task with the given local workers.

        Ties (typically W == 0 on an idle fleet, where W/rate cannot
        discriminate) break toward the highest-rate tier: an idle local
        worker always wins over an idle remote one.  The discrete-time
        simulator keeps the paper's uniform-random tie-break; this is the
        production-sensible refinement (noted in EXPERIMENTS.md).
        """
        est = self._est()
        tier = self.tiers(locals_)
        rate = np.take_along_axis(est, tier[:, None], axis=1)[:, 0]
        score = self.workload() / rate
        mins = np.flatnonzero(score <= score.min() * (1 + 1e-9))
        best_rate = rate[mins].max()
        cand = mins[rate[mins] >= best_rate * (1 - 1e-9)]
        m_star = int(self.rng.choice(cand))
        self.q[m_star, tier[m_star]] += 1
        return Decision(worker=m_star, tier=int(tier[m_star]))

    def claim(self, worker: int) -> Optional[Claim]:
        """Idle worker serves its own queues, local > rack > remote."""
        for t in range(3):
            if self.q[worker, t] > 0:
                self.q[worker, t] -= 1
                return Claim(source=worker, tier=t)
        return None

    def queue_depths(self) -> np.ndarray:
        return self.q.sum(axis=1)


@register_router
class PandasPoDRouter(BalancedPandasRouter):
    """Power-of-d-choices Balanced-PANDAS: O(d) routing on the host path.

    Instead of scanning all M workers per arrival, compare weighted
    workloads over {the task's locals} ∪ {d uniform samples} only — the
    candidate scoring touches O(d) rows of the queue matrix, which is what
    makes the router viable at very large fleets.  Claiming and estimator
    plumbing are inherited unchanged from `BalancedPandasRouter`; the JAX
    counterpart is `core/pandas_po2.py`.
    """

    name = "pandas_po2"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator=None, seed: int = 0, d: int = 2):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        if d < 1:
            raise ValueError(f"need d >= 1 candidate samples, got {d}")
        self.d = d

    def route(self, locals_: Sequence[int]) -> Decision:
        m = self.spec.num_workers
        locals_ = [int(x) for x in locals_]
        sampled = self.rng.choice(m, size=min(self.d, m), replace=False)
        cand = sorted(set(locals_) | {int(x) for x in sampled})
        local_pods = {int(p) for p in self.pod_of[locals_]}
        tier = np.array([0 if c in locals_
                         else (1 if int(self.pod_of[c]) in local_pods else 2)
                         for c in cand], np.int64)
        # (C, 3) estimated rates for the candidates only — never the full
        # (M, 3) matrix, or the O(d) claim would be O(M) in disguise.
        est = (self.estimator.rates_for(cand) if self.estimator is not None
               else np.tile(self.prior, (len(cand), 1)))
        w = (self.q[cand] / est).sum(axis=1)
        rate = np.take_along_axis(est, tier[:, None], axis=1)[:, 0]
        score = w / rate
        mins = np.flatnonzero(score <= score.min() * (1 + 1e-9))
        best_rate = rate[mins].max()
        pick = mins[rate[mins] >= best_rate * (1 - 1e-9)]
        j = int(self.rng.choice(pick))
        m_star = cand[j]
        self.q[m_star, tier[j]] += 1
        return Decision(worker=m_star, tier=int(tier[j]))


@register_router
class JsqMaxWeightRouter(Router):
    """Incremental JSQ-MaxWeight baseline: shortest-queue routing with
    MaxWeight-style claiming over the same fleet abstraction.
    """

    name = "jsq_maxweight"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        self.q = np.zeros(spec.num_workers, np.int64)

    def route(self, locals_: Sequence[int]) -> Decision:
        locals_ = list(locals_)
        j = _rand_argmin(self.rng, self.q[locals_].astype(np.float64))
        m_star = int(locals_[j])
        self.q[m_star] += 1
        return Decision(worker=m_star,
                        tier=tier_of(self.spec, locals_, m_star))

    def claim(self, worker: int) -> Optional[Claim]:
        """Idle worker claims the head task of the argmax weighted queue
        (MaxWeight work stealing); returns the queue (owning worker) claimed
        from, or None."""
        if not (self.q > 0).any():
            return None
        est = self._est()[worker]  # (3,)
        w = np.where(np.arange(self.spec.num_workers) == worker, est[0],
                     np.where(self.pod_of == self.pod_of[worker], est[1],
                              est[2]))
        score = np.where(self.q > 0, w * self.q, -np.inf)
        n_star = _rand_argmax(self.rng, score)
        self.q[n_star] -= 1
        tier = 0 if n_star == worker else (
            1 if self.pod_of[n_star] == self.pod_of[worker] else 2)
        return Claim(source=int(n_star), tier=tier)

    def queue_depths(self) -> np.ndarray:
        return self.q.copy()


@register_router
class FifoRouter(Router):
    """Global-FIFO baseline (Hadoop default).

    Stores its estimator like every other router (uniform base
    constructor): FIFO never *consults* rates, but `on_complete`
    observations still flow, so a fleet can switch from FIFO to a
    rate-aware policy without re-warming the estimates.
    """

    name = "fifo"

    def __init__(self, spec: ClusterSpec, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        self.queue: List[List[int]] = []

    def route(self, locals_: Sequence[int]) -> Decision:
        self.queue.append(list(locals_))
        return Decision(worker=-1, tier=-1, deferred=True)

    def claim(self, worker: int) -> Optional[Claim]:
        if not self.queue:
            return None
        self.queue.pop(0)
        return Claim(source=-1, tier=-1)  # tier depends on the task itself


def tier_of(spec: ClusterSpec, locals_: Sequence[int], worker: int) -> int:
    """0 local / 1 rack(pod)-local / 2 remote — shared helper."""
    if worker in set(locals_):
        return 0
    if spec.pod_of[worker] in set(spec.pod_of[list(locals_)]):
        return 1
    return 2


def _rand_argmin(rng, x: np.ndarray) -> int:
    mins = np.flatnonzero(x == x.min())
    return int(rng.choice(mins))


def _rand_argmax(rng, x: np.ndarray) -> int:
    maxs = np.flatnonzero(x == x.max())
    return int(rng.choice(maxs))
