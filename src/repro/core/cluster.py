"""Production-facing cluster routers: the paper's algorithms as an online,
host-side service (numpy, incremental) for the serving engine and the data
pipeline.

"Servers" here are abstract workers (model-replica groups, data hosts,
pipeline stages); "tasks" carry a set of local workers (where their
prefix-KV / data chunk lives).  The fleet layout is the same
`locality.Topology` the JAX simulator uses — the old host-only
``ClusterSpec`` is retired (a thin alias remains) — so locality tiers are
K-generic: local (on-worker), one tier per hierarchy level (same rack /
same pod: ICI transfer), remote (cross-pod, DCN transfer).

Every router subclasses `repro.core.policy.Router` and speaks the uniform
``route(locals_) -> Decision`` / ``claim(worker) -> Claim | None`` surface,
so the serving engine and data pipeline drive any of them through one code
path.  Each mirrors its `core/*.py` JAX policy exactly — unit tests
cross-check decisions against the JAX implementations — but maintains state
incrementally so it can sit on the critical path of a serving engine, and
sources its rates from `EwmaRateEstimator` (blind mode) or fixed priors.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.locality import Topology
from repro.core.policy import Claim, Decision, Router, register_router

def ClusterSpec(num_workers: int, workers_per_pod: int) -> Topology:
    """Retired host-side fleet spec, kept as a constructor shim: the
    unified `Topology` replaces it everywhere and validates what
    ClusterSpec never did (group sizes must tile ``num_workers``; a
    20-worker fleet in pods of 8 used to silently mis-assign pods)."""
    return Topology(num_workers, workers_per_pod)


def worker_tiers(spec: Topology, locals_: Sequence[int]) -> np.ndarray:
    """(M,) tier index (0 local .. K-1 remote) of each worker for a task
    whose data lives on `locals_` — the host-side `server_tiers`."""
    anc = np.asarray(spec.ancestors)
    locals_ = list(locals_)
    tier = np.full(spec.num_workers, spec.num_tiers - 1, np.int64)
    for lvl in range(anc.shape[0] - 1, -1, -1):
        tier[np.isin(anc[lvl], anc[lvl][locals_])] = lvl + 1
    tier[locals_] = 0
    return tier


def pair_worker_tiers(spec: Topology, worker: int) -> np.ndarray:
    """(M,) pair tier of every worker n w.r.t. `worker` (0 if n == worker,
    else 1 + deepest shared level, else K-1) — the host-side
    `locality.pair_tiers`."""
    anc = np.asarray(spec.ancestors)
    tier = np.full(spec.num_workers, spec.num_tiers - 1, np.int64)
    for lvl in range(anc.shape[0] - 1, -1, -1):
        tier[anc[lvl] == anc[lvl, worker]] = lvl + 1
    tier[worker] = 0
    return tier


def tier_of(spec: Topology, locals_: Sequence[int], worker: int) -> int:
    """Tier index (0 local .. K-1 remote) of one worker — shared helper."""
    if worker in set(locals_):
        return 0
    anc = np.asarray(spec.ancestors)
    for lvl in range(anc.shape[0]):
        if anc[lvl, worker] in set(int(a) for a in anc[lvl, list(locals_)]):
            return lvl + 1
    return spec.num_tiers - 1


@register_router
class BalancedPandasRouter(Router):
    """Incremental Balanced-PANDAS over an abstract worker fleet: weighted
    workload / estimated rate argmin per arrival, with the production
    two-stage tie-break (minimal score, then fastest tier, then random).
    """

    name = "balanced_pandas"

    def __init__(self, spec: Topology, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        # one queue per (worker, tier)
        self.q = np.zeros((spec.num_workers, self.num_tiers), np.int64)

    def tiers(self, locals_: Sequence[int]) -> np.ndarray:
        """(M,) tier index of each worker for this task."""
        return worker_tiers(self.spec, locals_)

    def workload(self) -> np.ndarray:
        est = self._est()
        return (self.q / est).sum(axis=1)

    def route(self, locals_: Sequence[int]) -> Decision:
        """Assign a task with the given local workers.

        Ties (typically W == 0 on an idle fleet, where W/rate cannot
        discriminate) break toward the highest-rate tier: an idle local
        worker always wins over an idle remote one.  The discrete-time
        simulator keeps the paper's uniform-random tie-break; this is the
        production-sensible refinement (noted in EXPERIMENTS.md).
        """
        est = self._est()
        tier = self.tiers(locals_)
        rate = np.take_along_axis(est, tier[:, None], axis=1)[:, 0]
        score = self.workload() / rate
        if not self.active_mask.all():
            # Descaled workers take no NEW work (mirrors the simulator's
            # server_mask seam); their queues keep draining via claim().
            score = np.where(self.active_mask, score, np.inf)
        mins = np.flatnonzero(score <= score.min() * (1 + 1e-9))
        best_rate = rate[mins].max()
        cand = mins[rate[mins] >= best_rate * (1 - 1e-9)]
        m_star = int(self.rng.choice(cand))
        self.q[m_star, tier[m_star]] += 1
        return Decision(worker=m_star, tier=int(tier[m_star]))

    def claim(self, worker: int) -> Optional[Claim]:
        """Idle worker serves its own queues, fastest tier first."""
        for t in range(self.num_tiers):
            if self.q[worker, t] > 0:
                self.q[worker, t] -= 1
                return Claim(source=worker, tier=t)
        return None

    def queue_depths(self) -> np.ndarray:
        return self.q.sum(axis=1)


@register_router
class PandasPoDRouter(BalancedPandasRouter):
    """Power-of-d-choices Balanced-PANDAS: O(d) routing on the host path.

    Instead of scanning all M workers per arrival, compare weighted
    workloads over {the task's locals} ∪ {d uniform samples} only — the
    candidate scoring touches O(d) rows of the queue matrix, which is what
    makes the router viable at very large fleets.  Claiming and estimator
    plumbing are inherited unchanged from `BalancedPandasRouter`; the JAX
    counterpart is `core/pandas_po2.py`.
    """

    name = "pandas_po2"

    def __init__(self, spec: Topology, rates: Sequence[float],
                 estimator=None, seed: int = 0, d: int = 2):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        if d < 1:
            raise ValueError(f"need d >= 1 candidate samples, got {d}")
        self.d = d

    def route(self, locals_: Sequence[int]) -> Decision:
        m = self.spec.num_workers
        locals_ = [int(x) for x in locals_]
        sampled = self.rng.choice(m, size=min(self.d, m), replace=False)
        cand = sorted(set(locals_) | {int(x) for x in sampled})
        if not self.active_mask.all():
            live = [c for c in cand if self.active_mask[c]]
            # All candidates descaled: fall back to the active fleet
            # rather than routing to a parked worker.
            cand = live or np.flatnonzero(self.active_mask).tolist()
        # O(d * depth) tier derivation: never touch all M workers
        tier = np.array([tier_of(self.spec, locals_, c) for c in cand],
                        np.int64)
        # (C, K) estimated rates for the candidates only — never the full
        # (M, K) matrix, or the O(d) claim would be O(M) in disguise.
        est = (self.estimator.rates_for(cand) if self.estimator is not None
               else np.tile(self.prior, (len(cand), 1)))
        w = (self.q[cand] / est).sum(axis=1)
        rate = np.take_along_axis(est, tier[:, None], axis=1)[:, 0]
        score = w / rate
        mins = np.flatnonzero(score <= score.min() * (1 + 1e-9))
        best_rate = rate[mins].max()
        pick = mins[rate[mins] >= best_rate * (1 - 1e-9)]
        j = int(self.rng.choice(pick))
        m_star = cand[j]
        self.q[m_star, tier[j]] += 1
        return Decision(worker=m_star, tier=int(tier[j]))


@register_router
class JsqMaxWeightRouter(Router):
    """Incremental JSQ-MaxWeight baseline: shortest-queue routing with
    MaxWeight-style claiming over the same fleet abstraction.
    """

    name = "jsq_maxweight"

    def __init__(self, spec: Topology, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        self.q = np.zeros(spec.num_workers, np.int64)

    def route(self, locals_: Sequence[int]) -> Decision:
        locals_ = list(locals_)
        if not self.active_mask.all():
            live = [w for w in locals_ if self.active_mask[w]]
            # JSQ routes among the task's locals; when every local is
            # descaled, widen to the active fleet (claim-side stealing
            # still drains parked queues).
            locals_ = live or np.flatnonzero(self.active_mask).tolist()
        j = _rand_argmin(self.rng, self.q[locals_].astype(np.float64))
        m_star = int(locals_[j])
        self.q[m_star] += 1
        return Decision(worker=m_star,
                        tier=tier_of(self.spec, locals_, m_star))

    def claim(self, worker: int) -> Optional[Claim]:
        """Idle worker claims the head task of the argmax weighted queue
        (MaxWeight work stealing); returns the queue (owning worker) claimed
        from, or None."""
        if not (self.q > 0).any():
            return None
        est = self._est()[worker]  # (K,)
        pair = pair_worker_tiers(self.spec, worker)
        w = est[pair]
        score = np.where(self.q > 0, w * self.q, -np.inf)
        n_star = _rand_argmax(self.rng, score)
        self.q[n_star] -= 1
        return Claim(source=int(n_star), tier=int(pair[n_star]))

    def queue_depths(self) -> np.ndarray:
        return self.q.copy()


@register_router
class FifoRouter(Router):
    """Global-FIFO baseline (Hadoop default).

    Stores its estimator like every other router (uniform base
    constructor): FIFO never *consults* rates, but `on_complete`
    observations still flow, so a fleet can switch from FIFO to a
    rate-aware policy without re-warming the estimates.
    """

    name = "fifo"

    def __init__(self, spec: Topology, rates: Sequence[float],
                 estimator=None, seed: int = 0):
        super().__init__(spec, rates, estimator=estimator, seed=seed)
        self.queue: List[List[int]] = []

    def route(self, locals_: Sequence[int]) -> Decision:
        self.queue.append(list(locals_))
        return Decision(worker=-1, tier=-1, deferred=True)

    def claim(self, worker: int) -> Optional[Claim]:
        if not self.queue:
            return None
        self.queue.pop(0)
        return Claim(source=-1, tier=-1)  # tier depends on the task itself


def _rand_argmin(rng, x: np.ndarray) -> int:
    mins = np.flatnonzero(x == x.min())
    return int(rng.choice(mins))


def _rand_argmax(rng, x: np.ndarray) -> int:
    maxs = np.flatnonzero(x == x.max())
    return int(rng.choice(maxs))
