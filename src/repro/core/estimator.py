"""Online processing-rate estimation (the paper's "future work" direction,
following Blind GB-PANDAS, Yekkehkhany & Nagi 2020).

The scheduler observes realized service times per (server, locality-tier) and
maintains EWMA estimates of the rates; an epsilon-greedy exploration term
occasionally routes a task off-policy so every (server, tier) keeps getting
samples.  In the TPU-framework integration this is how replica throughput is
tracked (stragglers/thermal throttling show up as decaying alpha-hat).

Two implementations:
  * `EwmaRateEstimator` — host-side (numpy), used by the serving engine and
    data pipeline.
  * `ewma_update` — functional JAX update, used inside simulations of the
    blind variant.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def ewma_update(est: jnp.ndarray, server: jnp.ndarray, tier: jnp.ndarray,
                service_slots: jnp.ndarray, decay: float = 0.98) -> jnp.ndarray:
    """Functional EWMA update of est (M,3) from one completed task.

    service_slots: observed completion time (slots).  The unbiased rate sample
    for geometric service is 1/service_slots.
    """
    sample = 1.0 / jnp.maximum(service_slots.astype(jnp.float32), 1.0)
    old = est[server, tier]
    return est.at[server, tier].set(decay * old + (1.0 - decay) * sample)


def ewma_time_update(tbar: jnp.ndarray, done: jnp.ndarray, tier: jnp.ndarray,
                     service_slots: jnp.ndarray,
                     decay: float = 0.98) -> jnp.ndarray:
    """Vectorized masked EWMA of the service TIME, one slot for all servers.

    tbar: (M, K) EWMA'd service time per (server, tier); done: (M,) bool
    completion mask this slot; tier: (M,) int32 tier served (0..K-1);
    service_slots: (M,) f32 observed completion times.  Like the host-side
    `EwmaRateEstimator`, the TIME is averaged and inverted by the consumer
    (1/E[T] is the consistent rate estimator; E[1/T] is biased upward).
    Used by the blind `SlotPolicy` (`core/blind_pandas.py`) inside
    `lax.scan` — fixed shapes, no scatter.
    """
    upd = decay * tbar + (1.0 - decay) * service_slots[:, None]
    mask = done[:, None] & (jnp.arange(tbar.shape[1])[None, :]
                            == tier[:, None])
    return jnp.where(mask, upd, tbar)


@dataclasses.dataclass
class EwmaRateEstimator:
    """Host-side per-(server, tier) EWMA rate estimator with priors.

    Until a (server, tier) pair has `min_samples` observations its estimate is
    blended toward the prior, which keeps cold-start routing sane (the blind
    algorithm's exploration phase).
    """

    num_servers: int
    prior: np.ndarray  # (K,) prior tier rates (fastest first)
    decay: float = 0.98
    min_samples: int = 8

    def __post_init__(self):
        # EWMA the service TIME and invert: 1/E[T] is the consistent rate
        # estimator (E[1/T] diverges for exponential service).
        self.prior = np.asarray(self.prior, np.float64)
        self._time = np.tile(1.0 / self.prior, (self.num_servers, 1))
        self._count = np.zeros((self.num_servers, self.prior.size), np.int64)

    @property
    def num_tiers(self) -> int:
        return int(self.prior.size)

    def observe(self, server: int, tier: int, service_time: float) -> None:
        """Record one completed task's service time (tier: 0 local ..
        K-1 remote)."""
        self._time[server, tier] = (self.decay * self._time[server, tier]
                                    + (1.0 - self.decay)
                                    * max(service_time, 1e-9))
        self._count[server, tier] += 1

    @property
    def rates(self) -> np.ndarray:
        """(M, 3) current estimates, prior-blended where under-sampled."""
        return self.rates_for(slice(None))

    def rates_for(self, servers) -> np.ndarray:
        """(len(servers), 3) estimates for a subset of servers — O(subset),
        for candidate-sampling routers that must not touch all M rows."""
        w = np.minimum(self._count[servers] / self.min_samples, 1.0)
        est = 1.0 / np.maximum(self._time[servers], 1e-9)
        return (w * est + (1.0 - w) * self.prior[None, :]).astype(np.float32)

    @property
    def sample_counts(self) -> np.ndarray:
        return self._count.copy()
