"""FIFO — Hadoop's default scheduler (paper §1, §4 comparison baseline).

A single global FIFO queue of tasks; an idle server takes the head task
regardless of locality, so the realized service rate is the task's true
locality tier w.r.t. the serving server (exact — the ring buffer stores task
types).  FIFO ignores both queue state and rates, so estimation errors do not
change its decisions; it is neither heavy-traffic delay optimal nor
throughput optimal on the rack model, and its queue diverges inside the other
algorithms' capacity region (paper Fig. 1).  The ring buffer is bounded
(``cap``); arrivals beyond it are dropped and counted, which caps the
measured delay at saturation instead of overflowing.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import locality as loc
from repro.core.claiming import tier_rates
from repro.core.policy import SlotPolicy, register_policy


class FifoState(NamedTuple):
    buf: jnp.ndarray           # (cap, 3) int32 ring buffer of task types
    head: jnp.ndarray          # () int32 index of oldest task
    count: jnp.ndarray         # () int32 number queued
    serving_tier: jnp.ndarray  # (M,) int32 class in service; 0 idle
    drops: jnp.ndarray         # () int32 arrivals dropped (buffer full)


def init_state(topo: loc.Topology, cap: int = 32768) -> FifoState:
    return FifoState(
        buf=jnp.zeros((cap, 3), jnp.int32),
        head=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
        serving_tier=jnp.zeros((topo.num_servers,), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
    )


def num_in_system(s: FifoState) -> jnp.ndarray:
    return s.count + jnp.sum(s.serving_tier > 0).astype(jnp.int32)


def slot_step(s: FifoState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              ancestors: jnp.ndarray):
    del est  # FIFO consults nothing
    anc = loc.as_ancestors(ancestors)
    cap = s.buf.shape[0]
    k_serve, k_perm = jax.random.split(key)
    n_arr = types.shape[0]
    tmk = loc.per_server_rates(true_rates, s.serving_tier.shape[0])

    # 1. Push arrivals (drop when full).
    def push(i, st):
        buf, head, count, drops = st
        fits = active[i] & (count < cap)
        pos = (head + count) % cap
        buf = buf.at[pos].set(jnp.where(fits, types[i], buf[pos]))
        count = count + fits.astype(jnp.int32)
        drops = drops + (active[i] & ~fits).astype(jnp.int32)
        return buf, head, count, drops

    buf, head, count, drops = jax.lax.fori_loop(
        0, n_arr, push, (s.buf, s.head, s.count, s.drops))

    # 2. Service completions at the CURRENT true rates (class stored, rate
    #    re-derived each slot -> scenario drift reaches in-flight tasks).
    done = jax.random.bernoulli(k_serve, tier_rates(s.serving_tier, tmk))
    completions = jnp.sum(done).astype(jnp.int32)
    serving_tier = jnp.where(done, 0, s.serving_tier)

    # 3. Idle servers pop heads in random server order.
    order = jax.random.permutation(k_perm, serving_tier.shape[0])

    def pop(i, st):
        head, count, serving_tier = st
        m = order[i]
        take = (serving_tier[m] == 0) & (count > 0)
        task = buf[head % cap]
        tier = loc.server_tiers(task, anc)[m] + 1  # service class 1..K
        serving_tier = serving_tier.at[m].set(
            jnp.where(take, tier, serving_tier[m]).astype(jnp.int32))
        head = (head + take.astype(jnp.int32)) % cap
        count = count - take.astype(jnp.int32)
        return head, count, serving_tier

    head, count, serving_tier = jax.lax.fori_loop(
        0, serving_tier.shape[0], pop, (head, count, serving_tier))

    return FifoState(buf, head, count, serving_tier, drops), completions


@register_policy
class FifoPolicy(SlotPolicy):
    """Global-FIFO: one shared rate-oblivious queue, idle servers pull in
    arrival order (the Hadoop-default floor every comparison stands on).

    `cap` (ring-buffer bound, a static shape) is the policy option that used
    to be special-cased in the simulator; it now travels in a
    ``PolicyConfig("fifo", {"cap": ...})``, and the drop counter surfaces
    through `extra_metrics`.
    """

    name = "fifo"

    def __init__(self, cap: int = 32_768):
        self.cap = cap

    def init_state(self, topo: loc.Topology, **opts) -> FifoState:
        return init_state(topo, cap=self.cap)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors):
        return slot_step(s, key, types, active, est, true_rates, ancestors)

    def num_in_system(self, s: FifoState) -> jnp.ndarray:
        return num_in_system(s)

    def extra_metrics(self, s: FifoState):
        return {"drops": s.drops.astype(jnp.float32)}

    def telemetry_gauges(self, s: FifoState):
        # one global queue: its depth plus busy servers (tiers resolve
        # only when an idle server pulls the head task)
        return {"queued": s.count.astype(jnp.float32),
                "in_service": jnp.sum(s.serving_tier > 0)
                .astype(jnp.float32)}
