"""JSQ-MaxWeight (paper §3.3; Wang et al. 2016, extended by Xie et al. 2016).

One queue per server, holding tasks *local to that server*.  Routing: JSQ
among the arrival's 3 local queues.  Scheduling: an idle server m serves the
head task of

    argmax_n  rate(m, n) * Q_n(t)

where ``rate(m, n)`` is the estimated rate of the (m, n) pair tier (K=3:
alpha if n == m, beta if same rack, gamma otherwise) — tier-generic through
the `core/locality.py` seam.  The weight uses the scheduler's *estimated*
rates (robustness experiment); the realized service rate uses the true
rates via the (m,n)-relation proxy (exact for n=m; see DESIGN.md §3).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import claiming, locality as loc
from repro.core.policy import SlotPolicy, register_policy


class JsqMwState(NamedTuple):
    q: jnp.ndarray             # (M,) int32 waiting tasks (local to each server)
    serving_tier: jnp.ndarray  # (M,) int32 (m,n)-class in service; 0 idle


def init_state(topo: loc.Topology) -> JsqMwState:
    m = topo.num_servers
    return JsqMwState(jnp.zeros((m,), jnp.int32), jnp.zeros((m,), jnp.int32))


def num_in_system(s: JsqMwState) -> jnp.ndarray:
    return jnp.sum(s.q) + jnp.sum(s.serving_tier > 0)


def slot_step(s: JsqMwState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              ancestors: jnp.ndarray):
    """est: (M, K) per-server estimated rates; server m weighs queues with its
    own estimates est[m].  true_rates: (K,) shared or (M, K) per-server."""
    anc = loc.as_ancestors(ancestors)
    k_route, k_serve, k_claim = jax.random.split(key, 3)
    n_arr = types.shape[0]
    tmk = loc.per_server_rates(true_rates, s.q.shape[0])

    # 1. JSQ routing among each arrival's local servers.
    def body(i, q):
        return claiming.jsq_route_one(q, jax.random.fold_in(k_route, i),
                                      types[i], active[i])
    q = jax.lax.fori_loop(0, n_arr, body, s.q)

    # 2. Service completions at the CURRENT true rates (re-derived from the
    #    stored class each slot, so scenario drift reaches in-flight tasks).
    done = jax.random.bernoulli(
        k_serve, claiming.tier_rates(s.serving_tier, tmk))
    completions = jnp.sum(done).astype(jnp.int32)
    serving_tier = jnp.where(done, 0, s.serving_tier)

    # 3. MaxWeight claims: weighted queue lengths with *estimated* rates.
    sid = jnp.arange(q.shape[0])

    def score_fn(m, qv):
        w = loc.pair_rate(m, sid, anc, est[m])
        return w * qv.astype(jnp.float32)

    def tier_fn(m, n):
        return claiming.pair_tier(m, n, anc)

    q, serving_tier = claiming.claim_loop(q, serving_tier, k_claim,
                                          score_fn, tier_fn)
    return JsqMwState(q, serving_tier), completions


@register_policy
class JsqMaxWeightPolicy(SlotPolicy):
    """JSQ-MaxWeight: join-shortest-queue routing + MaxWeight service over
    the (m, n) pair rates — throughput-optimal but NOT heavy-traffic
    delay-optimal, and the policy the paper shows degrades most under
    rate mis-estimation and drift.
    """

    name = "jsq_maxweight"

    def init_state(self, topo: loc.Topology, **opts) -> JsqMwState:
        return init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors):
        return slot_step(s, key, types, active, est, true_rates, ancestors)

    def num_in_system(self, s: JsqMwState) -> jnp.ndarray:
        return num_in_system(s)

    def telemetry_gauges(self, s: JsqMwState):
        return claiming.telemetry_gauges(s.q, s.serving_tier)
