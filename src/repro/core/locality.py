"""Rack/locality model of a data center (paper §2, System Model).

A data center has ``M`` servers grouped into racks of ``M_R`` servers.  A map
task's data chunk is replicated on 3 servers (its *local* servers); servers
sharing a rack with a local server are *rack-local*; everything else is
*remote*.  Mean service rates are ``alpha > beta > gamma`` for the three
tiers (probability of completing the in-service task in one slot of the
discrete-time model, i.e. geometric service with means 1/alpha etc.).

Capacity (hot-rack traffic).  With a fraction ``p_hot`` of arrivals drawn
with all three local servers inside rack 0 ("hot" types) and the rest
uniform over all servers, the fluid capacity is

    if p_hot * M * alpha <= M_R * alpha:      Lambda* = M * alpha
    else:  Lambda* = (M - M_R + M_R * alpha/gamma)
                     / ((1-p_hot)/alpha + p_hot/gamma)

Derivation: rack-0 servers serve hot tasks locally at ``alpha`` (with
diverse hot types every rack-0 server is local to many hot types, so a
balanced scheduler keeps each on its own local tasks); overflow hot traffic
is served remotely at ``gamma`` by the other racks, which also absorb the
uniform traffic locally at ``alpha``.  Uniform tasks lose nothing by
avoiding rack 0 since any of their (random) local servers serves at
``alpha``.  Setting the other-rack utilisation to one gives the formula.
"""

from __future__ import annotations

import dataclasses
import numbers
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

LOCAL, RACK_LOCAL, REMOTE = 1, 2, 3  # service classes; 0 == idle / none
NUM_REPLICAS = 3  # Hadoop default: each chunk lives on 3 servers


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static rack structure: ``num_servers`` servers in racks of ``servers_per_rack``."""

    num_servers: int
    servers_per_rack: int

    def __post_init__(self):
        if self.num_servers % self.servers_per_rack != 0:
            raise ValueError(
                f"num_servers={self.num_servers} not divisible by "
                f"servers_per_rack={self.servers_per_rack}"
            )
        if self.servers_per_rack < NUM_REPLICAS:
            raise ValueError("need at least 3 servers per rack for hot-rack types")

    @property
    def num_racks(self) -> int:
        return self.num_servers // self.servers_per_rack

    @property
    def rack_of(self) -> np.ndarray:
        """(M,) rack id of each server."""
        return np.arange(self.num_servers) // self.servers_per_rack


@dataclasses.dataclass(frozen=True)
class Rates:
    """Service rates per locality tier (completion prob/slot)."""

    alpha: float = 0.5
    beta: float = 0.45
    gamma: float = 0.25

    def __post_init__(self):
        if not (0 < self.gamma < self.beta < self.alpha <= 1.0):
            raise ValueError(f"need 0 < gamma < beta < alpha <= 1, got {self}")

    @property
    def heavy_traffic_optimal(self) -> bool:
        """Balanced-PANDAS heavy-traffic delay optimality condition (paper §3.2)."""
        return self.beta**2 > self.alpha * self.gamma

    def scaled(self, mult: float) -> "Rates":
        """Mis-estimated rates: all three off by the same multiplier (paper §4)."""
        return Rates(min(self.alpha * mult, 1.0), min(self.beta * mult, 1.0),
                     min(self.gamma * mult, 1.0))

    def as_array(self) -> jnp.ndarray:
        return jnp.array([self.alpha, self.beta, self.gamma], dtype=jnp.float32)


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Arrival process: truncated-Poisson(lam_total) arrivals/slot, each task's
    type = 3 distinct servers sampled from a hot-rack mixture."""

    lam_total: float  # mean arrivals per slot (all types)
    p_hot: float = 0.5  # fraction of tasks whose locals all live in rack 0
    max_arrivals: int = 24  # C_A bound of the paper's model

    def __post_init__(self):
        # lam_total (and, under scenario playback, p_hot) may be a traced
        # JAX value inside jit — validate only host-side numbers.
        if isinstance(self.p_hot, numbers.Real) and \
                not 0.0 <= float(self.p_hot) <= 1.0:
            raise ValueError(f"p_hot must be in [0, 1], got {self.p_hot}")
        if isinstance(self.max_arrivals, numbers.Integral) and \
                self.max_arrivals < 1:
            raise ValueError(
                f"max_arrivals must be >= 1, got {self.max_arrivals}")
        if isinstance(self.lam_total, numbers.Real) and \
                float(self.lam_total) < 0.0:
            raise ValueError(f"lam_total must be >= 0, got {self.lam_total}")


def capacity_hot_rack(topo: Topology, rates: Rates, p_hot: float) -> float:
    """Fluid capacity Lambda* (tasks/slot) for the hot-rack traffic pattern."""
    m, mr = topo.num_servers, topo.servers_per_rack
    a, g = rates.alpha, rates.gamma
    lam_uniform_only = m * a
    if p_hot * lam_uniform_only <= mr * a:  # hot fits in rack 0 locally
        return lam_uniform_only
    return (m - mr + mr * a / g) / ((1.0 - p_hot) / a + p_hot / g)


# ---------------------------------------------------------------------------
# Vectorized locality primitives (jit/vmap friendly)
# ---------------------------------------------------------------------------

def locality_masks(task_locals: jnp.ndarray, rack_of: jnp.ndarray):
    """Per-server local / rack-local masks for one task.

    task_locals: (3,) int32 server ids (the task's replicas)
    rack_of:     (M,) int32 rack id per server
    returns (local_mask, rack_mask): (M,) bool; rack_mask excludes locals.
    """
    m = rack_of.shape[0]
    sid = jnp.arange(m, dtype=task_locals.dtype)
    local = jnp.any(sid[:, None] == task_locals[None, :], axis=1)
    local_racks = rack_of[task_locals]  # (3,)
    in_rack = jnp.any(rack_of[:, None] == local_racks[None, :], axis=1)
    return local, in_rack & ~local


def rate_vector(task_locals: jnp.ndarray, rack_of: jnp.ndarray,
                rates3: jnp.ndarray) -> jnp.ndarray:
    """(M,) per-server service rate for one task under rates3=[a,b,g]."""
    local, rack = locality_masks(task_locals, rack_of)
    return jnp.where(local, rates3[0], jnp.where(rack, rates3[1], rates3[2]))


def class_of(task_locals: jnp.ndarray, rack_of: jnp.ndarray,
             server: jnp.ndarray) -> jnp.ndarray:
    """Service class (LOCAL/RACK_LOCAL/REMOTE) of `server` for this task."""
    local, rack = locality_masks(task_locals, rack_of)
    return jnp.where(local[server], LOCAL,
                     jnp.where(rack[server], RACK_LOCAL, REMOTE)).astype(jnp.int32)


def pair_rate(m: jnp.ndarray, n: jnp.ndarray, rack_of: jnp.ndarray,
              rates3: jnp.ndarray) -> jnp.ndarray:
    """(m,n)-relation proxy rate: server m pulling from server n's local queue.

    alpha if m == n, beta if same rack, gamma otherwise.  Used by JSQ-MW /
    Priority both as the MaxWeight weight (with estimated rates) and as the
    simulated service rate (with true rates); see DESIGN.md §3 for the O(1/M)
    fidelity note.
    """
    return jnp.where(m == n, rates3[0],
                     jnp.where(rack_of[m] == rack_of[n], rates3[1], rates3[2]))


def sample_task_types_at(key: jax.Array, rack_of: jnp.ndarray, p_hot,
                         hot_rack, batch: int) -> jnp.ndarray:
    """Sample `batch` task types: (batch, 3) int32, 3 distinct servers each.

    Hot tasks (prob `p_hot`) draw all replicas from rack `hot_rack`; the
    rest uniformly from all servers.  Uses Gumbel top-k for
    without-replacement sampling.  `p_hot` and `hot_rack` may be traced
    per-slot scenario knobs; for p_hot equal to the config constant and
    hot_rack == 0 the draws are bitwise identical to the static model
    (common random numbers across scenarios).
    """
    m = rack_of.shape[0]
    k_hot, k_gum = jax.random.split(key)
    hot = jax.random.bernoulli(k_hot, p_hot, (batch,))
    in_hot_rack = rack_of == hot_rack  # (m,)
    logits = jnp.where(
        hot[:, None],
        jnp.where(in_hot_rack[None, :], 0.0, -jnp.inf),
        jnp.zeros((1, m)),
    )
    gumbel = jax.random.gumbel(k_gum, (batch, m))
    _, idx = jax.lax.top_k(logits + gumbel, NUM_REPLICAS)
    return jnp.sort(idx, axis=1).astype(jnp.int32)  # canonical m1<m2<m3


def sample_task_types(key: jax.Array, topo: Topology, traffic: Traffic,
                      batch: int) -> jnp.ndarray:
    """Static-traffic wrapper over `sample_task_types_at` (hot rack 0)."""
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    return sample_task_types_at(key, rack_of, traffic.p_hot, jnp.int32(0),
                                batch)


def sample_arrivals_at(key: jax.Array, rack_of: jnp.ndarray, lam, p_hot,
                       hot_rack, max_arrivals: int):
    """One slot of arrivals under (possibly traced) per-slot scenario knobs:
    returns (types (C_A,3) int32, active (C_A,) bool)."""
    k_n, k_t = jax.random.split(key)
    n = jnp.minimum(jax.random.poisson(k_n, lam), max_arrivals)
    active = jnp.arange(max_arrivals) < n
    types = sample_task_types_at(k_t, rack_of, p_hot, hot_rack, max_arrivals)
    return types, active


def sample_arrivals(key: jax.Array, topo: Topology, traffic: Traffic):
    """Static-traffic wrapper over `sample_arrivals_at` (hot rack 0)."""
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    return sample_arrivals_at(key, rack_of, traffic.lam_total, traffic.p_hot,
                              jnp.int32(0), traffic.max_arrivals)


def per_server_rates(rates: jnp.ndarray, num_servers: int) -> jnp.ndarray:
    """Broadcast true service rates to per-server form: (M, 3).

    Accepts the classic shared ``(3,)`` vector or an ``(M, 3)`` matrix (the
    scenario subsystem's per-server fault injection).  Policies normalize
    through this one helper, so the simulator can feed either with zero
    per-scenario branching.
    """
    r = jnp.asarray(rates, jnp.float32).reshape((-1, 3))
    return jnp.broadcast_to(r, (num_servers, 3))


def random_argmin(key: jax.Array, score: jnp.ndarray) -> jnp.ndarray:
    """argmin with uniform random tie-breaking among exact minima (paper: ties
    are broken randomly)."""
    is_min = score == jnp.min(score)
    g = jax.random.gumbel(key, score.shape)
    return jnp.argmax(jnp.where(is_min, g, -jnp.inf)).astype(jnp.int32)


def random_argmax(key: jax.Array, score: jnp.ndarray) -> jnp.ndarray:
    is_max = score == jnp.max(score)
    g = jax.random.gumbel(key, score.shape)
    return jnp.argmax(jnp.where(is_max, g, -jnp.inf)).astype(jnp.int32)
