"""Tier-generic locality model of a data center (paper §2, System Model).

The paper instantiates a 3-tier hierarchy — ``M`` servers grouped into
racks, with mean service rates ``alpha > beta > gamma`` for local /
rack-local / remote service — but states the general fact outright: the
number of switches in the path of a data transfer depends on the internal
network structure of the data center.  This module is the tier-generic
core every layer derives from:

  * `Topology` — a K-level hierarchy (server -> rack -> pod -> ... ->
    root).  ``Topology(24, 6)`` is the paper's flat-rack default (K = 3
    tiers); ``Topology(24, (4, 12))`` adds a pod level (racks of 4 inside
    pods of 12 servers, K = 4); heterogeneous group sizes are allowed
    (``Topology(24, ((6, 6, 4, 4, 4),))``).  The normalized form is an
    **ancestor table**: a ``(depth, M)`` array whose row ``l`` holds each
    server's group id at level ``l`` (level 0 = rack).
  * `Rates` — a strictly-decreasing ``(K,)`` service-rate vector
    (completion prob/slot of the discrete-time model); ``Rates(a, b, g)``
    keeps the classic 3-tier spelling and ``.alpha``/``.beta``/``.gamma``
    remain as views of ``values[0]``/``values[1]``/``values[-1]``.
  * tier seam — `server_tiers` / `tier_masks` / `pair_tiers` map
    (task, server) and (server, server) relations onto tier indices
    ``0..K-1`` (0 = local, K-1 = remote); every policy, kernel and host
    router derives its locality logic from these.

Capacity (hot-rack traffic).  With a fraction ``p_hot`` of arrivals drawn
with all three local servers inside one rack ("hot" types) and the rest
uniform over all servers, the K-tier fluid capacity is the greedy
water-filling over tier pools: the hot rack serves hot tasks at
``rates[0]`` (with diverse hot types a balanced scheduler keeps each
rack server on its own local tasks), overflow hot traffic spills to the
tier-2 pool at ``rates[2]``, then tier-3, ...; uniform tasks are served
locally at ``rates[0]`` anywhere.  Setting the utilisation of the
partially-filled pool's regime to one gives, for the regime in which
pools ``i < j`` are hot-saturated,

    Lambda_j = (M - sum_{i<j} n_i + sum_{i<j} n_i r_i / r_j)
               / (p_hot / r_j + (1 - p_hot) / rates[0])

and the capacity is the unique consistent regime (K = 3 recovers the
closed form the seed shipped; validated against a brute-force LP in
tests/test_topology.py).
"""

from __future__ import annotations

import dataclasses
import numbers
from functools import lru_cache
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

LOCAL, RACK_LOCAL, REMOTE = 1, 2, 3  # K=3 service classes; 0 == idle / none
NUM_REPLICAS = 3  # Hadoop default: each chunk lives on 3 servers

# One hierarchy level: a uniform group size (int, in servers) or explicit
# per-group sizes (heterogeneous, must tile the fleet).
LevelSpec = Union[int, Sequence[int]]


def _normalize_levels(num_servers: int, spec) -> Tuple[Tuple[int, ...], ...]:
    """Canonical per-level group-size tuples for a `Topology` spec.

    `spec` is the legacy rack size (int), or a sequence of `LevelSpec`s
    ordered from the finest grouping (racks) outward (pods, cores, ...).
    Every level must tile ``num_servers`` exactly and nest inside the next
    (each pod is a union of whole racks) — the validation the retired
    host-side ``ClusterSpec`` never did.
    """
    if isinstance(spec, numbers.Integral):
        spec = (int(spec),)
    levels = []
    for li, level in enumerate(spec):
        if isinstance(level, numbers.Integral):
            size = int(level)
            if size < 1 or num_servers % size != 0:
                raise ValueError(
                    f"level {li}: group size {size} does not tile "
                    f"num_servers={num_servers}")
            sizes = (size,) * (num_servers // size)
        else:
            sizes = tuple(int(s) for s in level)
            if any(s < 1 for s in sizes):
                raise ValueError(f"level {li}: group sizes must be >= 1, "
                                 f"got {sizes}")
            if sum(sizes) != num_servers:
                raise ValueError(
                    f"level {li}: group sizes {sizes} sum to {sum(sizes)}, "
                    f"do not tile num_servers={num_servers}")
        levels.append(sizes)
    # nesting: every group boundary at level l+1 must align with level l
    for li in range(1, len(levels)):
        inner = np.cumsum(levels[li - 1])
        outer = np.cumsum(levels[li])
        if not set(outer).issubset(set(inner)):
            raise ValueError(
                f"level {li} groups {levels[li]} do not nest on level "
                f"{li - 1} boundaries {levels[li - 1]}")
        if len(levels[li]) >= len(levels[li - 1]):
            raise ValueError(
                f"level {li} must coarsen level {li - 1}: "
                f"{len(levels[li])} groups vs {len(levels[li - 1])}")
    return tuple(levels)


@lru_cache(maxsize=64)
def _ancestor_table(num_servers: int,
                    levels: Tuple[Tuple[int, ...], ...]) -> np.ndarray:
    """(depth, M) int32 ancestor-group id per server per level."""
    table = np.empty((len(levels), num_servers), np.int32)
    for li, sizes in enumerate(levels):
        table[li] = np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)
    table.setflags(write=False)
    return table


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static K-level hierarchy of ``num_servers`` servers.

    ``group_sizes`` orders the levels from finest (racks) outward; each
    entry is a uniform size in servers or explicit per-group sizes.  The
    number of locality tiers is ``depth + 2`` (local, one per level,
    remote): ``Topology(M, g)`` is the classic 3-tier rack model,
    ``Topology(M, ())`` a flat 2-tier fleet, ``Topology(M, (g, p))`` a
    4-tier fat-tree pod topology.
    """

    num_servers: int
    group_sizes: Union[int, Sequence[LevelSpec]] = ()

    def __post_init__(self):
        if self.num_servers < 1:
            raise ValueError(f"need num_servers >= 1, got {self.num_servers}")
        levels = _normalize_levels(self.num_servers, self.group_sizes)
        object.__setattr__(self, "group_sizes", levels)

    # -- structure ----------------------------------------------------------
    @property
    def depth(self) -> int:
        """Hierarchy levels above the server (1 for the flat-rack model)."""
        return len(self.group_sizes)

    @property
    def num_tiers(self) -> int:
        """K: local + one tier per level + remote."""
        return self.depth + 2

    @property
    def ancestors(self) -> np.ndarray:
        """(depth, M) int32 ancestor-group id of each server at each level
        (level 0 = rack) — the normalized form every consumer derives
        tiers from."""
        return _ancestor_table(self.num_servers, self.group_sizes)

    def groups_at(self, level: int) -> Tuple[int, ...]:
        """Group sizes (in servers) at hierarchy `level` (0 = rack)."""
        return self.group_sizes[level]

    # -- rack-level views (level 0; the paper's vocabulary) -----------------
    @property
    def num_racks(self) -> int:
        return len(self.group_sizes[0]) if self.depth else 1

    @property
    def rack_of(self) -> np.ndarray:
        """(M,) rack id of each server (all zero for a depth-0 fleet)."""
        if self.depth:
            return self.ancestors[0]
        return np.zeros(self.num_servers, np.int32)

    @property
    def servers_per_rack(self) -> int:
        """Uniform rack size (raises for heterogeneous racks)."""
        sizes = set(self.group_sizes[0]) if self.depth \
            else {self.num_servers}
        if len(sizes) != 1:
            raise ValueError(f"racks are heterogeneous: "
                             f"{self.group_sizes[0]}; use groups_at(0)")
        return next(iter(sizes))

    @property
    def min_rack_size(self) -> int:
        return min(self.group_sizes[0]) if self.depth else self.num_servers

    # -- legacy host-side aliases (the retired ClusterSpec vocabulary) ------
    @property
    def num_workers(self) -> int:
        return self.num_servers

    @property
    def pod_of(self) -> np.ndarray:
        return self.rack_of


class Rates:
    """Strictly-decreasing service rates per locality tier
    (completion prob/slot): ``Rates(alpha, beta, gamma)`` or
    ``Rates((r0, r1, ..., r_{K-1}))``."""

    __slots__ = ("values",)

    def __init__(self, *values):
        if not values:
            values = (0.5, 0.45, 0.25)  # the paper's defaults
        elif len(values) == 1 and not isinstance(values[0], numbers.Real):
            values = tuple(values[0])
        values = tuple(float(v) for v in values)
        if len(values) < 2:
            raise ValueError(f"need >= 2 tier rates, got {values}")
        ok = all(0.0 < v <= 1.0 for v in values) and \
            all(a > b for a, b in zip(values, values[1:]))
        if not ok:
            raise ValueError(f"need 1 >= r0 > r1 > ... > r_K-1 > 0, "
                             f"got {self.__class__.__name__}{values}")
        object.__setattr__(self, "values", values)

    def __setattr__(self, name, value):  # frozen, like the old dataclass
        raise dataclasses.FrozenInstanceError(f"cannot assign to {name!r}")

    @property
    def num_tiers(self) -> int:
        return len(self.values)

    # classic 3-tier spellings (alpha fastest, gamma slowest)
    @property
    def alpha(self) -> float:
        return self.values[0]

    @property
    def beta(self) -> float:
        return self.values[1]

    @property
    def gamma(self) -> float:
        return self.values[-1]

    @property
    def heavy_traffic_optimal(self) -> bool:
        """Balanced-PANDAS heavy-traffic delay optimality condition (paper
        §3.2), on the (fastest, second, slowest) tiers."""
        return self.values[1] ** 2 > self.values[0] * self.values[-1]

    def scaled(self, mult: float) -> "Rates":
        """Mis-estimated rates: every tier off by the same multiplier
        (paper §4); clamped into (0, 1] and re-validated."""
        return Rates(tuple(min(v * mult, 1.0) for v in self.values))

    def as_array(self) -> jnp.ndarray:
        return jnp.array(self.values, dtype=jnp.float32)

    def __repr__(self) -> str:
        return f"Rates{self.values}"

    def __eq__(self, other) -> bool:
        return isinstance(other, Rates) and self.values == other.values

    def __hash__(self) -> int:
        return hash(("Rates", self.values))


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Arrival process: truncated-Poisson(lam_total) arrivals/slot, each task's
    type = 3 distinct servers sampled from a hot-rack mixture."""

    lam_total: float  # mean arrivals per slot (all types)
    p_hot: float = 0.5  # fraction of tasks whose locals all live in rack 0
    max_arrivals: int = 24  # C_A bound of the paper's model

    def __post_init__(self):
        # lam_total (and, under scenario playback, p_hot) may be a traced
        # JAX value inside jit — validate only host-side numbers.
        if isinstance(self.p_hot, numbers.Real) and \
                not 0.0 <= float(self.p_hot) <= 1.0:
            raise ValueError(f"p_hot must be in [0, 1], got {self.p_hot}")
        if isinstance(self.max_arrivals, numbers.Integral) and \
                self.max_arrivals < 1:
            raise ValueError(
                f"max_arrivals must be >= 1, got {self.max_arrivals}")
        if isinstance(self.lam_total, numbers.Real) and \
                float(self.lam_total) < 0.0:
            raise ValueError(f"lam_total must be >= 0, got {self.lam_total}")


# ---------------------------------------------------------------------------
# K-tier fluid capacity (hot-rack traffic)
# ---------------------------------------------------------------------------


def hot_rack_tiers(topo: Topology, hot_rack: int = 0) -> np.ndarray:
    """(M,) tier of each server w.r.t. a task local to rack ``hot_rack``.

    Rack members come out as tier <= 1 (they serve hot tasks at
    ``rates[0]`` under the balanced-scheduler argument in the module
    docstring); everyone else at the tier of their deepest shared group.
    """
    anc = topo.ancestors
    reps = np.flatnonzero(topo.rack_of == hot_rack)
    if reps.size == 0:
        raise ValueError(f"hot_rack={hot_rack} is empty "
                         f"(topology has {topo.num_racks} racks)")
    tier = np.full(topo.num_servers, topo.num_tiers - 1, np.int64)
    for lvl in range(topo.depth - 1, -1, -1):
        tier[np.isin(anc[lvl], np.unique(anc[lvl][reps]))] = lvl + 1
    return tier


def capacity_hot_rack(topo: Topology, rates: Union[Rates, Sequence[float]],
                      p_hot: float, hot_rack: int = 0) -> float:
    """K-tier fluid capacity Lambda* (tasks/slot) for the hot-rack pattern.

    Greedy water-filling over tier pools (see module docstring); for the
    3-tier rack model this reproduces the seed's closed form exactly, and
    tests/test_topology.py checks it against a brute-force LP at
    K = 2, 3, 4 including heterogeneous rack sizes.
    """
    r = np.asarray(rates.values if isinstance(rates, Rates) else rates,
                   np.float64)
    k = r.size
    if k != topo.num_tiers:
        raise ValueError(f"rates have {k} tiers but topology has "
                         f"{topo.num_tiers}")
    m = topo.num_servers
    if p_hot <= 0.0:
        return float(m * r[0])
    tier = hot_rack_tiers(topo, hot_rack)
    pools = [(float(r[0]), int(np.sum(tier <= 1)))]
    pools += [(float(r[lvl]), int(np.sum(tier == lvl)))
              for lvl in range(2, k) if np.sum(tier == lvl) > 0]
    used_n = 0.0   # servers in hot-saturated pools
    used_c = 0.0   # hot service capacity of those pools
    for rate_j, n_j in pools:
        lam = (m - used_n + used_c / rate_j) \
            / (p_hot / rate_j + (1.0 - p_hot) / r[0])
        x_j = p_hot * lam - used_c  # hot traffic landing in pool j
        if -1e-9 <= x_j <= n_j * rate_j + 1e-9:
            return float(lam)
        used_n += n_j
        used_c += n_j * rate_j
    raise AssertionError("no consistent fluid regime found")  # unreachable


# ---------------------------------------------------------------------------
# Vectorized tier primitives (jit/vmap friendly) — the seam every consumer
# (policies, kernels, simulator) derives locality from
# ---------------------------------------------------------------------------


def as_ancestors(x: jnp.ndarray) -> jnp.ndarray:
    """Normalize a legacy (M,) rack map to a (depth, M) ancestor table."""
    a = jnp.asarray(x, jnp.int32)
    return a[None, :] if a.ndim == 1 else a


def server_tiers(task_locals: jnp.ndarray,
                 ancestors: jnp.ndarray) -> jnp.ndarray:
    """(M,) tier index 0..K-1 of every server for one task.

    task_locals: (3,) int32 server ids (the task's replicas)
    ancestors:   (depth, M) int32 table (or legacy (M,) rack map)
    """
    anc = as_ancestors(ancestors)
    d, m = anc.shape
    tier = jnp.full((m,), d + 1, jnp.int32)
    for lvl in range(d - 1, -1, -1):
        row = anc[lvl]
        share = jnp.any(row[:, None] == row[task_locals][None, :], axis=1)
        tier = jnp.where(share, lvl + 1, tier)
    sid = jnp.arange(m, dtype=task_locals.dtype)
    local = jnp.any(sid[:, None] == task_locals[None, :], axis=1)
    return jnp.where(local, 0, tier)


def tier_masks(task_locals: jnp.ndarray, ancestors: jnp.ndarray) -> jnp.ndarray:
    """(K, M) bool one-hot tier masks for one task (row k: servers at tier k)."""
    anc = as_ancestors(ancestors)
    tiers = server_tiers(task_locals, anc)
    k = anc.shape[0] + 2
    return tiers[None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]


def locality_masks(task_locals: jnp.ndarray, rack_of: jnp.ndarray):
    """Legacy 3-tier view: (local_mask, rack_mask) over (M,) servers;
    rack_mask excludes locals.  Derived from `server_tiers`."""
    tiers = server_tiers(task_locals, rack_of)
    return tiers == 0, tiers == 1


def rate_vector(task_locals: jnp.ndarray, ancestors: jnp.ndarray,
                rates_k: jnp.ndarray) -> jnp.ndarray:
    """(M,) per-server service rate for one task under a (K,) rate vector."""
    return jnp.asarray(rates_k)[server_tiers(task_locals, ancestors)]


def class_of(task_locals: jnp.ndarray, ancestors: jnp.ndarray,
             server: jnp.ndarray) -> jnp.ndarray:
    """Service class 1..K (LOCAL/RACK_LOCAL/.../REMOTE) of `server`."""
    return (server_tiers(task_locals, ancestors)[server] + 1).astype(jnp.int32)


def pair_tiers(m: jnp.ndarray, n: jnp.ndarray,
               ancestors: jnp.ndarray) -> jnp.ndarray:
    """(m,n)-relation tier index 0..K-1: 0 if m == n, else 1 + deepest
    shared level, else K-1.  Broadcasts over m/n."""
    anc = as_ancestors(ancestors)
    d = anc.shape[0]
    tier = jnp.full(jnp.broadcast_shapes(jnp.shape(m), jnp.shape(n)), d + 1,
                    jnp.int32)
    for lvl in range(d - 1, -1, -1):
        tier = jnp.where(anc[lvl][m] == anc[lvl][n], lvl + 1, tier)
    return jnp.where(m == n, 0, tier)


def pair_rate(m: jnp.ndarray, n: jnp.ndarray, ancestors: jnp.ndarray,
              rates_k: jnp.ndarray) -> jnp.ndarray:
    """(m,n)-relation proxy rate: server m pulling from server n's local
    queue, at the rate of their pair tier.  Used by JSQ-MW / Priority both
    as the MaxWeight weight (with estimated rates) and as the simulated
    service rate (with true rates); see DESIGN.md §3 for the O(1/M)
    fidelity note."""
    return jnp.asarray(rates_k)[pair_tiers(m, n, ancestors)]


def sample_task_types_at(key: jax.Array, rack_of: jnp.ndarray, p_hot,
                         hot_rack, batch: int,
                         rack_weights: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
    """Sample `batch` task types: (batch, 3) int32, 3 distinct servers each.

    Hot tasks (prob `p_hot`) draw all replicas from one rack — `hot_rack`
    when `rack_weights` is None, else a rack drawn per task from the
    (R,) arrival-weight vector (the per-rack skew knob); the rest
    uniformly from all servers.  Uses Gumbel top-k for
    without-replacement sampling.  `p_hot`, `hot_rack` and `rack_weights`
    may be traced per-slot scenario knobs; with `rack_weights is None`,
    p_hot equal to the config constant and hot_rack == 0 the draws are
    bitwise identical to the static model (common random numbers across
    scenarios — the weighted path splits the key differently and only
    activates when a segment opts into weights).
    """
    m = rack_of.shape[0]
    if rack_weights is None:
        k_hot, k_gum = jax.random.split(key)
        hot_racks = jnp.broadcast_to(jnp.asarray(hot_rack, jnp.int32),
                                     (batch,))
    else:
        k_hot, k_rack, k_gum = jax.random.split(key, 3)
        logw = jnp.log(jnp.asarray(rack_weights, jnp.float32))
        hot_racks = jax.random.categorical(k_rack, logw, shape=(batch,)
                                           ).astype(jnp.int32)
    hot = jax.random.bernoulli(k_hot, p_hot, (batch,))
    in_hot_rack = rack_of[None, :] == hot_racks[:, None]  # (batch, m)
    logits = jnp.where(
        hot[:, None],
        jnp.where(in_hot_rack, 0.0, -jnp.inf),
        jnp.zeros((1, m)),
    )
    gumbel = jax.random.gumbel(k_gum, (batch, m))
    _, idx = jax.lax.top_k(logits + gumbel, NUM_REPLICAS)
    return jnp.sort(idx, axis=1).astype(jnp.int32)  # canonical m1<m2<m3


def sample_task_types(key: jax.Array, topo: Topology, traffic: Traffic,
                      batch: int) -> jnp.ndarray:
    """Static-traffic wrapper over `sample_task_types_at` (hot rack 0)."""
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    return sample_task_types_at(key, rack_of, traffic.p_hot, jnp.int32(0),
                                batch)


def sample_arrivals_at(key: jax.Array, rack_of: jnp.ndarray, lam, p_hot,
                       hot_rack, max_arrivals: int,
                       rack_weights: Optional[jnp.ndarray] = None,
                       type_sampler=None):
    """One slot of arrivals under (possibly traced) per-slot scenario knobs:
    returns (types (C_A,3) int32, active (C_A,) bool).

    `type_sampler` is the replica-placement seam (`repro.placement`): a
    compiled ``sample(key, p_hot, hot_rack, batch, rack_weights)`` that
    replaces the default i.i.d.-uniform draw.  The arrival *count* stream
    (k_n) is split off first either way, so every placement sees the same
    offered traffic (common random numbers across placements)."""
    k_n, k_t = jax.random.split(key)
    n = jnp.minimum(jax.random.poisson(k_n, lam), max_arrivals)
    active = jnp.arange(max_arrivals) < n
    if type_sampler is None:
        types = sample_task_types_at(k_t, rack_of, p_hot, hot_rack,
                                     max_arrivals, rack_weights)
    else:
        types = type_sampler(k_t, p_hot, hot_rack, max_arrivals, rack_weights)
    return types, active


def sample_arrivals(key: jax.Array, topo: Topology, traffic: Traffic):
    """Static-traffic wrapper over `sample_arrivals_at` (hot rack 0)."""
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    return sample_arrivals_at(key, rack_of, traffic.lam_total, traffic.p_hot,
                              jnp.int32(0), traffic.max_arrivals)


def per_server_rates(rates: jnp.ndarray, num_servers: int) -> jnp.ndarray:
    """Broadcast true service rates to per-server form: (M, K).

    Accepts the shared ``(K,)`` vector or an ``(M, K)`` matrix (the
    scenario subsystem's per-server fault injection); K is inferred from
    the input.  Policies normalize through this one helper, so the
    simulator can feed either with zero per-scenario branching.
    """
    r = jnp.asarray(rates, jnp.float32)
    r = r[None, :] if r.ndim == 1 else r
    return jnp.broadcast_to(r, (num_servers, r.shape[-1]))


def random_argmin(key: jax.Array, score: jnp.ndarray) -> jnp.ndarray:
    """argmin with uniform random tie-breaking among exact minima (paper: ties
    are broken randomly)."""
    is_min = score == jnp.min(score)
    g = jax.random.gumbel(key, score.shape)
    return jnp.argmax(jnp.where(is_min, g, -jnp.inf)).astype(jnp.int32)


def random_argmax(key: jax.Array, score: jnp.ndarray) -> jnp.ndarray:
    is_max = score == jnp.max(score)
    g = jax.random.gumbel(key, score.shape)
    return jnp.argmax(jnp.where(is_max, g, -jnp.inf)).astype(jnp.int32)
