"""Power-of-d-choices Balanced-PANDAS (``pandas_po2``).

A genuinely new point of comparison added through the unified policy
registry alone (no simulator or engine edits): instead of scanning all M
servers per arrival, the router samples ``d`` candidate servers uniformly
at random and compares weighted workloads only over the candidate set plus
the task's 3 local servers.  This is the affinity-scheduling reading of the
power-of-d-choices idea (Mitzenmacher 2001; Kavousi 2017, arXiv:1705.03125
for the locality-aware line): locals are always candidates — dropping them
would send almost every task remote at small d, which no locality-aware
sampler would do — and the d uniform samples provide the "second choice"
pressure that spills load off a hot rack.

Queueing structure, service dynamics and idle-server scheduling are exactly
Balanced-PANDAS (`core/balanced_pandas.py`); only the arrival routing rule
differs.  At d >= M the candidate set is the whole fleet and the score
surface coincides with full Balanced-PANDAS, so every decision is drawn
from the same score-minimal set — but tie-breaks use differently-split RNG
keys, so sample paths are not bitwise identical (the cross-check tests pin
score-level agreement per decision and statistical agreement on delays).
On the host path (`core/cluster.py::PandasPoDRouter`)
routing cost drops from O(M) to O(d): the interesting trade in the
robustness figures is how much heavy-traffic delay that buys back.

Like the full-scan policy, the *scheduler* sees estimated rates ``est``
while service runs at the true rates — so `pandas_po2` joins the
robustness-under-mis-estimation study as a rate-aware arm.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import balanced_pandas as bp
from repro.core import locality as loc
from repro.core.policy import SlotPolicy, register_policy


def route_one_po_d(s: bp.PandasState, key: jax.Array, task: jnp.ndarray,
                   active: jnp.ndarray, est: jnp.ndarray,
                   ancestors: jnp.ndarray, d: int) -> bp.PandasState:
    """Route one arrival over {3 locals} ∪ {d uniform samples}.

    Same score (W/rate with the infinitesimal faster-tier preference, see
    `bp.route_one`) restricted to the candidate mask; non-candidates score
    +inf so `random_argmin` never picks them.
    """
    anc = loc.as_ancestors(ancestors)
    m = anc.shape[1]
    k_cand, k_tie = jax.random.split(key)
    sampled = jax.random.choice(k_cand, m, (min(d, m),), replace=False)
    tier_m = loc.server_tiers(task, anc)
    cand = (tier_m == 0) | jnp.zeros((m,), bool).at[sampled].set(True)
    est_rate = jnp.take_along_axis(est, tier_m[:, None], axis=1)[:, 0]
    score = bp.workload(s, est) / est_rate - est_rate * 1e-6
    score = jnp.where(cand, score, jnp.inf)
    m_star = loc.random_argmin(k_tie, score)
    return bp.push_task(s, m_star, tier_m, active)


def slot_step(s: bp.PandasState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              ancestors: jnp.ndarray, d: int = 2):
    """One slot: po-d arrival routing, then shared PANDAS service/schedule."""
    anc = loc.as_ancestors(ancestors)
    k_route, k_serve = jax.random.split(key)
    n_arr = types.shape[0]

    def body(i, st):
        return route_one_po_d(st, jax.random.fold_in(k_route, i), types[i],
                              active[i], est, anc, d)
    s = jax.lax.fori_loop(0, n_arr, body, s)

    return bp.serve_and_schedule(s, k_serve, true_rates)


@register_policy
class PandasPoDPolicy(SlotPolicy):
    """Power-of-d Balanced-PANDAS: score only the task's 3 locals plus d
    sampled candidates instead of all M servers — O(d) routing that
    trades a little exact-rate delay for a narrower error band.

    ``d`` is a static option (it shapes the candidate sample) carried by
    ``PolicyConfig("pandas_po2", {"d": ...})``; default 2, the classic
    power-of-two choices.
    """

    name = "pandas_po2"

    def __init__(self, d: int = 2):
        if d < 1:
            raise ValueError(f"need d >= 1 candidate samples, got {d}")
        self.d = d

    def init_state(self, topo: loc.Topology, **opts) -> bp.PandasState:
        return bp.init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors):
        return slot_step(s, key, types, active, est, true_rates, ancestors,
                         d=self.d)

    def num_in_system(self, s: bp.PandasState) -> jnp.ndarray:
        return bp.num_in_system(s)

    def telemetry_gauges(self, s: bp.PandasState):
        return bp.telemetry_gauges(s)
