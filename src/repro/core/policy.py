"""Unified SchedulerPolicy API: one registry for the JAX slot-policies and
the host-side routers.

The paper is a *comparison* of scheduling algorithms, and the affinity-
scheduling line of work keeps producing new locality-aware variants worth
slotting into the same harness.  This module is the single seam every
algorithm lands on:

  * `SlotPolicy` — the discrete-time simulator contract.  A policy owns a
    fixed-shape JAX state pytree and advances it one slot at a time inside
    `jax.lax.scan`; the simulator (`core/simulator.py`) never needs to know
    which algorithm it is running.  Per-policy constructor options (FIFO's
    buffer `cap`, power-of-d's `d`) travel in a `PolicyConfig`; per-policy
    outputs (FIFO's drop counter) come back through `extra_metrics`.

  * `Router` — the host-side (numpy, incremental) contract used on the
    critical path of the serving engine and the data pipeline.  All routers
    speak the same `route(locals_) -> Decision` / `claim(worker) -> Claim`
    language, so `serve/engine.py` and `data/pipeline.py` drive any of them
    through one code path: a `Decision` says where the task went (or that
    assignment is deferred to claim time), a `Claim` says which queue an
    idle worker just pulled from.

Both registries are populated by the `@register_policy` / `@register_router`
decorators at the definition site of each algorithm, so adding a scheduler
is one module with two decorated classes — it is then instantly available
to the simulator sweep, the robustness study, the serving engine, the data
pipeline, and the benchmarks.  `pandas_po2` (power-of-d-choices
Balanced-PANDAS, `core/pandas_po2.py`) is the proof: it was added through
the registry alone.
"""

from __future__ import annotations

import abc
import dataclasses
import importlib
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Shared routing dataclasses (host side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of `Router.route`: where an arriving task went.

    worker   -- assigned worker id, or -1 when assignment is deferred
    tier     -- locality tier (0 local .. K-1 remote) at the assigned
                worker, or -1 when deferred / unknown at routing time
    deferred -- True when the router queues globally and picks the worker
                only at claim time (e.g. FIFO)
    """

    worker: int
    tier: int = -1
    deferred: bool = False


@dataclasses.dataclass(frozen=True)
class Claim:
    """Outcome of `Router.claim`: what an idle worker just pulled.

    source -- index of the queue the task came from: a worker id for
              per-worker-queue routers (the claimer's own queue, or another
              worker's under MaxWeight work stealing), or -1 for a global
              queue (FIFO)
    tier   -- the router's belief of the service tier for this claim, or -1
              when it cannot know (global queue: depends on the task)
    """

    source: int
    tier: int = -1


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Name + per-policy constructor options, e.g.
    ``PolicyConfig("fifo", {"cap": 4096})`` or
    ``PolicyConfig("pandas_po2", {"d": 4})``."""

    name: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


PolicyLike = Union[str, PolicyConfig, "SlotPolicy"]


# ---------------------------------------------------------------------------
# SlotPolicy: the JAX discrete-time simulator contract
# ---------------------------------------------------------------------------


class SlotPolicy(abc.ABC):
    """One scheduling algorithm as seen by the discrete-time simulator.

    Implementations are stateless objects over an immutable options set
    (constructor kwargs); all mutable simulation state lives in the pytree
    returned by `init_state` and threaded through `slot_step` by the
    simulator's `lax.scan`.
    """

    name: str = ""
    #: whether slot_step accepts a ``server_mask=`` kwarg ((M,) bool,
    #: True = routable) — the autoscaling seam (`repro.control`): masked
    #: servers take no NEW work but keep draining their queues.
    supports_server_mask: bool = False
    #: whether slot_step accepts a ``signals=`` kwarg of in-scan telemetry
    #: readings (SLO-conditioned policies).  Such policies are the
    #: documented exception to the telemetry-purity invariant: enabling
    #: telemetry deliberately changes their sample path.  Without signals
    #: they must degrade to a signal-free base policy bitwise.
    uses_signals: bool = False

    @abc.abstractmethod
    def init_state(self, topo, **opts):
        """Fresh fixed-shape state pytree for `topo`."""

    @abc.abstractmethod
    def slot_step(self, state, key: jax.Array, types: jnp.ndarray,
                  active: jnp.ndarray, est: jnp.ndarray,
                  true_rates: jnp.ndarray, ancestors: jnp.ndarray):
        """One time slot: arrivals -> completions -> scheduling.

        types/active: the slot's (C_A, 3)/(C_A,) arrival batch; est: (M, K)
        *estimated* rates the scheduler decides with; true_rates: the rates
        the service dynamics use — the shared (K,) vector, or (M, K)
        per-server under scenario fault injection (stragglers, congestion);
        policies normalize via `locality.per_server_rates`.  `ancestors` is
        the topology's (depth, M) ancestor table (policies accept the
        legacy (M,) rack map too, via `locality.as_ancestors`).  Returns
        (state, completions int32).
        """

    @abc.abstractmethod
    def num_in_system(self, state) -> jnp.ndarray:
        """Total tasks present (queued + in service), int32 scalar."""

    def extra_metrics(self, state) -> Dict[str, jnp.ndarray]:
        """Per-policy end-of-run scalars (e.g. FIFO drop count); keys are
        merged into the simulator's metrics dict."""
        return {}

    def telemetry_gauges(self, state) -> Dict[str, jnp.ndarray]:
        """Per-slot scalar gauges for the telemetry time series
        (`repro.telemetry`): queue/occupancy readings off the live state,
        one value per track name.  Must be pure observation — no RNG, no
        state mutation — and fixed-keyed (the track list is resolved once
        at trace time).  Default: no per-policy tracks."""
        return {}


# ---------------------------------------------------------------------------
# Router: the host-side incremental contract
# ---------------------------------------------------------------------------


class Router(abc.ABC):
    """Incremental host-side scheduler over an abstract worker fleet.

    Uniform constructor: (spec, rates, estimator=None, seed=0).  `spec` is
    the same `locality.Topology` the JAX side uses (the old separate
    ``ClusterSpec`` is retired); `rates` is the (K,) tier-rate prior,
    K matching ``spec.num_tiers``.  When an `EwmaRateEstimator` is given
    its live (M, K) estimates are used instead (blind mode).  Every
    router accepts and stores the estimator, even rate-oblivious ones —
    observations still flow through `on_complete`, so switching a fleet
    from FIFO to a rate-aware policy needs no re-warming.
    """

    name: str = ""

    def __init__(self, spec, rates: Sequence[float], estimator=None,
                 seed: int = 0):
        self.spec = spec
        self.ancestors = np.asarray(spec.ancestors)  # (depth, M)
        self.num_tiers = spec.num_tiers
        self.prior = np.asarray(rates, np.float32)   # (K,) fastest first
        if self.prior.shape != (self.num_tiers,):
            raise ValueError(
                f"router prior has {self.prior.shape[0]} tier rates but the "
                f"fleet topology has {self.num_tiers} tiers")
        self.estimator = estimator
        self.rng = np.random.default_rng(seed)
        # (M,) bool routable mask (autoscaling seam): masked-out workers
        # receive no NEW work at route time but drain what they hold.
        self.active_mask = np.ones(spec.num_workers, bool)

    # -- estimated rates ----------------------------------------------------
    def _est(self) -> np.ndarray:
        """(M, K) current estimated rates (estimator if present, else prior)."""
        if self.estimator is not None:
            return self.estimator.rates
        return np.tile(self.prior, (self.spec.num_workers, 1))

    # -- the uniform surface ------------------------------------------------
    @abc.abstractmethod
    def route(self, locals_: Sequence[int]) -> Decision:
        """Admit one task whose data lives on `locals_`."""

    @abc.abstractmethod
    def claim(self, worker: int) -> Optional[Claim]:
        """Idle `worker` asks for its next task; None when nothing to do."""

    def on_complete(self, worker: int, tier: int, service_time: float) -> None:
        """Feed one observed (worker, tier, service_time) to the estimator."""
        if self.estimator is not None:
            self.estimator.observe(worker, tier, service_time)

    def queue_depths(self) -> np.ndarray:
        """(M,) tasks queued per worker (0s for global-queue routers)."""
        return np.zeros(self.spec.num_workers)

    def set_active(self, mask: Sequence[bool]) -> None:
        """Install the routable-worker mask (autoscaling seam).  At least
        one worker must stay active; routers fall back to the full fleet
        for a task whose every candidate is masked (better a remote
        assignment than a stuck task)."""
        m = np.asarray(mask, bool)
        if m.shape != (self.spec.num_workers,):
            raise ValueError(f"active mask must have shape "
                             f"({self.spec.num_workers},), got {m.shape}")
        if not m.any():
            raise ValueError("active mask must keep at least one worker")
        self.active_mask = m


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_POLICIES: Dict[str, Type[SlotPolicy]] = {}
_ROUTERS: Dict[str, Type[Router]] = {}

# Modules that register the built-in policies/routers as an import side
# effect.  Loaded lazily on first lookup so `policy.py` itself never imports
# an algorithm module at import time (no cycles).
_BUILTIN_MODULES = (
    "repro.core.balanced_pandas",
    "repro.core.jsq_maxweight",
    "repro.core.priority",
    "repro.core.fifo",
    "repro.core.pandas_po2",
    "repro.core.blind_pandas",
    "repro.core.slo_pandas",
    "repro.core.cluster",
)
_builtins_loaded = False


def _load_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    # Only mark loaded on full success: a failed import must resurface on
    # the next lookup, not leave a silently half-populated registry.
    _builtins_loaded = True


def register_policy(cls: Type[SlotPolicy]) -> Type[SlotPolicy]:
    """Class decorator: add a SlotPolicy to the registry under `cls.name`."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"policy class {cls.__name__} has no `name`")
    if name in _POLICIES:
        raise ValueError(f"duplicate policy registration: {name!r}")
    _POLICIES[name] = cls
    return cls


def register_router(cls: Type[Router]) -> Type[Router]:
    """Class decorator: add a Router to the registry under `cls.name`."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"router class {cls.__name__} has no `name`")
    if name in _ROUTERS:
        raise ValueError(f"duplicate router registration: {name!r}")
    _ROUTERS[name] = cls
    return cls


def available_policies() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_POLICIES))


def available_routers() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_ROUTERS))


def policy_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered `SlotPolicy`,
    from the first sentence of each class docstring — the self-describing
    registry surface behind ``benchmarks/run.py --help``."""
    from repro.utils.doc import first_doc_line
    _load_builtins()
    return {n: first_doc_line(c) for n, c in sorted(_POLICIES.items())}


def router_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered `Router`."""
    from repro.utils.doc import first_doc_line
    _load_builtins()
    return {n: first_doc_line(c) for n, c in sorted(_ROUTERS.items())}


def get_policy_cls(name: str) -> Type[SlotPolicy]:
    _load_builtins()
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; "
                         f"registered: {available_policies()}") from None


def get_router_cls(name: str) -> Type[Router]:
    _load_builtins()
    try:
        return _ROUTERS[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r}; "
                         f"registered: {available_routers()}") from None


def make_policy(spec: PolicyLike) -> SlotPolicy:
    """Resolve a policy name / PolicyConfig / instance to an instance."""
    if isinstance(spec, SlotPolicy):
        return spec
    if isinstance(spec, str):
        spec = PolicyConfig(spec)
    return get_policy_cls(spec.name)(**dict(spec.options))


def make_router(name: str, spec, rates: Sequence[float], estimator=None,
                seed: int = 0, **options) -> Router:
    """Instantiate a registered router with the uniform constructor."""
    return get_router_cls(name)(spec, rates, estimator=estimator, seed=seed,
                                **options)
