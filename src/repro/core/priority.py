"""Priority algorithm (paper §3.1; Xie & Lu 2015).

Designed for TWO locality levels (local/remote); run here on the 3-level
rack-structured system exactly as the paper does, where it is no longer
throughput optimal.  One queue per server holding local tasks; JSQ routing
among the arrival's 3 local queues.  An idle server serves its own queue if
nonempty (local, rate alpha); otherwise it helps the LONGEST queue in the
system (unweighted argmax — the algorithm ignores rates entirely, so rate
mis-estimation does not change its decisions; it serves as the
rate-oblivious control arm in the robustness study).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import claiming, locality as loc
from repro.core.policy import SlotPolicy, register_policy


class PriorityState(NamedTuple):
    q: jnp.ndarray             # (M,) int32
    serving_tier: jnp.ndarray  # (M,) int32 (m,n)-class in service; 0 idle


def init_state(topo: loc.Topology) -> PriorityState:
    m = topo.num_servers
    return PriorityState(jnp.zeros((m,), jnp.int32),
                         jnp.zeros((m,), jnp.int32))


def num_in_system(s: PriorityState) -> jnp.ndarray:
    return jnp.sum(s.q) + jnp.sum(s.serving_tier > 0)


def slot_step(s: PriorityState, key: jax.Array, types: jnp.ndarray,
              active: jnp.ndarray, est: jnp.ndarray, true_rates: jnp.ndarray,
              ancestors: jnp.ndarray):
    del est  # the Priority algorithm never consults service rates
    anc = loc.as_ancestors(ancestors)
    k_route, k_serve, k_claim = jax.random.split(key, 3)
    n_arr = types.shape[0]
    tmk = loc.per_server_rates(true_rates, s.q.shape[0])

    def body(i, q):
        return claiming.jsq_route_one(q, jax.random.fold_in(k_route, i),
                                      types[i], active[i])
    q = jax.lax.fori_loop(0, n_arr, body, s.q)

    done = jax.random.bernoulli(
        k_serve, claiming.tier_rates(s.serving_tier, tmk))
    completions = jnp.sum(done).astype(jnp.int32)
    serving_tier = jnp.where(done, 0, s.serving_tier)

    sid = jnp.arange(q.shape[0])
    big = jnp.float32(1e9)

    def score_fn(m, qv):
        # Own nonempty queue wins outright; otherwise longest queue.
        own = (sid == m) & (qv > 0)
        return jnp.where(own, big, qv.astype(jnp.float32))

    def tier_fn(m, n):
        return claiming.pair_tier(m, n, anc)

    q, serving_tier = claiming.claim_loop(q, serving_tier, k_claim,
                                          score_fn, tier_fn)
    return PriorityState(q, serving_tier), completions


@register_policy
class PriorityPolicy(SlotPolicy):
    """Priority: serve local tasks first, then rack-local, then remote —
    rate-oblivious 2-level design with a smaller capacity region than
    Balanced-PANDAS (its delay inside that region can still be excellent;
    see EXPERIMENTS.md §Reproduction).
    """

    name = "priority"

    def init_state(self, topo: loc.Topology, **opts) -> PriorityState:
        return init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors):
        return slot_step(s, key, types, active, est, true_rates, ancestors)

    def num_in_system(self, s: PriorityState) -> jnp.ndarray:
        return num_in_system(s)

    def telemetry_gauges(self, s: PriorityState):
        return claiming.telemetry_gauges(s.q, s.serving_tier)
