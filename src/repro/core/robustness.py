"""Robustness study driver (paper §4): sweeps load x estimation-error for
every registered algorithm and emits the data behind Figures 1-6 (plus the
beyond-paper `pandas_po2` arm; see EXPERIMENTS.md).

Figure map:
  fig1: all four algorithms, exact parameters, load sweep.
  fig2: PANDAS vs JSQ-MW, exact parameters, high-load closeup.
  fig3: robustness with parameters LOWER than real by eps in {5..30}%.
  fig4: sensitivity (delay vs eps) of PANDAS vs JSQ-MW, lower errors.
  fig5/fig6: same with parameters HIGHER than real.

Priority and FIFO never consult the rate estimates, so their error curves are
flat by construction; we simulate them once (exact) per load and reuse.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import locality as loc, simulator as sim

EPS_GRID = (0.05, 0.10, 0.15, 0.20, 0.25, 0.30)
RATE_AWARE = ("balanced_pandas", "pandas_po2", "jsq_maxweight")
RATE_OBLIVIOUS = ("priority", "fifo")


@dataclasses.dataclass(frozen=True)
class StudyConfig:
    sim: sim.SimConfig
    loads: Sequence[float] = (0.5, 0.6, 0.7, 0.8, 0.9, 0.95)
    high_loads: Sequence[float] = (0.90, 0.93, 0.95, 0.97)
    eps_grid: Sequence[float] = EPS_GRID
    error_mode: str = "per_server"
    seeds: Sequence[int] = (0, 1)


def default_study(fast: bool = False) -> StudyConfig:
    if fast:
        return StudyConfig(
            sim=sim.default_config(horizon=4_000, warmup=1_000),
            loads=(0.6, 0.8, 0.9), high_loads=(0.9, 0.95),
            eps_grid=(0.1, 0.3), seeds=(0,),
        )
    return StudyConfig(sim=sim.default_config(horizon=30_000, warmup=8_000))


def run_study(cfg: StudyConfig, algos: Optional[Sequence[str]] = None,
              signs: Sequence[int] = (-1, 1)) -> Dict:
    """Returns nested results:
    delay[algo]: (L, E, S) with E = 1 (exact) + len(eps_grid)*len(signs)
    plus the grids needed to plot.  Error settings only materialize for
    rate-aware algorithms; oblivious ones get the exact column only.
    """
    algos = list(algos or (RATE_AWARE + RATE_OBLIVIOUS))
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates, cfg.sim.p_hot)
    lam = np.asarray(cfg.loads, np.float32) * cap
    seeds = np.asarray(cfg.seeds)

    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)
    est_settings = [("exact", 0.0, 0)]
    ests = [est_exact]
    for sign in signs:
        for eps in cfg.eps_grid:
            est_settings.append((cfg.error_mode, eps, sign))
            ests.append(sim.make_estimates(cfg.sim, cfg.error_mode, eps, sign))
    est_stack = np.stack(ests)

    out: Dict = {"capacity": cap, "loads": np.asarray(cfg.loads),
                 "lam": lam, "est_settings": est_settings,
                 "delay": {}, "throughput": {}, "final_n": {}}
    for algo in algos:
        stack = est_stack if algo in RATE_AWARE else est_stack[:1]
        res = sim.sweep(algo, cfg.sim, lam, stack, seeds)
        out["delay"][algo] = res["mean_delay"]
        out["throughput"][algo] = res["throughput"]
        out["final_n"][algo] = res["final_n"]
    return out


def sensitivity(delay_les: np.ndarray) -> np.ndarray:
    """Paper figs 4/6 metric: relative delay deviation from the exact-parameter
    run, per error setting.  delay_les: (L, E, S) -> (L, E-1) mean over seeds."""
    d = delay_les.mean(-1)
    return (d[:, 1:] - d[:, :1]) / d[:, :1]


def summarize(study: Dict) -> str:
    """Human-readable table of the study results."""
    lines = []
    settings = study["est_settings"]
    for algo, d in study["delay"].items():
        dm = d.mean(-1)  # (L, E)
        for li, load in enumerate(study["loads"]):
            cols = "  ".join(f"{dm[li, ei]:8.2f}" for ei in range(dm.shape[1]))
            lines.append(f"{algo:16s} rho={load:4.2f}  {cols}")
        lines.append("")
    lines.append("columns: " + ", ".join(
        f"{m}{'' if s == 0 else ('-' if s < 0 else '+')}{e:.0%}"
        for (m, e, s) in settings))
    return "\n".join(lines)
