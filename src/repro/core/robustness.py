"""Robustness study driver (paper §4): sweeps load x estimation-error for
every registered algorithm and emits the data behind Figures 1-6 (plus the
beyond-paper `pandas_po2` arm; see EXPERIMENTS.md).

Figure map:
  fig1: all four algorithms, exact parameters, load sweep.
  fig2: PANDAS vs JSQ-MW, exact parameters, high-load closeup.
  fig3: robustness with parameters LOWER than real by eps in {5..30}%.
  fig4: sensitivity (delay vs eps) of PANDAS vs JSQ-MW, lower errors.
  fig5/fig6: same with parameters HIGHER than real.

Priority and FIFO never consult the rate estimates, so their error curves are
flat by construction; we simulate them once (exact) per load and reuse.

Drift study (`drift_study`, beyond the paper's figures): the paper argues
Balanced-PANDAS matters because of "the change of traffic over time in
addition to estimation errors of processing rates" — the scenario subsystem
(`repro.workloads`) finally runs that experiment.  Two arms per scenario:
a fixed prior that is exactly right at t=0 but never updated, vs the blind
EWMA policy (`blind_pandas`) that starts from the same prior and keeps
learning.  Under time-varying truth (stragglers, rack congestion, hotspot
migration) the fixed prior goes stale mid-run; the study measures what the
online estimator buys back.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core import locality as loc, simulator as sim
from repro.core.policy import PolicyConfig, PolicyLike
from repro.placement import PlacementLike, placement_capacity
from repro.telemetry import TelemetryLike
from repro.workloads import Scenario, ScenarioConfig, ScenarioLike

EPS_GRID = (0.05, 0.10, 0.15, 0.20, 0.25, 0.30)
RATE_AWARE = ("balanced_pandas", "pandas_po2", "jsq_maxweight")
RATE_OBLIVIOUS = ("priority", "fifo")
# Scenarios for the drift study: "static" is the control arm where the
# fixed prior is unbeatable (it is exact and never goes stale).
DRIFT_SCENARIOS = ("static", "diurnal", "flash_crowd", "mmpp", "hot_shift",
                   "stragglers", "rack_congestion")
# Placement-study grid: every registered placement x one representative
# policy per family (full-scan PANDAS, blind EWMA PANDAS, MaxWeight)
# under the two scenarios that move locality/network structure.
PLACEMENTS = ("uniform", "hdfs", "spread", "hot_aware")
PLACEMENT_POLICIES = ("balanced_pandas", "blind_pandas", "jsq_maxweight")
PLACEMENT_SCENARIOS = ("static", "hot_shift", "rack_congestion")
# Replication-lifecycle study grid: every shipped controller under the two
# failure scenarios, for the two schedulers whose robustness gap the paper
# cares about.  "fixed" is the no-repair control arm.
REPLICATIONS = ("fixed", "popularity", "repair")
REPLICATION_SCENARIOS = ("server_loss", "rack_loss")
REPLICATION_POLICIES = ("balanced_pandas", "jsq_maxweight")
# Tail-latency study grid (EXPERIMENTS.md §Tail latency): heavy-traffic
# loads where mean ordering and tail ordering can diverge, for the
# delay-optimal arm, the throughput-optimal arm, and the Hadoop floor.
TAIL_POLICIES = ("balanced_pandas", "jsq_maxweight", "fifo")
TAIL_LOADS = (0.90, 0.95, 0.99)
# SLO-control study grid (EXPERIMENTS.md §SLO control): control-plane arms
# x {mean-optimal, SLO-conditioned} schedulers at heavy-traffic loads.
CONTROL_ARMS = ("none", "admission", "autoscale", "both")
CONTROL_POLICIES = ("balanced_pandas", "slo_pandas")
CONTROL_LOADS = (0.90, 0.95, 0.99)


@dataclasses.dataclass(frozen=True)
class StudyConfig:
    sim: sim.SimConfig
    loads: Sequence[float] = (0.5, 0.6, 0.7, 0.8, 0.9, 0.95)
    high_loads: Sequence[float] = (0.90, 0.93, 0.95, 0.97)
    eps_grid: Sequence[float] = EPS_GRID
    error_mode: str = "per_server"
    seeds: Sequence[int] = (0, 1)


def default_study(fast: bool = False) -> StudyConfig:
    if fast:
        return StudyConfig(
            sim=sim.default_config(horizon=4_000, warmup=1_000),
            loads=(0.6, 0.8, 0.9), high_loads=(0.9, 0.95),
            eps_grid=(0.1, 0.3), seeds=(0,),
        )
    return StudyConfig(sim=sim.default_config(horizon=30_000, warmup=8_000))


def run_study(cfg: StudyConfig, algos: Optional[Sequence[str]] = None,
              signs: Sequence[int] = (-1, 1),
              scenario: ScenarioLike = None,
              placement: PlacementLike = None,
              telemetry: TelemetryLike = None,
              fleet=None) -> Dict:
    """Returns nested results:
    delay[algo]: (L, E, S) with E = 1 (exact) + len(eps_grid)*len(signs)
    plus the grids needed to plot.  Error settings only materialize for
    rate-aware algorithms; oblivious ones get the exact column only.
    `scenario` (name / Scenario; None -> static) applies to every arm — the
    loads stay expressed as fractions of the STATIC fluid capacity (under
    the uniform placement, whatever `placement` the arms actually run).
    With `telemetry` enabled (True / TelemetryConfig) the result grows
    delay_p50/delay_p95/delay_p99[algo] arrays of the same (L, E, S) shape
    — the FCFS-coupled sojourn percentiles next to the Little's-law means.
    """
    algos = list(algos or (RATE_AWARE + RATE_OBLIVIOUS))
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates, cfg.sim.p_hot)
    lam = np.asarray(cfg.loads, np.float32) * cap
    seeds = np.asarray(cfg.seeds)

    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)
    est_settings = [("exact", 0.0, 0)]
    ests = [est_exact]
    for sign in signs:
        for eps in cfg.eps_grid:
            est_settings.append((cfg.error_mode, eps, sign))
            ests.append(sim.make_estimates(cfg.sim, cfg.error_mode, eps, sign))
    est_stack = np.stack(ests)

    out: Dict = {"capacity": cap, "loads": np.asarray(cfg.loads),
                 "lam": lam, "est_settings": est_settings,
                 "delay": {}, "throughput": {}, "final_n": {}}
    pct_keys = ("delay_p50", "delay_p95", "delay_p99")
    if telemetry is not None:
        for k in pct_keys:
            out[k] = {}
    for algo in algos:
        stack = est_stack if algo in RATE_AWARE else est_stack[:1]
        res = sim.sweep(algo, cfg.sim, lam, stack, seeds, scenario=scenario,
                        placement=placement, telemetry=telemetry,
                        fleet=fleet)
        out["delay"][algo] = res["mean_delay"]
        out["throughput"][algo] = res["throughput"]
        out["final_n"][algo] = res["final_n"]
        if telemetry is not None:
            for k in pct_keys:
                out[k][algo] = res[k]
    return out


def drift_study(cfg: StudyConfig,
                scenarios: Union[Sequence[str],
                                 Mapping[str, ScenarioLike]] = DRIFT_SCENARIOS,
                load: float = 0.75) -> Dict:
    """Fixed-prior vs blind-EWMA Balanced-PANDAS under each scenario.

    Both arms start from the exact static rates — the *best possible*
    fixed prior — so any blind win is pure drift-tracking, not prior
    quality.  Returns delay/throughput/final_n[scenario][arm] arrays of
    shape (S_seeds,) plus the winner per scenario.

    `scenarios` is a sequence of registered names, or — for scenarios that
    need options, e.g. a compiled trace replay — a ``{label: ScenarioLike}``
    mapping; results are keyed by the label either way.
    """
    if isinstance(scenarios, Mapping):
        scen_map: Dict[str, ScenarioLike] = dict(scenarios)
    else:
        scen_map = {s.name if isinstance(s, (Scenario, ScenarioConfig))
                    else str(s): s for s in scenarios}
    r = cfg.sim.true_rates
    prior = r.values
    arms: Dict[str, PolicyLike] = {
        "fixed_prior": "balanced_pandas",
        "blind_ewma": PolicyConfig("blind_pandas", {"prior": prior}),
    }
    cap = loc.capacity_hot_rack(cfg.sim.topo, r, cfg.sim.p_hot)
    lam = np.asarray([load], np.float32) * cap
    seeds = np.asarray(cfg.seeds)
    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]

    out: Dict = {"capacity": cap, "load": load, "arms": tuple(arms),
                 "scenarios": tuple(scen_map), "delay": {},
                 "throughput": {}, "final_n": {}}
    for scen, spec in scen_map.items():
        for name in ("delay", "throughput", "final_n"):
            out[name][scen] = {}
        for arm, policy in arms.items():
            res = sim.sweep(policy, cfg.sim, lam, est_exact, seeds,
                            scenario=spec)
            out["delay"][scen][arm] = res["mean_delay"][0, 0]
            out["throughput"][scen][arm] = res["throughput"][0, 0]
            out["final_n"][scen][arm] = res["final_n"][0, 0]
    out["blind_wins"] = {
        scen: float(out["delay"][scen]["blind_ewma"].mean())
        < float(out["delay"][scen]["fixed_prior"].mean())
        for scen in scen_map}
    return out


def summarize_drift(study: Dict) -> str:
    """Human-readable drift-study table (one row per scenario)."""
    width = max([16] + [len(s) for s in study["scenarios"]])
    lines = [f"{'scenario':{width}s} {'fixed_prior':>12s} {'blind_ewma':>12s}"
             f"  winner   (mean delay, slots; load "
             f"{study['load']:.2f} x static capacity)"]
    for scen in study["scenarios"]:
        d_fix = float(study["delay"][scen]["fixed_prior"].mean())
        d_bl = float(study["delay"][scen]["blind_ewma"].mean())
        win = "blind" if study["blind_wins"][scen] else "fixed"
        lines.append(f"{scen:{width}s} {d_fix:12.2f} {d_bl:12.2f}  {win}")
    return "\n".join(lines)


def placement_study(cfg: StudyConfig,
                    placements: Sequence[str] = PLACEMENTS,
                    policies: Sequence[str] = PLACEMENT_POLICIES,
                    scenarios: Union[Sequence[str],
                                     Mapping[str, ScenarioLike]]
                    = PLACEMENT_SCENARIOS,
                    load: float = 0.7,
                    capacity_samples: int = 2000) -> Dict:
    """Placement x policy x scenario sweep: what hierarchy-aware replica
    placement buys each scheduler (the knob the uniform model hard-coded).

    Every arm runs at the same offered load — `load` x the *uniform*
    static fluid capacity — so delay deltas across placements are
    placement effects, not load normalization artifacts.  Per placement
    the study also records the fluid capacity its replica distribution
    induces (`repro.placement.placement_capacity`; None without scipy).
    Returns delay/throughput/final_n[placement][scenario][policy] arrays
    of shape (S_seeds,).
    """
    if isinstance(scenarios, Mapping):
        scen_map: Dict[str, ScenarioLike] = dict(scenarios)
    else:
        scen_map = {s.name if isinstance(s, (Scenario, ScenarioConfig))
                    else str(s): s for s in scenarios}
    r = cfg.sim.true_rates
    arms: Dict[str, PolicyLike] = {
        str(p): (PolicyConfig("blind_pandas", {"prior": r.values})
                 if p == "blind_pandas" else p)
        for p in policies}
    cap = loc.capacity_hot_rack(cfg.sim.topo, r, cfg.sim.p_hot)
    lam = np.asarray([load], np.float32) * cap
    seeds = np.asarray(cfg.seeds)
    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]

    out: Dict = {"capacity_uniform": cap, "load": load,
                 "placements": tuple(placements), "policies": tuple(arms),
                 "scenarios": tuple(scen_map),
                 "capacity": {}, "delay": {}, "throughput": {}, "final_n": {}}
    for plc in placements:
        out["capacity"][plc] = placement_capacity(
            cfg.sim.topo, r, cfg.sim.p_hot, plc,
            n_samples=capacity_samples, strict=False)
        for name in ("delay", "throughput", "final_n"):
            out[name][plc] = {scen: {} for scen in scen_map}
        for scen, spec in scen_map.items():
            for pol, policy in arms.items():
                res = sim.sweep(policy, cfg.sim, lam, est_exact, seeds,
                                scenario=spec, placement=plc)
                out["delay"][plc][scen][pol] = res["mean_delay"][0, 0]
                out["throughput"][plc][scen][pol] = res["throughput"][0, 0]
                out["final_n"][plc][scen][pol] = res["final_n"][0, 0]
    return out


def summarize_placement(study: Dict) -> str:
    """Human-readable placement-study table (scenario-major, one row per
    placement; columns are policies)."""
    pols = list(study["policies"])
    width = max([10] + [len(p) for p in study["placements"]])
    lines = [f"load {study['load']:.2f} x uniform static capacity "
             f"({study['capacity_uniform']:.2f} tasks/slot); "
             f"cells: mean delay (slots) over seeds"]
    header = f"{'placement':{width}s} {'fluid_cap':>9s}  " + \
        "  ".join(f"{p:>15s}" for p in pols)
    for scen in study["scenarios"]:
        lines.append(f"-- scenario: {scen}")
        lines.append(header)
        for plc in study["placements"]:
            cap = study["capacity"][plc]
            cap_s = f"{cap:9.2f}" if cap is not None else f"{'n/a':>9s}"
            cells = "  ".join(
                f"{float(study['delay'][plc][scen][p].mean()):15.2f}"
                for p in pols)
            lines.append(f"{plc:{width}s} {cap_s}  {cells}")
    return "\n".join(lines)


def replication_study(cfg: StudyConfig,
                      replications: Sequence[str] = REPLICATIONS,
                      scenarios: Union[Sequence[str],
                                       Mapping[str, ScenarioLike]]
                      = REPLICATION_SCENARIOS,
                      policies: Sequence[str] = REPLICATION_POLICIES,
                      loads: Sequence[float] = (0.7, 0.95)) -> Dict:
    """Replication-controller x failure-scenario x scheduler sweep: what
    adaptive replication and failure-driven repair buy (and cost) when the
    scenario actually kills servers.

    Every arm runs at `loads` x the static fluid capacity of the *healthy*
    cluster, so delay deltas under a loss window mix two effects the study
    separates: capacity lost to dead servers (visible in `availability` /
    `data_loss`) and foreground slots consumed by the re-replication storm
    (visible in `repair_moves` and the delay gap between the `fixed` control
    arm and the repairing controllers).  Returns per-metric nested dicts
    ``out[metric][scenario][controller][policy]`` with shape (L, S_seeds);
    replication metrics (availability, data_loss, mean_replication,
    repair_moves) come from the lifecycle machinery, which every failure
    scenario engages for all controllers including `fixed`.
    """
    if isinstance(scenarios, Mapping):
        scen_map: Dict[str, ScenarioLike] = dict(scenarios)
    else:
        scen_map = {s.name if isinstance(s, (Scenario, ScenarioConfig))
                    else str(s): s for s in scenarios}
    r = cfg.sim.true_rates
    cap = loc.capacity_hot_rack(cfg.sim.topo, r, cfg.sim.p_hot)
    lam = np.asarray(loads, np.float32) * cap
    seeds = np.asarray(cfg.seeds)
    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]

    metrics = ("delay", "throughput", "availability", "data_loss",
               "mean_replication", "repair_moves")
    src_key = {"delay": "mean_delay", "data_loss": "data_loss_frac"}
    out: Dict = {"capacity": cap, "loads": np.asarray(loads),
                 "replications": tuple(replications),
                 "scenarios": tuple(scen_map), "policies": tuple(policies)}
    for m in metrics:
        out[m] = {scen: {ctrl: {} for ctrl in replications}
                  for scen in scen_map}
    for scen, spec in scen_map.items():
        for ctrl in replications:
            for pol in policies:
                res = sim.sweep(pol, cfg.sim, lam, est_exact, seeds,
                                scenario=spec, replication=ctrl)
                for m in metrics:
                    key = src_key.get(m, m)
                    val = res.get(key)
                    out[m][scen][ctrl][pol] = (
                        None if val is None else val[:, 0])
    return out


def summarize_replication(study: Dict) -> str:
    """Human-readable replication-study table (scenario-major; one row per
    controller x load, columns per scheduler: delay / availability /
    data-loss / repair moves)."""
    pols = list(study["policies"])
    width = max([10] + [len(c) for c in study["replications"]])
    lines = [f"loads x healthy static capacity "
             f"({study['capacity']:.2f} tasks/slot); cells: "
             f"delay(slots) | avail | data_loss | repair_moves, "
             f"mean over seeds"]
    for scen in study["scenarios"]:
        lines.append(f"-- scenario: {scen}")
        lines.append(f"{'controller':{width}s} {'rho':>5s}  " +
                     "  ".join(f"{p:>34s}" for p in pols))
        for ctrl in study["replications"]:
            for li, rho in enumerate(study["loads"]):
                cells = []
                for p in pols:
                    d = float(study["delay"][scen][ctrl][p][li].mean())
                    av = study["availability"][scen][ctrl][p]
                    dl = study["data_loss"][scen][ctrl][p]
                    mv = study["repair_moves"][scen][ctrl][p]
                    if av is None:
                        cells.append(f"{d:9.2f} | {'n/a':>5s} | {'n/a':>6s}"
                                     f" | {'n/a':>5s}")
                    else:
                        cells.append(
                            f"{d:9.2f} | {float(av[li].mean()):5.3f} | "
                            f"{float(dl[li].mean()):6.4f} | "
                            f"{float(mv[li].mean()):5.0f}")
                lines.append(f"{ctrl:{width}s} {float(rho):5.2f}  " +
                             "  ".join(cells))
    return "\n".join(lines)


def tail_study(cfg: StudyConfig,
               policies: Sequence[str] = TAIL_POLICIES,
               loads: Sequence[float] = TAIL_LOADS,
               scenario: ScenarioLike = None,
               telemetry: TelemetryLike = True) -> Dict:
    """Heavy-traffic tail-latency study: p50/p95/p99 sojourn next to the
    Little's-law mean for each scheduler across a rho grid.

    The point of the exercise (EXPERIMENTS.md §Tail latency): mean-delay
    ordering between schedulers need not match tail ordering — a policy
    can win on average and still lose the p99.  All arms run at exact
    rate estimates; percentiles come from the in-scan FCFS-coupled
    histogram, so values are upper bin edges (error <= one bin width; see
    `repro.telemetry`).  Returns nested dicts
    ``out[metric][policy]`` with shape (L, S_seeds) for metric in
    mean / p50 / p95 / p99, plus accounting (`dropped`, `unmatched`).
    """
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates,
                                cfg.sim.p_hot)
    lam = np.asarray(loads, np.float32) * cap
    seeds = np.asarray(cfg.seeds)
    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]

    keymap = {"mean": "mean_delay", "p50": "delay_p50", "p95": "delay_p95",
              "p99": "delay_p99", "dropped": "telemetry_dropped",
              "unmatched": "telemetry_unmatched"}
    out: Dict = {"capacity": cap, "loads": np.asarray(loads),
                 "policies": tuple(policies)}
    for m in keymap:
        out[m] = {}
    for pol in policies:
        res = sim.sweep(pol, cfg.sim, lam, est_exact, seeds,
                        scenario=scenario, telemetry=telemetry)
        for m, k in keymap.items():
            out[m][pol] = res[k][:, 0]  # drop the singleton est axis
    return out


def summarize_tail(study: Dict) -> str:
    """Human-readable tail-latency table (one row per policy x load),
    flagging loads where the p99 winner differs from the mean winner."""
    width = max([16] + [len(p) for p in study["policies"]])
    lines = [f"loads x static capacity ({study['capacity']:.2f} tasks/slot);"
             f" delays in slots, mean over seeds; percentiles are upper "
             f"histogram-bin edges (inf = past hist_max)"]
    lines.append(f"{'policy':{width}s} {'rho':>5s} {'mean':>9s} "
                 f"{'p50':>8s} {'p95':>8s} {'p99':>8s}")
    for li, rho in enumerate(study["loads"]):
        by = {m: {p: float(np.mean(study[m][p][li]))
                  for p in study["policies"]}
              for m in ("mean", "p50", "p95", "p99")}
        for pol in study["policies"]:
            lines.append(
                f"{pol:{width}s} {float(rho):5.2f} {by['mean'][pol]:9.2f} "
                f"{by['p50'][pol]:8.1f} {by['p95'][pol]:8.1f} "
                f"{by['p99'][pol]:8.1f}")
        mean_win = min(by["mean"], key=by["mean"].get)
        p99_win = min(by["p99"], key=by["p99"].get)
        if mean_win != p99_win:
            lines.append(f"{'':{width}s}       ^ tail flip: mean winner "
                         f"{mean_win}, p99 winner {p99_win}")
    return "\n".join(lines)


def control_arm_spec(arm: str, cap: float, admit_frac: float = 0.93):
    """The ``control=`` value for one study arm.

    The admission arm is a token bucket refilling at ``admit_frac`` x the
    fluid capacity (burst = 8 x cap): it clips the offered load to just
    under the stability boundary, which is precisely the regime where
    shedding a few percent of arrivals collapses the queueing tail.  The
    autoscale arm is the proactive in-scan headroom planner; "both"
    composes the two in one plane.
    """
    bucket = {"name": "token_bucket",
              "options": {"rate": admit_frac * cap, "burst": 8.0 * cap}}
    return {"none": None, "admission": bucket, "autoscale": "autoscale",
            "both": (bucket, "autoscale")}[arm]


def control_study(cfg: StudyConfig,
                  policies: Sequence[str] = CONTROL_POLICIES,
                  arms: Sequence[str] = CONTROL_ARMS,
                  loads: Sequence[float] = CONTROL_LOADS,
                  admit_frac: float = 0.93,
                  slo_target: float = 40.0) -> Dict:
    """SLO-control study: {no control, admission, autoscale, both} x
    {balanced_pandas, slo_pandas} at heavy-traffic loads, telemetry on.

    The question (EXPERIMENTS.md §SLO control): what does each control
    lever buy at the tail?  Admission trades throughput (shed arrivals)
    for p99; autoscaling trades energy/fleet-size for nothing at high
    rho (it keeps everything on) but shows its descale floor at moderate
    rho; the SLO-conditioned scheduler moves the tail with zero shed.
    Under admission/loadgen control the Little's-law mean uses the
    MEASURED admitted rate as its denominator, so means stay comparable
    across arms.  ``slo_target`` (slots) is applied to every
    signal-reading policy (``uses_signals``) — pick it between the
    uncontrolled p50 and p99 at the top load so breach episodes actually
    occur (the class default of 96 never breaches at these scales).
    Returns ``out[metric][policy][arm]`` arrays of shape (L, S_seeds)
    for metric in mean / p50 / p95 / p99 / shed_rate / throughput
    (shed_rate is NaN for the uncontrolled arm).
    """
    from repro.core.policy import get_policy_cls
    cap = loc.capacity_hot_rack(cfg.sim.topo, cfg.sim.true_rates,
                                cfg.sim.p_hot)
    lam = np.asarray(loads, np.float32) * cap
    seeds = np.asarray(cfg.seeds)
    est_exact = sim.make_estimates(cfg.sim, "network", 0.0, -1)[None]

    keymap = {"mean": "mean_delay", "p50": "delay_p50", "p95": "delay_p95",
              "p99": "delay_p99", "throughput": "throughput"}
    out: Dict = {"capacity": cap, "loads": np.asarray(loads),
                 "policies": tuple(policies), "arms": tuple(arms),
                 "admit_frac": admit_frac, "slo_target": slo_target}
    for m in list(keymap) + ["shed_rate"]:
        out[m] = {p: {} for p in policies}
    for pol in policies:
        pol_like: PolicyLike = pol
        if getattr(get_policy_cls(pol), "uses_signals", False):
            pol_like = PolicyConfig(pol, {"slo_target": slo_target})
        for arm in arms:
            res = sim.sweep(pol_like, cfg.sim, lam, est_exact, seeds,
                            telemetry=True,
                            control=control_arm_spec(arm, cap, admit_frac))
            for m, k in keymap.items():
                out[m][pol][arm] = res[k][:, 0]  # drop singleton est axis
            out["shed_rate"][pol][arm] = (
                res["ctl_shed_rate"][:, 0] if "ctl_shed_rate" in res
                else np.full((len(loads), len(seeds)), np.nan))
    return out


def summarize_control(study: Dict) -> str:
    """Human-readable SLO-control table (policy x arm rows per load),
    flagging loads where a controlled arm beats the uncontrolled p99."""
    width = max([16] + [len(p) for p in study["policies"]])
    lines = [f"loads x static capacity ({study['capacity']:.2f} tasks/slot); "
             f"admission bucket at {study['admit_frac']:.0%} of capacity; "
             f"SLO target {study['slo_target']:.0f} slots; delays in slots "
             f"(mean via measured admitted rate), mean over seeds"]
    lines.append(f"{'policy':{width}s} {'arm':>10s} {'rho':>5s} "
                 f"{'mean':>9s} {'p99':>8s} {'shed':>7s} {'thru':>7s}")
    for li, rho in enumerate(study["loads"]):
        for pol in study["policies"]:
            base_p99 = float(np.mean(study["p99"][pol]["none"][li])) \
                if "none" in study["arms"] else np.nan
            for arm in study["arms"]:
                mean = float(np.mean(study["mean"][pol][arm][li]))
                p99 = float(np.mean(study["p99"][pol][arm][li]))
                shed = float(np.mean(study["shed_rate"][pol][arm][li]))
                thru = float(np.mean(study["throughput"][pol][arm][li]))
                mark = " <- beats uncontrolled p99" \
                    if arm != "none" and p99 < base_p99 else ""
                lines.append(
                    f"{pol:{width}s} {arm:>10s} {float(rho):5.2f} "
                    f"{mean:9.2f} {p99:8.1f} "
                    f"{('-' if np.isnan(shed) else f'{shed:.1%}'):>7s} "
                    f"{thru:7.3f}{mark}")
        lines.append("")
    return "\n".join(lines[:-1])


def sensitivity(delay_les: np.ndarray) -> np.ndarray:
    """Paper figs 4/6 metric: relative delay deviation from the exact-parameter
    run, per error setting.  delay_les: (L, E, S) -> (L, E-1) mean over seeds."""
    d = delay_les.mean(-1)
    return (d[:, 1:] - d[:, :1]) / d[:, :1]


def summarize(study: Dict) -> str:
    """Human-readable table of the study results."""
    lines = []
    settings = study["est_settings"]
    for algo, d in study["delay"].items():
        dm = d.mean(-1)  # (L, E)
        for li, load in enumerate(study["loads"]):
            cols = "  ".join(f"{dm[li, ei]:8.2f}" for ei in range(dm.shape[1]))
            lines.append(f"{algo:16s} rho={load:4.2f}  {cols}")
        lines.append("")
    lines.append("columns: " + ", ".join(
        f"{m}{'' if s == 0 else ('-' if s < 0 else '+')}{e:.0%}"
        for (m, e, s) in settings))
    return "\n".join(lines)
