"""Vectorized discrete-time simulator (paper §4 experimental engine).

One `jax.lax.scan` over time slots per configuration; `jax.vmap` over the
sweep grid (load x error x seed).  All state is fixed-shape, so the whole
robustness study compiles to a single XLA program.

Scenarios (`repro.workloads`): every run plays back a declarative
piecewise schedule of workload knobs — arrival-rate multiplier, hot
fraction, hot rack, per-server/per-tier true-rate multipliers — gathered
per slot from compiled fixed-shape arrays (`slot_knobs`).  The simulator
itself contains zero per-scenario branching: the default ``"static"``
scenario multiplies every knob by 1.0 and reproduces the pre-scenario
sample paths bitwise (common random numbers preserved across scenarios and
policies alike).

The simulator is algorithm-agnostic: it drives any registered `SlotPolicy`
(see `core/policy.py`) and accepts a policy name, a `PolicyConfig` carrying
per-policy options (e.g. ``PolicyConfig("fifo", {"cap": 4096})``,
``PolicyConfig("pandas_po2", {"d": 4})``), or a policy instance.  Per-policy
metrics (FIFO's drop counter) are merged into the output via
`SlotPolicy.extra_metrics`.

Mean task completion time is measured via Little's law:
``W = mean(N_in_system over measurement window) / lambda_total`` (slots),
exact for stationary ergodic systems.  Divergence (instability / outside the
capacity region) is visible as ``final_n`` growing with the horizon and as
throughput < arrival rate.

Error models for the estimated rates (see balanced_pandas.py docstring for
the scale-invariance finding that motivates them):
  - "uniform":    est = true * (1 +/- eps) for all three tiers — provably a
                  no-op for PANDAS/MW decisions; kept as the control arm.
  - "network":    alpha known exactly; beta, gamma scaled by (1 +/- eps) —
                  mis-estimated network depreciation (the realistic reading
                  of the paper's experiment; used for the figure benches).
  - "per_server": each server's three estimates carry iid multipliers in
                  [1-eps, 1] (sign<0) or [1, 1+eps] (sign>0).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import ControlLike, resolve_control
from repro.core import locality as loc
from repro.core.policy import PolicyLike, make_policy
from repro import workloads as wl
from repro.placement import PlacementLike, make_placement
from repro.replication import ReplicationLike, make_replication
from repro.telemetry import (SimTelemetry, TelemetryLike,
                             as_telemetry_config)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    topo: loc.Topology
    true_rates: loc.Rates
    p_hot: float = 0.5
    max_arrivals: int = 24
    horizon: int = 40_000
    warmup: int = 10_000

    def __post_init__(self):
        # Same guard as loc.Traffic: p_hot feeds bernoulli via the compiled
        # scenario schedule, and a negative value would flow silently.
        if not 0.0 <= self.p_hot <= 1.0:
            raise ValueError(f"p_hot must be in [0, 1], got {self.p_hot}")
        if self.max_arrivals < 1:
            raise ValueError(
                f"max_arrivals must be >= 1, got {self.max_arrivals}")
        if not 0 <= self.warmup < self.horizon:
            raise ValueError(f"need 0 <= warmup < horizon, got "
                             f"warmup={self.warmup} horizon={self.horizon}")
        # Rate vector and hierarchy must agree on the tier count, and every
        # rack must be able to hold a hot task's replica set (the sampler
        # draws NUM_REPLICAS distinct servers from one rack).
        if self.true_rates.num_tiers != self.topo.num_tiers:
            raise ValueError(
                f"true_rates have {self.true_rates.num_tiers} tiers but the "
                f"topology has {self.topo.num_tiers}")
        if self.topo.min_rack_size < loc.NUM_REPLICAS:
            raise ValueError(
                f"every rack needs >= {loc.NUM_REPLICAS} servers for "
                f"hot-rack types; smallest rack has "
                f"{self.topo.min_rack_size}")


def default_config(**kw) -> SimConfig:
    """Paper-scale default: 24 servers in 4 racks, hot-rack traffic."""
    return SimConfig(topo=loc.Topology(24, 6), true_rates=loc.Rates(), **kw)


def make_estimates(cfg: SimConfig, mode: str, eps: float, sign: int,
                   seed: int = 0) -> np.ndarray:
    """(M, K) estimated rates for one error setting.  sign: -1 lower, +1 higher.

    "network" scales every non-local tier (the rack/pod/DCN rates) and
    leaves the local rate exact, generalizing the 3-tier beta/gamma error.
    """
    m = cfg.topo.num_servers
    k = cfg.true_rates.num_tiers
    true_k = np.asarray(cfg.true_rates.values, np.float32)
    if mode == "uniform":
        mult = np.full((m, k), 1.0 + sign * eps, np.float32)
    elif mode == "network":
        mult = np.ones((m, k), np.float32)
        mult[:, 1:] = 1.0 + sign * eps
    elif mode == "per_server":
        rng = np.random.default_rng(seed)
        u = rng.uniform(0.0, eps, size=(m, k)).astype(np.float32)
        mult = 1.0 + sign * u
    else:
        raise ValueError(f"unknown error mode {mode!r}")
    est = true_k[None, :] * mult
    return np.clip(est, 1e-3, 1.0)


def _merge_metrics(out: Dict[str, Any], extra: Dict[str, Any],
                   source: str) -> None:
    """Merge `extra` into the metrics dict, refusing to silently overwrite
    a key another layer already produced (policy extra_metrics vs
    replication vs telemetry vs the core Little's-law scalars)."""
    for k in extra:
        if k in out:
            raise ValueError(
                f"{source} metric key {k!r} collides with an existing "
                f"metrics key; rename it (existing keys: {sorted(out)})")
    out.update(extra)


def _build_run(policy_like: PolicyLike, cfg: SimConfig,
               scenario: wl.ScenarioLike = None,
               placement: PlacementLike = None,
               replication: ReplicationLike = None,
               telemetry: TelemetryLike = None,
               control: ControlLike = None):
    """Returns jit-able run(lam_total, est(M,3), seed) -> metrics dict.

    `scenario` (name / ScenarioConfig / Scenario; None -> "static") compiles
    to fixed-shape per-segment arrays gathered once per slot — the only
    scenario seam in the simulator, shared by every policy.

    `placement` (name / PlacementConfig / PlacementPolicy; None ->
    "uniform") compiles to the per-task replica sampling distribution
    (`repro.placement`) the arrival stream draws task types from; the
    default reproduces the classic i.i.d.-uniform draws bitwise.

    `replication` (name / ReplicationConfig / ReplicationController; None
    -> "fixed") selects the replication-lifecycle controller
    (`repro.replication`).  The machinery only engages when the
    controller is dynamic or the scenario carries a failure track
    (``server_loss`` / ``rack_loss``) — a compile-time Python fact, so
    ``"fixed"`` with no failures runs the exact pre-replication step and
    stays bitwise-identical (same keys, same metrics keys; pinned by
    tests/test_replication.py).  In machinery mode the lifecycle rides
    the scan carry: dead servers serve at rate 0 and lose their
    replicas, migration endpoints serve at the contention multiplier,
    and availability / data-loss metrics join the output dict.

    `telemetry` (None / True / TelemetryConfig; `repro.telemetry`)
    compiles the in-scan recorders into the step: a FIFO-coupled sojourn
    histogram (-> ``delay_p50/p95/p99``), a queue-length histogram, and
    downsampled time series.  ``None`` compiles nothing (the pre-telemetry
    step, bitwise); when on, the recorder consumes no random bits, so the
    sample path is still bitwise-identical — only new metrics keys appear
    (both facts pinned in tests/test_telemetry.py).

    `control` (None / name / ControlConfig / Controller / sequence;
    `repro.control`) engages the control plane: load generation reshapes
    the offered rate, admission trims the fixed-shape arrival lane mask
    BEFORE routing (shed tasks never touch a queue or the telemetry
    sojourn pairing), and autoscaling hands mask-aware policies a
    per-slot (M,) routable-server mask (descaled servers drain — distinct
    from the replication ``alive`` track, where dead servers stop serving
    and lose replicas).  ``None`` compiles nothing: the exact pre-control
    step, bitwise for every policy (pinned in tests/test_control.py).
    When engaged, ``ctl_*`` metrics join the output and ``mean_delay``'s
    Little's-law denominator switches from the configured rate to the
    MEASURED admitted rate (the configured lam no longer equals what
    entered the system).  SLO-conditioned policies (``uses_signals``)
    additionally receive the recorder's live p99 each slot when
    ``telemetry=`` is on.
    """
    policy = make_policy(policy_like)
    topo, true_rates = cfg.topo, cfg.true_rates
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    ancestors = jnp.asarray(topo.ancestors, jnp.int32)  # (depth, M)
    true_k = true_rates.as_array()
    plc = make_placement(placement)
    sample_types = plc.build_sampler(topo)
    sched = wl.compile_schedule(wl.make_scenario(scenario), topo,
                                cfg.horizon, cfg.p_hot)
    ctrl = make_replication(replication)
    rep_sim = None
    if not (ctrl.is_static and sched.alive is None):
        rep_sim = ctrl.build_sim(topo, np.asarray(true_rates.values), plc)
    # Telemetry (repro.telemetry): in-scan recorders for delay/queue-length
    # distributions and downsampled time series.  `None` compiles nothing
    # (the pre-telemetry step, bitwise); when configured, the recorder is
    # pure observation — it consumes no random bits, so the sample path is
    # STILL bitwise-identical and only new metrics keys appear.
    tel = None
    if telemetry is not None and telemetry is not False:
        tel_tracks = []
        if rep_sim is not None:
            tel_tracks += ["alive_servers", "open_lanes"]
        tel_tracks += sorted(policy.telemetry_gauges(
            policy.init_state(topo)))
        tel = SimTelemetry(as_telemetry_config(telemetry), cfg.horizon,
                           cfg.warmup, topo.num_servers, cfg.max_arrivals,
                           tuple(tel_tracks))
    # Control plane (repro.control): None compiles nothing — the exact
    # pre-control step (bitwise).  Engaged, its state rides the scan carry
    # between the replication and telemetry slices.
    plane = resolve_control(control)
    ctl = None
    if plane is not None:
        ctl = plane.build_sim(topo, cfg, sched,
                              float(np.asarray(true_rates.values)[0]))
        if ctl.has_mask and not policy.supports_server_mask:
            raise ValueError(
                f"control plane {plane.describe()!r} autoscales, but policy "
                f"{policy.name!r} does not accept a server mask "
                f"(supports_server_mask=False); drop the autoscale "
                f"controller or pick a mask-aware policy")
    uses_signals = bool(getattr(policy, "uses_signals", False)) \
        and tel is not None
    # Carry layout: (state, mean_n, n_meas, completions)[+rep][+ctl][+tel].
    i_rep = 4 if rep_sim is not None else None
    i_ctl = 4 + (rep_sim is not None) if ctl is not None else None
    i_tel = 4 + (rep_sim is not None) + (ctl is not None) \
        if tel is not None else None
    # Little's-law denominator: the offered rate over the measurement
    # window is lam_total x the window's mean arrival multiplier (exactly
    # 1.0 for the static scenario and any unit-mean modulation).
    lam_scale = wl.mean_lam_mult_over(sched, cfg.warmup, cfg.horizon)
    init = functools.partial(policy.init_state, topo)

    def run(lam_total, est, seed):
        base = jax.random.PRNGKey(seed)

        def step(carry, t):
            state, mean_n, n_meas, completions = carry[:4]
            knobs = wl.slot_knobs(sched, t)
            key_t = jax.random.fold_in(base, t)
            k_arr, k_algo = jax.random.split(key_t)
            if tel is not None or ctl is not None:
                # observed BEFORE this slot's arrivals/service touch state
                n_prev = policy.num_in_system(state).astype(jnp.int32)
            if ctl is not None:
                # loadgen shapes the offered rate (closed loop gates on the
                # POLICY's in-system count, exact even under policy drops)
                lam_t, arr_cap = ctl.offered_lam(n_prev, lam_total, knobs)
            else:
                lam_t = lam_total * knobs.lam_mult
            # Arrival stream depends only on (seed, t) and the scenario:
            # identical across policies -> paired comparisons (common
            # random numbers).  The control plane consumes no random bits,
            # so CRN coupling survives engagement too.
            types, active = loc.sample_arrivals_at(
                k_arr, rack_of, lam_t, knobs.p_hot,
                knobs.hot_rack, cfg.max_arrivals, knobs.rack_weights,
                type_sampler=sample_types)
            server_mask = None
            if ctl is not None:
                # admission trims the lane mask BEFORE routing; autoscale
                # computes this slot's routable-server mask
                ctl_state, active, server_mask = ctl.pre(
                    carry[i_ctl], active, arr_cap, n_prev, lam_t,
                    t >= cfg.warmup)
            true_mk = true_k[None, :] * knobs.rate_mult
            if rep_sim is not None:
                alive = knobs.alive if knobs.alive is not None \
                    else jnp.ones(topo.num_servers, jnp.float32)
                rep_state, fg_mult = rep_sim.step(
                    carry[i_rep], alive, key_t, active, t >= cfg.warmup)
                true_mk = true_mk * fg_mult[:, None]
            step_kw = {}
            if server_mask is not None:
                step_kw["server_mask"] = server_mask
            if uses_signals:
                step_kw["signals"] = {
                    "delay_p99": tel.live_quantile(carry[i_tel], 0.99)}
            state, compl = policy.slot_step(state, k_algo, types, active,
                                            est, true_mk, ancestors,
                                            **step_kw)
            n = policy.num_in_system(state).astype(jnp.float32)
            in_window = (t >= cfg.warmup).astype(jnp.float32)
            n_meas = n_meas + in_window
            mean_n = mean_n + in_window * (n - mean_n) / jnp.maximum(n_meas, 1.0)
            completions = completions + compl * (t >= cfg.warmup)
            out_carry = (state, mean_n, n_meas, completions)
            if rep_sim is not None:
                out_carry += (rep_state,)
            if ctl is not None:
                out_carry += (ctl_state,)
            if tel is not None:
                # admissions inferred from the state delta, so arrivals the
                # policy rejected (FIFO's drops) never enter the sojourn
                # pairing; pure observation of the post-step state
                n_now = policy.num_in_system(state).astype(jnp.int32)
                extras = dict(policy.telemetry_gauges(state))
                if rep_sim is not None:
                    extras["alive_servers"] = jnp.sum(
                        alive > 0.5).astype(jnp.float32)
                    extras["open_lanes"] = jnp.sum(
                        rep_state.lane_left > 0.0).astype(jnp.float32)
                out_carry += (tel.record(carry[i_tel], t,
                                         n_now - n_prev + compl,
                                         compl, n_now, extras),)
            return out_carry, ()

        carry0 = (init(), jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0))
        if rep_sim is not None:
            carry0 += (rep_sim.init(),)
        if ctl is not None:
            carry0 += (ctl.init(),)
        if tel is not None:
            carry0 += (tel.init(),)
        carry, _ = jax.lax.scan(step, carry0, jnp.arange(cfg.horizon))
        state, mean_n, n_meas, completions = carry[:4]
        # Little's law needs a positive offered rate; lam_total == 0 used
        # to divide straight to inf — flag it as NaN instead (the host-side
        # drivers additionally reject negative loads outright).
        denom = lam_total * lam_scale
        out = {
            "mean_n": mean_n,
            "mean_delay": jnp.where(denom > 0, mean_n / denom, jnp.nan),
            "throughput": completions / jnp.maximum(n_meas, 1.0),
            "final_n": policy.num_in_system(state).astype(jnp.float32),
        }
        if ctl is not None:
            # Control reshapes the arrival stream (closed loop, shedding),
            # so Little's law must divide by what actually ENTERED the
            # system: the measured in-window admitted rate.
            adm_rate = ctl.measured_rate(carry[i_ctl], n_meas)
            out["mean_delay"] = jnp.where(adm_rate > 0, mean_n / adm_rate,
                                          jnp.nan)
        _merge_metrics(out, policy.extra_metrics(state),
                       "SlotPolicy.extra_metrics")
        if rep_sim is not None:
            _merge_metrics(out, rep_sim.metrics(carry[i_rep]),
                           "replication lifecycle")
        if ctl is not None:
            _merge_metrics(out, ctl.metrics(carry[i_ctl]), "control plane")
        if tel is not None:
            _merge_metrics(out, tel.metrics(carry[i_tel]), "telemetry")
        return out

    return run


def _fleet_engaged(fleet, policy, cfg, scenario, placement, replication,
                   telemetry, control=None) -> bool:
    """Resolve the ``fleet=`` seam shared by simulate/sweep.

    ``False`` -> dense, always.  ``True`` / a FleetConfig -> fleet path,
    raising if the configuration has no fleet step.  ``None`` (default)
    -> auto: fleet only when supported AND the topology is at least
    ``sharding.sim.FLEET_AUTO_THRESHOLD`` servers, so every paper-scale
    run keeps the faithful (bitwise-pinned) dense path.  A control plane
    always pins the dense path (the fleet step has no control seam yet).
    """
    if control is not None:
        if fleet is True or (fleet is not None and fleet is not False):
            raise ValueError("fleet=True is not supported with control=; "
                             "the fleet step has no control-plane seam yet")
        return False
    if fleet is False:
        return False
    from repro.sharding import sim as fleet_sim  # lazy: avoids a cycle
    reason = fleet_sim.fleet_supported(policy, cfg, scenario, placement,
                                       replication, telemetry)
    if fleet is None:
        return (reason is None and cfg.topo.num_servers
                >= fleet_sim.FLEET_AUTO_THRESHOLD)
    if reason is not None:
        raise ValueError(f"fleet=True requested but unsupported: {reason}")
    return True


def simulate(policy: PolicyLike, cfg: SimConfig, lam_total: float,
             est: np.ndarray, seed: int = 0,
             scenario: wl.ScenarioLike = None,
             placement: PlacementLike = None,
             replication: ReplicationLike = None,
             telemetry: TelemetryLike = None,
             control: ControlLike = None,
             fleet=None) -> Dict[str, Any]:
    """Single-configuration run (jit-compiled).  ``lam_total == 0`` yields
    ``mean_delay = NaN`` (Little's law is undefined); negative loads are
    rejected here.  Scalar metrics come back as floats; array-valued
    telemetry metrics (histograms, the series) as numpy arrays.

    ``control`` engages the control plane (`repro.control`: load
    generation, admission, autoscaling); ``None`` compiles the exact
    pre-control program.  ``fleet`` selects the fleet-scale backend
    (`repro.sharding.sim`): ``None`` auto-engages it for supported
    configurations at >= 1024 servers, ``True``/`FleetConfig` forces it
    (raising when the configuration has no fleet step), ``False`` pins
    the dense path.
    """
    if lam_total < 0:
        raise ValueError(f"lam_total must be >= 0, got {lam_total}")
    if _fleet_engaged(fleet, policy, cfg, scenario, placement, replication,
                      telemetry, control):
        from repro.sharding import sim as fleet_sim
        return fleet_sim.fleet_simulate(policy, cfg, lam_total, est, seed,
                                        fleet)
    run = jax.jit(_build_run(policy, cfg, scenario, placement, replication,
                             telemetry, control))
    out = run(jnp.float32(lam_total), jnp.asarray(est, jnp.float32),
              jnp.asarray(seed, jnp.uint32))
    res: Dict[str, Any] = {}
    for k, v in out.items():
        arr = np.asarray(v)
        res[k] = float(arr) if arr.ndim == 0 else arr
    return res


def sweep(policy: PolicyLike, cfg: SimConfig, lam_grid: np.ndarray,
          est_stack: np.ndarray, seeds: np.ndarray,
          scenario: wl.ScenarioLike = None,
          placement: PlacementLike = None,
          replication: ReplicationLike = None,
          telemetry: TelemetryLike = None,
          control: ControlLike = None,
          fleet=None) -> Dict[str, np.ndarray]:
    """Full cartesian sweep, vmapped: results have shape (L, E, S).

    lam_grid: (L,) loads; est_stack: (E, M, K); seeds: (S,).  The scenario
    schedule, the compiled placement sampler, the replication machinery,
    and the telemetry recorder are closure constants — their shapes carry
    no batch dimension, so the whole grid still compiles to one vmapped
    XLA program (lifecycle and recorder state vmap through the scan
    carry).  Telemetry metrics batch like everything else: scalars
    (delay_p50/p95/p99) come back (L, E, S), histograms (L, E, S, bins+1),
    the series (L, E, S, T_s, n_tracks).
    """
    if np.any(np.asarray(lam_grid) < 0):
        raise ValueError(f"lam_grid must be >= 0, got {lam_grid}")
    if _fleet_engaged(fleet, policy, cfg, scenario, placement, replication,
                      telemetry, control):
        from repro.sharding import sim as fleet_sim
        return fleet_sim.fleet_sweep(policy, cfg, lam_grid, est_stack,
                                     seeds, fleet)
    run = _build_run(policy, cfg, scenario, placement, replication,
                     telemetry, control)
    f = jax.vmap(jax.vmap(jax.vmap(run, (None, None, 0)), (None, 0, None)),
                 (0, None, None))
    f = jax.jit(f)
    out = f(jnp.asarray(lam_grid, jnp.float32),
            jnp.asarray(est_stack, jnp.float32),
            jnp.asarray(seeds, jnp.uint32))
    return {k: np.asarray(v) for k, v in out.items()}
