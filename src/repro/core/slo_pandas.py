"""SLO-conditioned Balanced-PANDAS: p99-aware routing and draining.

Balanced-PANDAS optimizes the MEAN workload; PR 7's tail study found that
at rho = 0.99 the mean-optimal policy is no longer the p99 winner.  This
policy closes the loop: it reads the in-scan telemetry recorder's running
sojourn-p99 estimate (`SimTelemetry.live_quantile`, delivered by the
simulator as the ``signals`` kwarg) and switches behaviour only while the
estimate breaches ``slo_target``:

  * **routing** — the score gains a ``drain_bias * W_m`` penalty, i.e.
    arrivals weigh a server's total backlog ``drain_bias`` x more heavily
    relative to its locality rate.  Under breach the policy trades
    locality for equalizing the longest workloads — exactly the regime
    where the tail lives in a few deep queues;
  * **scheduling** — idle servers serve their LONGEST queue (most tasks)
    instead of their fastest tier, draining the backlog that holds the
    oldest work (queues are FIFO within a tier, so the longest queue
    bounds the oldest waiting task).

Outside a breach — and whenever ``signals`` is absent (``telemetry=None``:
there is nothing to read) — every decision compiles to the exact
Balanced-PANDAS program: same key splits, same scores, same tie-breaks.
The signal-free path is pinned bitwise against ``balanced_pandas`` in
tests/test_control.py.  This is the documented exception to the
telemetry-purity invariant: enabling telemetry deliberately changes this
policy's sample path (``uses_signals = True``; the purity test skips it).

The breach flag is NaN-safe by construction: the live p99 is NaN until
the first completion is binned (NaN > target is False -> no breach) and
inf once the estimate passes the histogram range (inf > target is True
-> breach, correctly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import balanced_pandas as bp
from repro.core import locality as loc
from repro.core.policy import SlotPolicy, register_policy


def _route_one_slo(s, key, task, active, est, ancestors, server_mask,
                   breach, drain_bias: float):
    """`bp.route_one` with the breach-gated workload penalty (identical
    decisions — same key, same tie-break — when ``breach`` is False)."""
    tier_m = loc.server_tiers(task, ancestors)
    est_rate = jnp.take_along_axis(est, tier_m[:, None], axis=1)[:, 0]
    w = bp.workload(s, est)
    score = w / est_rate - est_rate * 1e-6
    score = jnp.where(breach, score + drain_bias * w, score)
    if server_mask is not None:
        score = jnp.where(server_mask, score, jnp.inf)
    m_star = loc.random_argmin(key, score)
    return bp.push_task(s, m_star, tier_m, active)


def _schedule_idle_slo(s, done, breach):
    """`bp.schedule_idle` whose tier pick flips to the LONGEST nonempty
    queue under breach (fastest nonempty tier otherwise)."""
    k = s.q.shape[1]
    serving = jnp.where(done, 0, s.serving)
    nonempty = s.q > 0
    fastest = jnp.argmax(nonempty, axis=1)
    longest = jnp.argmax(s.q, axis=1)
    first = jnp.where(breach, longest, fastest)
    has_task = jnp.any(nonempty, axis=1)
    take = (serving == 0) & has_task
    dec = take[:, None] & (jnp.arange(k)[None, :] == first[:, None])
    return bp.PandasState(
        q=s.q - dec.astype(jnp.int32),
        serving=jnp.where(take, first + 1, serving).astype(jnp.int32),
    )


@register_policy
class SloPandasPolicy(SlotPolicy):
    """SLO-conditioned Balanced-PANDAS: while the in-scan sojourn-p99
    estimate breaches ``slo_target`` (slots), routing adds a
    ``drain_bias`` x workload penalty and idle servers drain their
    longest queue; otherwise — and always when telemetry is off — it IS
    Balanced-PANDAS, bitwise.  Requires ``telemetry=`` to act
    (``signals`` carry the live p99); without it the breach can never be
    observed and the policy silently degrades to the base program.
    """

    name = "slo_pandas"
    supports_server_mask = True
    uses_signals = True

    def __init__(self, slo_target: float = 96.0, drain_bias: float = 0.25):
        if slo_target <= 0.0:
            raise ValueError(f"slo_target must be > 0, got {slo_target}")
        if drain_bias < 0.0:
            raise ValueError(f"drain_bias must be >= 0, got {drain_bias}")
        self.slo_target = float(slo_target)
        self.drain_bias = float(drain_bias)

    def init_state(self, topo: loc.Topology, **opts) -> bp.PandasState:
        return bp.init_state(topo)

    def slot_step(self, s, key, types, active, est, true_rates, ancestors,
                  server_mask=None, signals=None):
        if signals is None:
            # No telemetry -> nothing to condition on: the exact
            # Balanced-PANDAS program (bitwise; pinned in tests).
            return bp.slot_step(s, key, types, active, est, true_rates,
                                ancestors, server_mask=server_mask)
        breach = signals["delay_p99"] > self.slo_target
        anc = loc.as_ancestors(ancestors)
        k_route, k_serve = jax.random.split(key)

        def body(i, st):
            return _route_one_slo(st, jax.random.fold_in(k_route, i),
                                  types[i], active[i], est, anc, server_mask,
                                  breach, self.drain_bias)
        s = jax.lax.fori_loop(0, types.shape[0], body, s)
        done, completions = bp.service_completions(s, k_serve, true_rates)
        return _schedule_idle_slo(s, done, breach), completions

    def num_in_system(self, s: bp.PandasState) -> jnp.ndarray:
        return bp.num_in_system(s)

    def telemetry_gauges(self, s: bp.PandasState):
        return bp.telemetry_gauges(s)
