"""Deterministic sharded data pipeline with locality-aware chunk scheduling.

The MapReduce structure of the paper maps directly onto the input pipeline of
distributed training: the corpus is split into chunks, every chunk is
replicated on 3 data hosts — *which* hosts is the configured
`PlacementPolicy` (`repro.placement`, ``PipelineConfig.placement``;
the default "uniform" is the classic rendezvous hashing, bitwise) — and
each read is a "map task" whose service rate depends on where it runs —
on a replica host (local), on a host in the same pod (rack-local:
ICI/within-cell network), or across pods (remote: DCN).  The chunk->host assignment runs any router
registered in `core/policy.py` (Balanced-PANDAS default; JSQ-MW, FIFO,
power-of-d PANDAS selectable by name), all driven through the uniform
`route -> Decision` / `claim -> Claim` surface, with host read rates
estimated online (EWMA), so a straggling host automatically sheds load —
the robustness property the paper establishes is exactly what makes the
blind version deployable.  Time-varying faults come from the scenario
subsystem (`PipelineConfig.scenario`, `repro.workloads`): straggler windows
and congestion sags play back on the virtual clock, and the estimator
tracks them while they last — including windows replayed from a recorded
cluster trace (``scenario=ScenarioConfig("trace", {...})``).

Tokens are synthesized deterministically from (seed, chunk_id), so any two
runs — and any resharding of hosts — produce identical global batches
(byte-for-byte reproducible input pipeline, a hard requirement for elastic
restarts).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.cluster import tier_of
from repro.core.estimator import EwmaRateEstimator
from repro.core.locality import Topology
from repro.core.policy import make_router
from repro.placement import PlacementLike, make_placement
from repro.placement.policies import chunk_replicas  # noqa: F401  (canonical
# home is the placement subsystem; re-exported for the long-standing name)
from repro.replication import ReplicationLike, make_replication
from repro.telemetry import CLOCK_UNIT_US, EventRecorder
from repro.workloads import ScenarioLike, host_playback, make_scenario


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_hosts: int = 16
    hosts_per_pod: int = 8
    num_chunks: int = 1024
    tokens_per_chunk: int = 65_536
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    replication: int = 3
    scheduler: str = "balanced_pandas"
    # replica placement (repro.placement): which hosts hold each chunk.
    # None -> "uniform" (the classic rendezvous placement, bitwise).
    placement: PlacementLike = None
    # deterministic placement rebalance cadence (reads between
    # `PlacementPolicy.rebalance()` calls; 0 disables) — only meaningful
    # for popularity-driven placements (hot_aware)
    rebalance_every: int = 0
    # token unigram skew: 0.0 keeps the classic uniform synthetic tokens
    # (bitwise); > 0 draws Zipf(s)-distributed tokens so a language model
    # trained on the pipeline has learnable statistics (quickstart)
    token_skew: float = 0.0
    # mean simulated read service rates (reads per virtual-clock unit)
    rate_local: float = 1.0
    rate_rack: float = 0.8
    rate_remote: float = 0.4
    # K-tier overrides: a full `locality.Topology` for the host fleet
    # (num_hosts/hosts_per_pod are then derived from it) and a (K,)
    # tier-rate vector replacing the three rate_* fields.
    topology: Optional[Topology] = None
    tier_rates: Optional[Tuple[float, ...]] = None
    # scenario playback (repro.workloads) on the virtual clock: straggler
    # hosts and congestion windows; None -> "static" (multipliers 1.0)
    scenario: ScenarioLike = None
    scenario_horizon: float = 256.0  # virtual-time units per playback cycle
    # replication lifecycle (repro.replication): chunk replica sets become
    # time-varying — wiped on host death, repaired / widened by the
    # selected controller under the migration bandwidth cap.  None ->
    # "fixed"; the machinery only engages when a dynamic controller is
    # selected or the scenario carries a failure track, so the default
    # read path stays bitwise identical.  (`replication` above is the
    # *factor*; this picks the *controller*.)
    replication_policy: ReplicationLike = None
    # structured event tracing (repro.telemetry.EventRecorder): chunk-read
    # complete events and failover instants on the pipeline's virtual
    # clock (1 clock unit == 1 ms in the exported Chrome trace).  None ->
    # no events, zero overhead.
    tracer: Optional[EventRecorder] = None


def chunk_tokens(cfg: PipelineConfig, chunk_id: int) -> np.ndarray:
    """Deterministic synthetic tokens for one chunk: uniform by default
    (bitwise-stable across PRs), Zipf-skewed when ``cfg.token_skew > 0``
    (rank r gets mass ~ r^-skew — learnable unigram statistics)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, chunk_id]))
    if cfg.token_skew <= 0.0:
        return rng.integers(0, cfg.vocab_size, cfg.tokens_per_chunk,
                            dtype=np.int32)
    p = np.arange(1, cfg.vocab_size + 1, dtype=np.float64) ** -cfg.token_skew
    cdf = np.cumsum(p / p.sum())
    u = rng.random(cfg.tokens_per_chunk)
    return np.minimum(np.searchsorted(cdf, u),
                      cfg.vocab_size - 1).astype(np.int32)


class DataPipeline:
    """Iterator of {tokens, labels} batches with scheduler-driven reads.

    Reads run on a virtual clock: each chunk read is assigned to a host by
    the configured router and "takes" a sampled service time based on its
    true locality tier (optionally skewed by `slow_hosts` to model
    stragglers).  Observed times feed the EWMA estimator, closing the blind
    scheduling loop.  Metrics expose locality mix and per-host load.
    """

    def __init__(self, cfg: PipelineConfig,
                 slow_hosts: Optional[Dict[int, float]] = None):
        self.cfg = cfg
        # Same unified `Topology` as the JAX side (ClusterSpec retired)
        self.spec = cfg.topology if cfg.topology is not None else \
            Topology(cfg.num_hosts, cfg.hosts_per_pod)
        n_hosts = self.spec.num_servers
        self.prior = np.asarray(
            cfg.tier_rates if cfg.tier_rates is not None
            else (cfg.rate_local, cfg.rate_rack, cfg.rate_remote),
            np.float32)
        if self.prior.shape != (self.spec.num_tiers,):
            raise ValueError(f"pipeline prior has {self.prior.size} tier "
                             f"rates but the fleet has "
                             f"{self.spec.num_tiers} tiers")
        self.estimator = EwmaRateEstimator(n_hosts, self.prior)
        self.router = make_router(cfg.scheduler, self.spec, self.prior,
                                  estimator=self.estimator, seed=cfg.seed)
        # Replica placement: every chunk -> host assignment flows through
        # one PlacementPolicy (uniform == the classic `chunk_replicas`).
        self.placement = make_placement(cfg.placement)
        if cfg.rebalance_every < 0:
            raise ValueError(f"rebalance_every must be >= 0, got "
                             f"{cfg.rebalance_every}")
        self.slow = slow_hosts or {}
        # Scenario playback over the virtual clock: the same declarative
        # scenarios the simulator and serving engine run, here modelling
        # straggler hosts / congested links during read windows.
        self.playback = host_playback(make_scenario(cfg.scenario),
                                      n_hosts, cfg.scenario_horizon,
                                      num_tiers=self.spec.num_tiers,
                                      rack_of=np.asarray(self.spec.rack_of))
        # Replication lifecycle over the chunk catalogue: engaged only when
        # a controller is configured or the scenario kills hosts.
        ctrl = make_replication(cfg.replication_policy)
        if ctrl.is_static and self.playback.alive is None:
            self.replication_ctl = None
        else:
            self.replication_ctl = ctrl.build_host(
                self.spec, self.placement, cfg.num_chunks, cfg.replication,
                cfg.seed, self.prior)
        # Structured event tracing: hosts are trace tids, the virtual
        # clock maps to trace time at 1 unit == 1 ms.
        self.tracer = cfg.tracer
        if self.replication_ctl is not None:
            self.replication_ctl.tracer = self.tracer
        if self.tracer is not None:
            self.tracer.metadata("process_name", name="data_pipeline")
            for h in range(n_hosts):
                self.tracer.metadata("thread_name", tid=h, name=f"host{h}")
        self.rng = np.random.default_rng(cfg.seed + 1)
        self._clock = 0.0
        self.metrics = {"local": 0, "rack": 0, "remote": 0,
                        "reads": 0, "virtual_time": 0.0,
                        "tier_reads": np.zeros(self.spec.num_tiers, np.int64),
                        "host_reads": np.zeros(n_hosts, np.int64)}
        self._chunk_order = np.random.default_rng(cfg.seed + 2).permutation(
            cfg.num_chunks)
        self._cursor = 0  # chunk index
        self._buffer = np.empty((0,), np.int32)

    # -- scheduling ---------------------------------------------------------
    def _read_chunk(self, chunk_id: int) -> np.ndarray:
        if self.replication_ctl is not None:
            # advance the lifecycle to the virtual clock, then read from
            # the live catalogue; an all-dead chunk falls back to the
            # static placement (cold-store refetch, counted as lost)
            self.replication_ctl.observe(
                self._clock, self.playback.alive_mask_at(self._clock))
            self.replication_ctl.note_read(chunk_id)
            locs = self.replication_ctl.replicas_for(chunk_id)
            self.metrics["lost_reads"] = self.replication_ctl.lost_reads
            self.metrics["repair_moves"] = self.replication_ctl.moves
            if not locs:
                locs = self.placement.replicas(self.spec, chunk_id,
                                               self.cfg.replication,
                                               self.cfg.seed)
        else:
            locs = self.placement.replicas(self.spec, chunk_id,
                                           self.cfg.replication,
                                           self.cfg.seed)
        decision = self.router.route(locs)
        # Deferred-assignment routers (global queue) pick the host only at
        # claim time; the synchronous pipeline stands in for "whichever host
        # goes idle next" with a uniform draw.
        host = decision.worker if not decision.deferred \
            else int(self.rng.integers(self.spec.num_servers))
        if self.replication_ctl is not None \
                and not self.replication_ctl.is_alive(host):
            # failover: a dead host cannot serve — retry on the first live
            # replica (or any live host for an all-dead set)
            live = [h for h in locs if self.replication_ctl.is_alive(h)] \
                or [h for h in range(self.spec.num_servers)
                    if self.replication_ctl.is_alive(h)]
            host = live[0]
            self.metrics["failovers"] = self.metrics.get("failovers", 0) + 1
            if self.tracer is not None:
                self.tracer.instant("failover", cat="pipeline",
                                    ts_us=self._clock * CLOCK_UNIT_US,
                                    tid=host, chunk=chunk_id)
        tier = tier_of(self.spec, locs, host)
        rate = float(self.prior[tier])
        rate *= self.slow.get(host, 1.0)
        rate *= self.playback.rate_mult_at(self._clock, host, tier)
        if self.replication_ctl is not None:
            # migration endpoints serve foreground reads at the
            # contention multiplier while a copy is in flight
            rate *= self.replication_ctl.contention_mult(host)
        service = float(self.rng.exponential(1.0 / max(rate, 1e-6)))
        if self.tracer is not None:
            # the read occupies [clock, clock + service) on the host's lane
            self.tracer.complete("chunk_read",
                                 self._clock * CLOCK_UNIT_US,
                                 service * CLOCK_UNIT_US, cat="read",
                                 tid=host, chunk=chunk_id, tier=tier)
        self._clock += service
        self.router.claim(host)  # drain the queued task (read runs now)
        self.router.on_complete(host, tier, service)
        # legacy 3-way counters: "remote" is the last tier (so a 2-tier
        # fleet counts non-local reads as remote, not rack); intermediate
        # tiers (rack, pod, ...) aggregate under "rack"
        key = "local" if tier == 0 else (
            "remote" if tier == self.spec.num_tiers - 1 else "rack")
        self.metrics[key] += 1
        self.metrics["tier_reads"][tier] += 1
        self.metrics["reads"] += 1
        self.metrics["virtual_time"] = self._clock
        self.metrics["host_reads"][host] += 1
        # popularity feedback -> deterministic rebalance on a fixed cadence
        self.placement.note_read(chunk_id)
        if self.cfg.rebalance_every and \
                self.metrics["reads"] % self.cfg.rebalance_every == 0:
            self.metrics["rebalanced"] = self.metrics.get("rebalanced", 0) \
                + self.placement.rebalance()
        return chunk_tokens(self.cfg, chunk_id)

    # -- iteration ----------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        need = self.cfg.global_batch * (self.cfg.seq_len + 1)
        while self._buffer.size < need:
            chunk_id = int(self._chunk_order[self._cursor
                                             % self.cfg.num_chunks])
            self._cursor += 1
            self._buffer = np.concatenate(
                [self._buffer, self._read_chunk(chunk_id)])
        flat = self._buffer[:need].reshape(self.cfg.global_batch,
                                           self.cfg.seq_len + 1)
        self._buffer = self._buffer[need:]
        return {"tokens": flat[:, :-1].copy(), "labels": flat[:, 1:].copy()}

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        # `reads` drives the rebalance cadence and `placement` carries the
        # popularity state (hot_aware), so a restored pipeline places and
        # rebalances exactly like the uninterrupted run would have.
        out = {"cursor": self._cursor, "buffer": self._buffer.copy(),
               "clock": self._clock, "reads": int(self.metrics["reads"]),
               "placement": self.placement.state_dict()}
        if self.replication_ctl is not None:
            out["replication"] = self.replication_ctl.state_dict()
        return out

    def load_state_dict(self, s: Dict) -> None:
        self._cursor = int(s["cursor"])
        self._buffer = np.asarray(s["buffer"], np.int32)
        self._clock = float(s["clock"])
        # pre-placement checkpoints (no keys) restore as before
        self.metrics["reads"] = int(s.get("reads", self.metrics["reads"]))
        if s.get("placement"):
            self.placement.load_state_dict(s["placement"])
        if s.get("replication"):
            if self.replication_ctl is None:
                raise ValueError("checkpoint carries replication-lifecycle "
                                 "state but this pipeline has no controller "
                                 "configured (replication_policy)")
            self.replication_ctl.load_state_dict(s["replication"])

    @property
    def locality_fractions(self) -> Tuple[float, float, float]:
        r = max(self.metrics["reads"], 1)
        return (self.metrics["local"] / r, self.metrics["rack"] / r,
                self.metrics["remote"] / r)
