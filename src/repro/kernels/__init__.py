"""Pallas TPU kernels for the perf-critical compute paths:

  wwl_route        — batched Balanced-PANDAS weighted-workload argmin routing
  slot_step        — fused fleet slot-step (workload + private-route argmin)
  maxweight        — batched JSQ-MaxWeight weighted argmax claims
  flash_attention  — block-wise online-softmax attention (GQA/SWA/softcap)
  ssd_scan         — Mamba-2 SSD chunked scan

Public API lives in ops.py (padding + interpret fallback); oracles in ref.py.
"""

from repro.kernels.ops import (  # noqa: F401
    flash_attention, fleet_route, maxweight_claim, ssd, wwl_route,
)
