"""Pallas TPU kernel: block-wise online-softmax attention (FlashAttention-2
schedule adapted to the TPU memory hierarchy).

Serving the assigned LM architectures makes prefill attention the dominant
MXU workload; this kernel is the perf-critical path for prefill_32k.  TPU
adaptation: (bq, D) query tiles stay resident in VMEM while (bk, D) key/value
tiles stream HBM->VMEM; both matmuls hit the MXU with 128-aligned tiles; the
online-softmax running (max, sum, acc) live in VMEM scratch across the
sequential k-grid dimension.  GQA is handled by aliasing the kv-head block
index map (no KV replication in HBM), sliding windows and Gemma-style logit
soft-capping are fused into the tile mask, so local-attention layers skip no
memory traffic they don't need.

Full-block skipping for causal/windowed masks is intentionally left to the
masked-compute path (see EXPERIMENTS.md §Perf for the measured effect of
tightening the k-grid instead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, tq: int, tk: int,
                 kv_blocks: int):
    """Grid: (batch*heads, Tq/bq, Tk/bk); k innermost (sequential)."""
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)

    logits = jax.lax.dot_general(                     # (bq, bk) on the MXU
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)

    # Position bookkeeping: query rows are offset so the LAST query attends
    # to the LAST key (cache-aligned decode/prefill semantics).
    qpos = (iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            + (tk - tq))
    kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < tk  # key padding
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                               # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    # Rows that are fully masked so far keep m == NEG_INF; exp(0)=1 guard:
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev > NEG_INF, jnp.exp(m_prev - m_new), 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); returns (B, Hq, Tq, D).

    Semantics contract: ref.mha (GQA grouping, causal/window offsets for
    Tq != Tk, softcap).
    """
    bsz, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    scale = d ** -0.5 if scale is None else scale

    bq = min(block_q, _round_up(tq, 8))
    bk = min(block_k, _round_up(tk, 128))
    tq_p, tk_p = _round_up(tq, bq), _round_up(tk, bk)

    qf = _pad_axis(q.reshape(bsz * hq, tq, d), tq_p, 1)
    kf = _pad_axis(k.reshape(bsz * hkv, tk, d), tk_p, 1)
    vf = _pad_axis(v.reshape(bsz * hkv, tk, d), tk_p, 1)

    kv_blocks = tk_p // bk
    grid = (bsz * hq, tq_p // bq, kv_blocks)

    def kv_index(h, i, j):
        return ((h // hq) * hkv + (h % hq) // group, j, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, tq=tq, tk=tk,
        kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * hq, tq_p, d), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :tq].reshape(bsz, hq, tq, d)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_axis(x, target, axis):
    if x.shape[axis] == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, widths)
