"""Pallas TPU kernel: batched JSQ-MaxWeight claim scoring (weighted argmax).

The MaxWeight baseline's hot loop: each of B idle servers scans all N queues
for ``argmax_n w(m,n) * Q_n`` where the weight depends on server/queue
identity and rack co-membership.  Same tiling/accumulator structure as
wwl_route (see that module for the TPU-adaptation rationale), with a masked
max-reduction instead of min and the empty-queue mask folded in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.0e38


def _claim_kernel(queues_ref, qrack_ref, idle_ref, irack_ref, rates_ref,
                  score_ref, queue_ref, *, block_n: int):
    """One (idle-server-block, queue-block) tile.

    queues_ref: (bn,)   f32  queue lengths of this block
    qrack_ref:  (bn,)   i32  rack of each queue's owner
    idle_ref:   (bb,)   i32  idle server ids
    irack_ref:  (bb,)   i32  idle server racks
    rates_ref:  (bb, 3) f32  per-idle-server estimated rates
    score_ref:  (bb,)   f32  running max score (output, revisited)
    queue_ref:  (bb,)   i32  running argmax    (output, revisited)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, NEG_INF)
        queue_ref[...] = jnp.zeros_like(queue_ref)

    q = queues_ref[...]
    qrack = qrack_ref[...]
    idle = idle_ref[...]
    irack = irack_ref[...]
    rates = rates_ref[...]

    bb, bn = idle.shape[0], q.shape[0]
    qid = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)

    is_self = qid == idle[:, None]
    same_rack = jnp.broadcast_to(qrack[None, :], (bb, bn)) == irack[:, None]
    w = jnp.where(is_self, rates[:, 0:1],
                  jnp.where(same_rack, rates[:, 1:2], rates[:, 2:3]))
    score = jnp.where(q[None, :] > 0, w * q[None, :], NEG_INF)

    blk_max = jnp.max(score, axis=1)
    blk_arg = jnp.argmax(score, axis=1).astype(jnp.int32)

    best = score_ref[...]
    better = blk_max > best  # strict: lowest queue index on ties
    score_ref[...] = jnp.where(better, blk_max, best)
    queue_ref[...] = jnp.where(better, j * block_n + blk_arg, queue_ref[...])


@functools.partial(jax.jit, static_argnames=("block_idle", "block_queues",
                                             "interpret"))
def maxweight_claim_pallas(queues: jnp.ndarray, queue_rack: jnp.ndarray,
                           idle_servers: jnp.ndarray, idle_rack: jnp.ndarray,
                           est_rates: jnp.ndarray, *, block_idle: int = 128,
                           block_queues: int = 512, interpret: bool = False):
    """Padded, tiled argmax claims.  See ref.maxweight_claim for semantics.
    Padding queues must carry Q=0 (masked), padding idle rows are sliced off
    by ops.maxweight_claim."""
    b = idle_servers.shape[0]
    n = queues.shape[0]
    grid = (b // block_idle, n // block_queues)

    kernel = functools.partial(_claim_kernel, block_n=block_queues)
    score, queue = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_queues,), lambda i, j: (j,)),
            pl.BlockSpec((block_queues,), lambda i, j: (j,)),
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
            pl.BlockSpec((block_idle, 3), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(queues.astype(jnp.float32), queue_rack.astype(jnp.int32),
      idle_servers.astype(jnp.int32), idle_rack.astype(jnp.int32),
      est_rates.astype(jnp.float32))
    return queue, score
