"""Pallas TPU kernel: batched JSQ-MaxWeight claim scoring (weighted argmax).

The MaxWeight baseline's hot loop: each of B idle servers scans all N queues
for ``argmax_n w(m,n) * Q_n`` where the weight depends on server/queue
identity and the deepest hierarchy level the pair shares — derived from the
``(depth, .)`` ancestor tables (`Topology.ancestors`), with the depth loop
unrolled at trace time so the K=3 instance lowers to exactly one rack
comparison.  Same tiling/accumulator structure as wwl_route (see that
module for the TPU-adaptation rationale), with a masked max-reduction
instead of min and the empty-queue mask folded in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -3.0e38


def _claim_kernel(queues_ref, qanc_ref, idle_ref, ianc_ref, rates_ref,
                  score_ref, queue_ref, *, block_n: int, depth: int):
    """One (idle-server-block, queue-block) tile.

    queues_ref: (bn,)      f32  queue lengths of this block
    qanc_ref:   (D, bn)    i32  ancestor table of each queue's owner
    idle_ref:   (bb,)      i32  idle server ids
    ianc_ref:   (D, bb)    i32  ancestor table of each idle server
    rates_ref:  (bb, K)    f32  per-idle-server estimated tier rates
    score_ref:  (bb,)      f32  running max score (output, revisited)
    queue_ref:  (bb,)      i32  running argmax    (output, revisited)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, NEG_INF)
        queue_ref[...] = jnp.zeros_like(queue_ref)

    q = queues_ref[...]
    idle = idle_ref[...]
    rates = rates_ref[...]

    bb, bn = idle.shape[0], q.shape[0]
    qid = j * block_n + jax.lax.broadcasted_iota(jnp.int32, (bb, bn), 1)

    is_self = qid == idle[:, None]
    # remote weight by default; sharpen level by level, deepest first
    w = jnp.broadcast_to(rates[:, depth + 1:depth + 2], (bb, bn))
    for lvl in range(depth - 1, -1, -1):
        qrow = qanc_ref[lvl, :]                # (bn,)
        irow = ianc_ref[lvl, :]                # (bb,)
        share = jnp.broadcast_to(qrow[None, :], (bb, bn)) == irow[:, None]
        w = jnp.where(share, rates[:, lvl + 1:lvl + 2], w)
    w = jnp.where(is_self, rates[:, 0:1], w)
    score = jnp.where(q[None, :] > 0, w * q[None, :], NEG_INF)

    blk_max = jnp.max(score, axis=1)
    blk_arg = jnp.argmax(score, axis=1).astype(jnp.int32)

    best = score_ref[...]
    better = blk_max > best  # strict: lowest queue index on ties
    score_ref[...] = jnp.where(better, blk_max, best)
    queue_ref[...] = jnp.where(better, j * block_n + blk_arg, queue_ref[...])


@functools.partial(jax.jit, static_argnames=("block_idle", "block_queues",
                                             "interpret"))
def maxweight_claim_pallas(queues: jnp.ndarray, queue_anc: jnp.ndarray,
                           idle_servers: jnp.ndarray, idle_anc: jnp.ndarray,
                           est_rates: jnp.ndarray, *, block_idle: int = 128,
                           block_queues: int = 512, interpret: bool = False):
    """Padded, tiled argmax claims.  See ref.maxweight_claim for semantics.
    queue_anc (depth, N) / idle_anc (depth, B) are ancestor tables;
    est_rates (B, depth + 2).  Padding queues must carry Q=0 (masked),
    padding idle rows are sliced off by ops.maxweight_claim."""
    b = idle_servers.shape[0]
    n = queues.shape[0]
    depth = queue_anc.shape[0]
    grid = (b // block_idle, n // block_queues)

    kernel = functools.partial(_claim_kernel, block_n=block_queues,
                               depth=depth)
    score, queue = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_queues,), lambda i, j: (j,)),
            pl.BlockSpec((depth, block_queues), lambda i, j: (0, j)),
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
            pl.BlockSpec((depth, block_idle), lambda i, j: (0, i)),
            pl.BlockSpec((block_idle, depth + 2), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
            pl.BlockSpec((block_idle,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(queues.astype(jnp.float32), queue_anc.astype(jnp.int32),
      idle_servers.astype(jnp.int32), idle_anc.astype(jnp.int32),
      est_rates.astype(jnp.float32))
    return queue, score
