"""Public jit'd wrappers for the Pallas kernels: padding to block multiples,
CPU interpret-mode fallback, and shape plumbing.

On TPU the kernels run compiled; everywhere else (this CPU container, unit
tests) they run with ``interpret=True`` which executes the kernel body in
Python/XLA-CPU with identical semantics — that is how correctness is
validated against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import maxweight as _mw
from repro.kernels import ref
from repro.kernels import slot_step as _slot
from repro.kernels import ssd_scan as _ssd
from repro.kernels import wwl_route as _wwl


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int, value):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _dilate_depth0(est, ids):
    """Depth-0 (K=2) fleets reach the kernels as a synthetic depth-1 table
    whose groups are the server ids themselves (share <=> local, which the
    local override supersedes) with the remote rate duplicated into the
    unused middle column; callers remap nonzero tiers back to 1."""
    anc = jnp.asarray(ids, jnp.int32)[None, :]
    est = jnp.concatenate([est[:, :1], est[:, 1:2], est[:, 1:2]], axis=1)
    return anc, est


def wwl_route(workload, est_rates, server_anc, task_locals, *,
              block_tasks: int = 128, block_servers: int = 512,
              interpret: bool | None = None):
    """Batched Balanced-PANDAS routing. See ref.wwl_route for semantics.

    `server_anc` is the (depth, M) `Topology.ancestors` table (a legacy
    (M,) rack map is accepted).  Accepts arbitrary B, M; pads internally
    (padding servers get +inf workload and rate 1 so they never win the
    argmin; their pad ancestor ids collide only with each other).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, m = task_locals.shape[0], workload.shape[0]
    anc = jnp.asarray(server_anc, jnp.int32)
    anc = anc[None, :] if anc.ndim == 1 else anc
    er = jnp.asarray(est_rates, jnp.float32)
    k2 = anc.shape[0] == 0
    if k2:
        anc, er = _dilate_depth0(er, jnp.arange(m))
    bs = min(block_servers, _round_up(m, 128))
    bt = min(block_tasks, _round_up(b, 8))
    wl = _pad_to(jnp.asarray(workload, jnp.float32), bs, 0, np.float32(3e38))
    er = _pad_to(er, bs, 0, 1.0)
    sa = _pad_to(anc, bs, 1, np.int32(2**30))
    tl = _pad_to(jnp.asarray(task_locals, jnp.int32), bt, 0, 0)
    server, tier, score = _wwl.wwl_route_pallas(
        wl, er, sa, tl, block_tasks=bt, block_servers=bs, interpret=interpret)
    server, tier, score = server[:b], tier[:b], score[:b]
    if k2:
        tier = jnp.minimum(tier, 1)  # collapse the synthetic level
    return server, tier, score


def fleet_route(q, serving, est_rates, server_anc, task_locals, *,
                block_tasks: int = 128, block_servers: int = 512,
                interpret: bool | None = None):
    """Fused fleet slot-step private routing.  See ref.fleet_route.

    `server_anc` is the (depth, M) `Topology.ancestors` table (a legacy
    (M,) rack map is accepted).  Accepts arbitrary B, M; pads internally
    (padding servers carry q=0/serving=0/rate=1 and pad ancestor ids that
    collide only with each other, so they sit on the masked remote tier
    and never win the argmin).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, m = task_locals.shape[0], q.shape[0]
    anc = jnp.asarray(server_anc, jnp.int32)
    anc = anc[None, :] if anc.ndim == 1 else anc
    er = jnp.asarray(est_rates, jnp.float32)
    qf = jnp.asarray(q, jnp.float32)
    k2 = anc.shape[0] == 0
    if k2:
        anc, er = _dilate_depth0(er, jnp.arange(m))
        qf = jnp.concatenate([qf[:, :1], jnp.zeros_like(qf[:, :1]),
                              qf[:, 1:2]], axis=1)
    bs = min(block_servers, _round_up(m, 128))
    bt = min(block_tasks, _round_up(b, 8))
    qf = _pad_to(qf, bs, 0, 0.0)
    sv = _pad_to(jnp.asarray(serving, jnp.int32), bs, 0, 0)
    er = _pad_to(er, bs, 0, 1.0)
    sa = _pad_to(anc, bs, 1, np.int32(2**30))
    tl = _pad_to(jnp.asarray(task_locals, jnp.int32), bt, 0, 0)
    server, tier, score = _slot.fleet_route_pallas(
        qf, sv, er, sa, tl, block_tasks=bt, block_servers=bs,
        interpret=interpret)
    server, tier, score = server[:b], tier[:b], score[:b]
    if k2:
        tier = jnp.minimum(tier, 1)  # collapse the synthetic level
    return server, tier, score


def maxweight_claim(queues, queue_anc, idle_servers, idle_anc, est_rates, *,
                    block_idle: int = 128, block_queues: int = 512,
                    interpret: bool | None = None):
    """Batched JSQ-MaxWeight claims. See ref.maxweight_claim.  Ancestor
    tables are (depth, N)/(depth, B) (legacy rack maps accepted).  Padding
    queues carry Q=0 (masked out); padding idle rows sliced off."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, n = idle_servers.shape[0], queues.shape[0]
    qa = jnp.asarray(queue_anc, jnp.int32)
    qa = qa[None, :] if qa.ndim == 1 else qa
    ia = jnp.asarray(idle_anc, jnp.int32)
    ia = ia[None, :] if ia.ndim == 1 else ia
    ids = jnp.asarray(idle_servers, jnp.int32)
    er = jnp.asarray(est_rates, jnp.float32)
    if qa.shape[0] == 0:  # depth-0 (K=2) fleet
        qa = jnp.arange(n, dtype=jnp.int32)[None, :]
        ia, er = _dilate_depth0(er, ids)
    bq = min(block_queues, _round_up(n, 128))
    bi = min(block_idle, _round_up(b, 8))
    q = _pad_to(jnp.asarray(queues, jnp.float32), bq, 0, 0.0)
    qa = _pad_to(qa, bq, 1, np.int32(2**30))
    ids = _pad_to(ids, bi, 0, 0)
    ia = _pad_to(ia, bi, 1, np.int32(2**30 - 1))
    er = _pad_to(er, bi, 0, 1.0)
    queue, score = _mw.maxweight_claim_pallas(
        q, qa, ids, ia, er, block_idle=bi, block_queues=bq,
        interpret=interpret)
    return queue[:b], score[:b]


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Block-wise online-softmax attention (GQA/SWA/softcap).

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D).  See ref.mha for semantics.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def ssd(x, a, b, c, init_state=None, *, block_t: int = 128,
        interpret: bool | None = None):
    """Mamba-2 SSD chunked scan.  See ref.ssd for semantics."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _ssd.ssd_chunked(x, a, b, c, init_state=init_state,
                            block_t=block_t, interpret=interpret)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# Re-exported oracles for convenience in tests/benchmarks.
reference = ref
