"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: kernel tests sweep shapes/dtypes and
assert_allclose against these functions.  They are also the fallback path on
backends where the kernels are not worth launching (tiny shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- wwl_route ---

def _as_anc(x: jnp.ndarray) -> jnp.ndarray:
    """Normalize a legacy (M,)/(B,) rack map to a (depth, ...) table."""
    a = jnp.asarray(x)
    return a[None] if a.ndim == 1 else a


def wwl_route(workload: jnp.ndarray, est_rates: jnp.ndarray,
              server_anc: jnp.ndarray, task_locals: jnp.ndarray):
    """Batched Balanced-PANDAS routing against a workload snapshot.

    workload:    (M,)   f32  estimated weighted workload per server
    est_rates:   (M,K)  f32  per-server estimated tier rates (fastest first)
    server_anc:  (D,M)  i32  ancestor-group id per (level, server) — the
                             `Topology.ancestors` table; a legacy (M,)
                             rack map is accepted (D = 1, K = 3)
    task_locals: (B,3)  i32  local servers per task

    Returns (server (B,) i32, tier (B,) i32 in 0..K-1 (0 local, K-1
    remote), score (B,) f32).  Ties break to the lowest server index
    (deterministic; the sequential simulator keeps the paper's random
    tie-breaking).
    """
    anc = _as_anc(server_anc)
    d, m = anc.shape
    sid = jnp.arange(m, dtype=task_locals.dtype)
    local = jnp.any(sid[None, :, None] == task_locals[:, None, :], axis=-1)
    tier = jnp.full(local.shape, d + 1, jnp.int32)
    rate = jnp.broadcast_to(est_rates[None, :, d + 1], local.shape)
    for lvl in range(d - 1, -1, -1):
        row = anc[lvl]
        task_groups = row[task_locals]  # (B, 3)
        share = jnp.any(row[None, :, None] == task_groups[:, None, :],
                        axis=-1)
        tier = jnp.where(share, lvl + 1, tier)
        rate = jnp.where(share, est_rates[None, :, lvl + 1], rate)
    tier = jnp.where(local, 0, tier)
    rate = jnp.where(local, est_rates[None, :, 0], rate)
    score = workload[None, :] / rate  # (B, M)
    server = jnp.argmin(score, axis=1).astype(jnp.int32)
    b = jnp.arange(task_locals.shape[0])
    return server, tier[b, server], score[b, server]


def fleet_route(q: jnp.ndarray, serving: jnp.ndarray, est_rates: jnp.ndarray,
                server_anc: jnp.ndarray, task_locals: jnp.ndarray):
    """Fused fleet slot-step private routing (workload + masked argmin).

    q:           (M,K)  f32/i32 waiting tasks per (server, tier)
    serving:     (M,)   i32     class in service (0 idle, 1..K)
    est_rates:   (M,K)  f32     per-server estimated tier rates
    server_anc:  (D,M)  i32     ancestor table (legacy (M,) rack map ok)
    task_locals: (B,3)  i32     local servers per task

    Workload is computed from (q, serving) exactly as
    `core.balanced_pandas.workload` (left-associative tier sum plus the
    in-service residual), then each task argmins W_m / rate - rate * 1e-6
    over its *private* servers only — those at a tier strictly better
    than remote (tier < K-1).  Remote-tier servers are masked out; the
    fleet backend fills the remote pool by water-filling instead of
    per-task argmin.  Returns (server (B,) i32, tier (B,) i32, score
    (B,) f32 with +LARGE for tasks whose best option is remote).  Ties
    break to the lowest server index.
    """
    anc = _as_anc(server_anc)
    d, m = anc.shape
    est = jnp.asarray(est_rates, jnp.float32)
    qf = jnp.asarray(q, jnp.float32)
    k = qf.shape[1]
    w = qf[:, 0] / est[:, 0]
    for t in range(1, k):
        w = w + qf[:, t] / est[:, t]
    resid_idx = jnp.clip(serving - 1, 0, k - 1)
    resid = jnp.take_along_axis(est, resid_idx[:, None], axis=1)[:, 0]
    w = w + jnp.where(serving > 0, 1.0 / resid, 0.0)

    sid = jnp.arange(m, dtype=task_locals.dtype)
    local = jnp.any(sid[None, :, None] == task_locals[:, None, :], axis=-1)
    tier = jnp.full(local.shape, d + 1, jnp.int32)
    rate = jnp.broadcast_to(est[None, :, d + 1], local.shape)
    for lvl in range(d - 1, -1, -1):
        row = anc[lvl]
        task_groups = row[task_locals]  # (B, 3)
        share = jnp.any(row[None, :, None] == task_groups[:, None, :],
                        axis=-1)
        tier = jnp.where(share, lvl + 1, tier)
        rate = jnp.where(share, est[None, :, lvl + 1], rate)
    tier = jnp.where(local, 0, tier)
    rate = jnp.where(local, est[None, :, 0], rate)
    score = w[None, :] / rate - rate * 1e-6
    score = jnp.where(tier <= d, score, 3.0e38)
    server = jnp.argmin(score, axis=1).astype(jnp.int32)
    b = jnp.arange(task_locals.shape[0])
    return server, tier[b, server], score[b, server]


# ------------------------------------------------------------- maxweight ---

def maxweight_claim(queues: jnp.ndarray, queue_anc: jnp.ndarray,
                    idle_servers: jnp.ndarray, idle_anc: jnp.ndarray,
                    est_rates: jnp.ndarray):
    """Batched JSQ-MaxWeight claim scoring against a queue snapshot.

    queues:       (N,)   f32/i32 queue lengths
    queue_anc:    (D,N)  i32     ancestor table of each queue's owner
    idle_servers: (B,)   i32     ids of idle servers
    idle_anc:     (D,B)  i32     ancestor table of each idle server
    est_rates:    (B,K)  f32     estimated tier rates per idle server

    Legacy (N,)/(B,) rack maps are accepted (D = 1, K = 3).  Returns
    (queue (B,) i32, score (B,) f32): argmax_n w(m,n) * Q_n with empty
    queues masked to -inf.  Lowest-index tie-break.
    """
    q_anc, i_anc = _as_anc(queue_anc), _as_anc(idle_anc)
    d, n = q_anc.shape
    qid = jnp.arange(n, dtype=idle_servers.dtype)
    is_self = idle_servers[:, None] == qid[None, :]
    w = jnp.broadcast_to(est_rates[:, d + 1:d + 2], is_self.shape)
    for lvl in range(d - 1, -1, -1):
        share = i_anc[lvl][:, None] == q_anc[lvl][None, :]
        w = jnp.where(share, est_rates[:, lvl + 1:lvl + 2], w)
    w = jnp.where(is_self, est_rates[:, 0:1], w)
    score = jnp.where(queues[None, :] > 0, w * queues[None, :], -jnp.inf)
    queue = jnp.argmax(score, axis=1).astype(jnp.int32)
    b = jnp.arange(idle_servers.shape[0])
    return queue, score[b, queue]


# ------------------------------------------------------- flash attention ---

def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: int = 0, softcap: float = 0.0,
        scale: float | None = None) -> jnp.ndarray:
    """Reference multi-head attention with GQA, sliding window and softcap.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0.
    window > 0 -> sliding-window causal attention of that width.
    softcap > 0 -> logits = softcap * tanh(logits / softcap) (Gemma-2).
    Decode is Tq == 1 against a Tk-long cache (pass causal=False and mask via
    kv_len semantics upstream).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qr = q.reshape(b, hkv, group, tq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    tk = k.shape[2]
    qpos = jnp.arange(tq)[:, None] + (tk - tq)  # align cache offsets
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, tq, d).astype(q.dtype)


# --------------------------------------------------------------- ssd scan ---

def ssd(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
        init_state: jnp.ndarray | None = None):
    """Reference Mamba-2 SSD (state-space dual) recurrence, sequential form.

    x: (B, T, H, P)   inputs per head (P = head dim)
    a: (B, T, H)      per-step log-decay (a_t = exp(log_a) in (0,1])
    b: (B, T, N)      input projection onto state (N = state dim)
    c: (B, T, N)      output projection
    init_state: (B, H, P, N) or None.

    h_t = a_t * h_{t-1} + x_t (outer) b_t ;  y_t = h_t @ c_t
    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xt, at, bt, ct = inp
        state = state * at[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32))
        yt = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, yt

    xs = (x.swapaxes(0, 1), jnp.exp(a).swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1), c.swapaxes(0, 1))
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final
