"""Pallas TPU kernel: fused fleet slot-step routing (workload + private argmin).

The fleet backend (`sharding/sim.py`) splits Balanced-PANDAS routing of a
B-task arrival batch into a *private* phase (each task scores the servers
that are local / rack-local / ... / anything better than the remote tier)
and a shared *pool* phase (the remote tier is filled globally by a
water-level computation, outside this kernel — it couples all tasks in the
slot).  This kernel fuses the private phase with the workload computation
it consumes:

    W_m     = sum_k q[m, k] / est[m, k]  (+ in-service residual)
    score   = W_m / est[m, tier(m, task)] - est[...] * 1e-6
    out_b   = argmin over servers with tier(m, task) < K-1

so one kernel launch replaces the per-slot chain of dense XLA ops
(workload reduction, per-task tier derivation, masked argmin) that
dominates dispatch time on CPU at M >= 10^4.  Compare `wwl_route.py`,
which scores ALL servers (including the remote tier) against a
precomputed workload vector: the fused kernel reads the raw policy state
(q, serving) instead, and masks the remote tier out, because the fleet
path assigns remote traffic by water-filling rather than per-task argmin
(B tasks hitting the same remote argmin would pile onto one server —
see docs/scaling.md).

The ``- rate * 1e-6`` term is the same infinitesimal faster-tier
preference the sequential simulator applies on exact workload ties
(`core/balanced_pandas.route_one`); tie-breaking among equal scores is
lowest-server-index (deterministic), as in the other scheduling kernels.

Semantics contract: `ref.fleet_route`.  The XLA realization used for the
CPU hot loop lives in `sharding/sim.py` (segment-min candidates); it is
exact against the same oracle (fuzzed in tests/test_fleet_scale.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LARGE = 3.0e38  # +inf surrogate inside min-accumulators (matches wwl_route)


def _fleet_route_kernel(q_ref, serving_ref, rates_ref, anc_ref, locals_ref,
                        lanc_ref, score_ref, server_ref, tier_ref, *,
                        block_m: int, depth: int):
    """One (task-block, server-block) tile.

    q_ref:       (bm, K)      f32   waiting tasks per (server, tier)
    serving_ref: (bm,)        i32   class in service (0 idle, 1..K)
    rates_ref:   (bm, K)      f32   est tier rates slice (K = depth + 2)
    anc_ref:     (D, bm)      i32   ancestor table slice of this block
    locals_ref:  (bt, 3)      i32   task local servers
    lanc_ref:    (bt, D, 3)   i32   ancestor groups of those locals
    score_ref:   (bt,)        f32   running min private score   (revisited)
    server_ref:  (bt,)        i32   running argmin server       (revisited)
    tier_ref:    (bt,)        i32   tier at argmin              (revisited)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, LARGE)
        server_ref[...] = jnp.zeros_like(server_ref)
        tier_ref[...] = jnp.zeros_like(tier_ref)

    q = q_ref[...]                             # (bm, K)
    rates = rates_ref[...]                     # (bm, K)
    serving = serving_ref[...]                 # (bm,)
    locs = locals_ref[...]                     # (bt, 3)
    k = q.shape[1]

    # fused workload: left-associative tier sum + in-service residual,
    # matching core/balanced_pandas.workload bit-for-bit
    w = q[:, 0] / rates[:, 0]
    for t in range(1, k):
        w = w + q[:, t] / rates[:, t]
    resid_idx = jnp.clip(serving - 1, 0, k - 1)
    resid_rate = jnp.take_along_axis(rates, resid_idx[:, None], axis=1)[:, 0]
    w = w + jnp.where(serving > 0, 1.0 / resid_rate, 0.0)

    bt = locs.shape[0]
    bm = w.shape[0]
    sid = j * block_m + jax.lax.broadcasted_iota(jnp.int32, (bt, bm), 1)

    local = (sid == locs[:, 0:1]) | (sid == locs[:, 1:2]) | (sid == locs[:, 2:3])
    # remote by default; sharpen tier/rate level by level, deepest first —
    # the depth loop is unrolled at trace time (static shape)
    tier = jnp.full((bt, bm), depth + 1, jnp.int32)
    rate = jnp.broadcast_to(rates[None, :, depth + 1], (bt, bm))
    for lvl in range(depth - 1, -1, -1):
        anc_row = anc_ref[lvl, :]              # (bm,)
        lanc = lanc_ref[...][:, lvl, :]        # (bt, 3)
        rk = jnp.broadcast_to(anc_row[None, :], (bt, bm))
        share = ((rk == lanc[:, 0:1]) | (rk == lanc[:, 1:2])
                 | (rk == lanc[:, 2:3]))
        tier = jnp.where(share, lvl + 1, tier)
        rate = jnp.where(share, rates[None, :, lvl + 1], rate)
    tier = jnp.where(local, 0, tier)
    rate = jnp.where(local, rates[None, :, 0], rate)
    score = jnp.broadcast_to(w[None, :], (bt, bm)) / rate - rate * 1e-6
    # the private mask: the remote tier (K-1 = depth+1) is pool-filled
    score = jnp.where(tier <= depth, score, LARGE)

    blk_min = jnp.min(score, axis=1)                       # (bt,)
    blk_arg = jnp.argmin(score, axis=1).astype(jnp.int32)  # (bt,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)[:, 0]
    blk_tier = tier[rows, blk_arg]

    best = score_ref[...]
    better = blk_min < best                    # strict: keeps lowest index
    score_ref[...] = jnp.where(better, blk_min, best)
    server_ref[...] = jnp.where(better, j * block_m + blk_arg, server_ref[...])
    tier_ref[...] = jnp.where(better, blk_tier, tier_ref[...])


@functools.partial(jax.jit, static_argnames=("block_tasks", "block_servers",
                                             "interpret"))
def fleet_route_pallas(q: jnp.ndarray, serving: jnp.ndarray,
                       est_rates: jnp.ndarray, server_anc: jnp.ndarray,
                       task_locals: jnp.ndarray, *, block_tasks: int = 128,
                       block_servers: int = 512, interpret: bool = False):
    """Padded, tiled fused workload + private-route.  See ref.fleet_route.

    q (M, K) f32, serving (M,) i32, est_rates (M, K) f32, server_anc the
    (depth, M) ancestor table.  Caller guarantees M % block_servers == 0
    and B % block_tasks == 0 (ops.fleet_route pads; padding servers carry
    pad ancestor ids that collide only with each other, so they land on
    the masked remote tier and never win).
    """
    b = task_locals.shape[0]
    m = q.shape[0]
    depth = server_anc.shape[0]
    grid = (b // block_tasks, m // block_servers)
    task_lanc = jnp.swapaxes(server_anc[:, task_locals], 0, 1)

    kernel = functools.partial(_fleet_route_kernel, block_m=block_servers,
                               depth=depth)
    score, server, tier = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_servers, depth + 2), lambda i, j: (j, 0)),
            pl.BlockSpec((block_servers,), lambda i, j: (j,)),
            pl.BlockSpec((block_servers, depth + 2), lambda i, j: (j, 0)),
            pl.BlockSpec((depth, block_servers), lambda i, j: (0, j)),
            pl.BlockSpec((block_tasks, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_tasks, depth, 3), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), serving.astype(jnp.int32),
      est_rates.astype(jnp.float32), server_anc.astype(jnp.int32),
      task_locals.astype(jnp.int32), task_lanc.astype(jnp.int32))
    return server, tier, score
