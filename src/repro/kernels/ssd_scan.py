"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

The SSD recurrence  h_t = a_t h_{t-1} + x_t b_t^T,  y_t = h_t c_t  is the
compute core of mamba2-1.3b and the Mamba layers of jamba; long_500k decode
and train_4k both hinge on it.  A naive scan is sequential over T; the SSD
insight (Dao & Gu 2024) is that within a chunk of L steps the output is a
masked (L, L) matmul — MXU food — and only the chunk-to-chunk state carry is
sequential.

TPU adaptation: chunk length L=128 matches the MXU tile; the (P, N) state
lives in VMEM scratch and persists across the sequential chunk grid
dimension; all four big products (C·Bᵀ, scores·X, C·state, Xᵀ·decayed-B) are
128-aligned matmuls.  Decay factors use exp of cumulative log-decay with
a_log <= 0, so every exponent is <= 0 and the kernel is overflow-free.

Inputs (see ref.ssd): x (B,T,H,P), a_log (B,T,H) <= 0, b (B,T,N), c (B,T,N).
Grid: (B, H, T/L), chunk innermost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref, state_scr,
                *, chunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = h0_ref[0, 0].astype(jnp.float32)  # (P, N)

    x = x_ref[0, :, 0, :].astype(jnp.float32)   # (L, P)
    a = a_ref[0, :, 0].astype(jnp.float32)      # (L,)  log-decay, <= 0
    bmat = b_ref[0].astype(jnp.float32)         # (L, N)
    cmat = c_ref[0].astype(jnp.float32)         # (L, N)
    state = state_scr[...]                      # (P, N)

    lcum = jnp.cumsum(a)                        # (L,) cumulative log-decay
    # Intra-chunk: scores[t, s] = exp(lcum[t]-lcum[s]) * <c_t, b_s>, s <= t.
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ldiff = lcum[:, None] - lcum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
           >= jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    decay = jnp.where(tri, jnp.exp(jnp.minimum(ldiff, 0.0)), 0.0)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, P)

    # Inter-chunk: carry-in state contribution y += exp(lcum) * (C @ stateᵀ).
    y += jnp.exp(lcum)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # State update: h' = exp(total) h + Σ_s exp(total - lcum[s]) x_s b_sᵀ.
    total = lcum[-1]
    w = jnp.exp(total - lcum)[:, None] * bmat   # (L, N)
    state_scr[...] = (jnp.exp(total) * state
                      + jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(j == chunks - 1)
    def _finish():
        hT_ref[0, 0] = state_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ssd_chunked(x, a, b, c, init_state=None, *, block_t: int = 128,
                interpret: bool = False):
    """See ref.ssd for semantics.  T must be padded to block_t by the caller
    or here (padding steps carry a_log=0, b=0 -> state passes through)."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    lt = min(block_t, _round_up(t, 8))
    t_p = _round_up(t, lt)
    if t_p != t:
        x = _pad_axis(x, t_p, 1)
        a = _pad_axis(a, t_p, 1)      # a_log = 0 -> decay 1 (state carried)
        b = _pad_axis(b, t_p, 1)      # b = 0 -> no state injection
        c = _pad_axis(c, t_p, 1)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    chunks = t_p // lt
    grid = (bsz, h, chunks)
    y, h_final = pl.pallas_call(
        functools.partial(_ssd_kernel, chunks=chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lt, 1, p), lambda i, hh, j: (i, j, hh, 0)),
            pl.BlockSpec((1, lt, 1), lambda i, hh, j: (i, j, hh)),
            pl.BlockSpec((1, lt, n), lambda i, hh, j: (i, j, 0)),
            pl.BlockSpec((1, lt, n), lambda i, hh, j: (i, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, hh, j: (i, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lt, 1, p), lambda i, hh, j: (i, j, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, hh, j: (i, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t_p, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c, init_state)
    return y[:, :t], h_final


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pad_axis(x, target, axis):
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, widths)
