"""Pallas TPU kernel: batched Balanced-PANDAS routing (weighted-workload argmin).

At fleet scale the scheduler's hot loop is, per tick: for each of B arriving
tasks, find ``argmin_m W_m / rate(m, task)`` over M servers, where the rate
tier (local / rack-local / pod-local / ... / remote) is derived from the
task's 3 replica holders and a ``(depth, M)`` **ancestor table** (row l =
each server's group id at hierarchy level l — `Topology.ancestors`).  B and
M both reach 10^4-10^5, so the (B, M) score matrix never fits VMEM at once —
we tile it.

TPU adaptation (vs. the CPU/host scheduler the paper assumes): this is a
VPU-bound masked reduction, not a matmul, so the MXU is idle; what matters is
(a) 8x128-aligned tiles, (b) streaming the server axis through VMEM while
keeping a running (min, argmin) accumulator per task row, and (c) deriving
the locality tier on the fly from 3 x depth integer comparisons per
(task, server) pair — the depth loop is unrolled at trace time (depth is a
static shape), so the K=3 instance lowers to exactly the one rack
comparison the seed shipped — instead of materializing a (B, M) tier
matrix in HBM.

Grid: (B/bt, M/bm) with the server axis innermost.  Accumulators live in the
output block (revisited across the inner dimension — standard Pallas
reduction pattern).

Tie-breaking is lowest-server-index (deterministic).  The faithful simulator
(core/) keeps the paper's random tie-breaking; the production router uses
this kernel where determinism is a feature (replayable scheduling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_LARGE = 3.0e38


def _route_kernel(workload_ref, rates_ref, anc_ref, locals_ref, lanc_ref,
                  score_ref, server_ref, tier_ref, *, block_m: int,
                  depth: int):
    """One (task-block, server-block) tile.

    workload_ref: (bm,)        f32   workload slice of this server block
    rates_ref:    (bm, K)      f32   est tier rates slice (K = depth + 2)
    anc_ref:      (D, bm)      i32   ancestor table slice of this block
    locals_ref:   (bt, 3)      i32   task local servers
    lanc_ref:     (bt, D, 3)   i32   ancestor groups of those locals
    score_ref:    (bt,)        f32   running min score     (output, revisited)
    server_ref:   (bt,)        i32   running argmin server (output, revisited)
    tier_ref:     (bt,)        i32   tier at argmin        (output, revisited)
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        score_ref[...] = jnp.full_like(score_ref, NEG_LARGE)
        server_ref[...] = jnp.zeros_like(server_ref)
        tier_ref[...] = jnp.full_like(tier_ref, depth + 1)

    w = workload_ref[...]                      # (bm,)
    rates = rates_ref[...]                     # (bm, K)
    locs = locals_ref[...]                     # (bt, 3)

    bt = locs.shape[0]
    bm = w.shape[0]
    sid = j * block_m + jax.lax.broadcasted_iota(jnp.int32, (bt, bm), 1)

    local = (sid == locs[:, 0:1]) | (sid == locs[:, 1:2]) | (sid == locs[:, 2:3])
    # remote by default; sharpen tier/rate level by level, deepest first —
    # the depth loop is unrolled at trace time (static shape)
    tier = jnp.full((bt, bm), depth + 1, jnp.int32)
    rate = jnp.broadcast_to(rates[None, :, depth + 1], (bt, bm))
    for lvl in range(depth - 1, -1, -1):
        anc_row = anc_ref[lvl, :]              # (bm,)
        lanc = lanc_ref[...][:, lvl, :]        # (bt, 3)
        rk = jnp.broadcast_to(anc_row[None, :], (bt, bm))
        share = ((rk == lanc[:, 0:1]) | (rk == lanc[:, 1:2])
                 | (rk == lanc[:, 2:3]))
        tier = jnp.where(share, lvl + 1, tier)
        rate = jnp.where(share, rates[None, :, lvl + 1], rate)
    tier = jnp.where(local, 0, tier)
    rate = jnp.where(local, rates[None, :, 0], rate)
    score = jnp.broadcast_to(w[None, :], (bt, bm)) / rate  # (bt, bm)

    blk_min = jnp.min(score, axis=1)                       # (bt,)
    blk_arg = jnp.argmin(score, axis=1).astype(jnp.int32)  # (bt,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)[:, 0]
    blk_tier = tier[rows, blk_arg]

    best = score_ref[...]
    better = blk_min < best                                # strict: keeps lowest index
    score_ref[...] = jnp.where(better, blk_min, best)
    server_ref[...] = jnp.where(better, j * block_m + blk_arg, server_ref[...])
    tier_ref[...] = jnp.where(better, blk_tier, tier_ref[...])


@functools.partial(jax.jit, static_argnames=("block_tasks", "block_servers",
                                             "interpret"))
def wwl_route_pallas(workload: jnp.ndarray, est_rates: jnp.ndarray,
                     server_anc: jnp.ndarray, task_locals: jnp.ndarray,
                     *, block_tasks: int = 128, block_servers: int = 512,
                     interpret: bool = False):
    """Padded, tiled argmin routing.  See ref.wwl_route for semantics.

    server_anc is the (depth, M) ancestor table; est_rates (M, depth + 2).
    Caller guarantees M % block_servers == 0 and B % block_tasks == 0
    (ops.wwl_route pads; padding servers carry +inf workload so they never
    win, padding tasks are sliced off).
    """
    b = task_locals.shape[0]
    m = workload.shape[0]
    depth = server_anc.shape[0]
    grid = (b // block_tasks, m // block_servers)
    # (B, D, 3) ancestor groups of each task's locals: gathered outside the
    # kernel (one gather per level, B*D*3 ints — tiny next to (B, M))
    task_lanc = jnp.swapaxes(server_anc[:, task_locals], 0, 1)

    kernel = functools.partial(_route_kernel, block_m=block_servers,
                               depth=depth)
    score, server, tier = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_servers,), lambda i, j: (j,)),
            pl.BlockSpec((block_servers, depth + 2), lambda i, j: (j, 0)),
            pl.BlockSpec((depth, block_servers), lambda i, j: (0, j)),
            pl.BlockSpec((block_tasks, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_tasks, depth, 3), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
            pl.BlockSpec((block_tasks,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(workload.astype(jnp.float32), est_rates.astype(jnp.float32),
      server_anc.astype(jnp.int32), task_locals.astype(jnp.int32),
      task_lanc.astype(jnp.int32))
    return server, tier, score
