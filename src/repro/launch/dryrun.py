import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), prove the
sharding config is coherent, and extract the roofline terms.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
          --mesh both --out experiments/dryrun

The XLA_FLAGS line above MUST execute before any jax import (device count
locks at first init); do not move it.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import registry, runtime  # noqa: E402
from repro.configs.shapes import SHAPES, applicable  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.models.config import active_param_count, param_count  # noqa: E402
from repro.utils import hlo as hlo_lib  # noqa: E402
from repro.utils import roofline as rl  # noqa: E402


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (lowered, meta) for one cell."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    plan = runtime.plan_for(cfg, shape_name, shape.kind,
                            dp_axes=mesh_lib.dp_axes(mesh))
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind}
    with mesh:
        if shape.kind == "train":
            fn, astate, abatch, _ = steps_lib.build_train_step(
                cfg, mesh, plan, shape.global_batch, shape.seq_len)
            lowered = fn.lower(astate, abatch)
        elif shape.kind == "prefill":
            fn, (ap, ac, ab), _ = steps_lib.build_prefill_step(
                cfg, mesh, plan, shape.global_batch, shape.seq_len)
            lowered = fn.lower(ap, ac, ab)
        else:
            fn, (ap, ac, ab), _ = steps_lib.build_serve_step(
                cfg, mesh, plan, shape.global_batch, shape.seq_len)
            lowered = fn.lower(ap, ac, ab)
    return lowered, mesh, cfg, shape, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    t0 = time.time()
    try:
        lowered, mesh, cfg, shape, meta = lower_cell(arch, shape_name,
                                                     multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in the system
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}

    n_dev = mesh.devices.size
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    pod_boundary = 256 if multi_pod else None
    rep = hlo_lib.analyze(text, pod_boundary=pod_boundary)

    # ---- useful model FLOPs ----
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        attn = rl.attention_flops("train", cfg, shape.seq_len,
                                  shape.global_batch)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        attn = rl.attention_flops("serve", cfg, shape.seq_len,
                                  shape.global_batch)
    else:
        tokens = shape.global_batch  # one token per sequence
        attn = rl.attention_flops("serve", cfg, shape.seq_len,
                                  shape.global_batch, decode=True)
    mflops = rl.model_flops(
        "train" if shape.kind == "train" else "serve", n_active, tokens, attn)

    # Analytic minimum HBM traffic (global): params once (x3 for train:
    # fwd read, bwd read, grad+opt update), caches once, activation stream.
    p_bytes = 2.0 * param_count(cfg)
    d_model = cfg.d_model
    act_stream = 2.0 * tokens * d_model * max(cfg.num_layers, 1) * 2
    if shape.kind == "train":
        mbytes = 3.0 * p_bytes + 2.0 * act_stream
    elif shape.kind == "prefill":
        mbytes = p_bytes + act_stream
    else:
        cache_bytes = _tree_bytes_for(arch, shape, multi_pod)
        mbytes = p_bytes + cache_bytes + 2.0 * tokens * d_model * 2

    roof = rl.Roofline(
        flops_per_device=rep.flops,
        hbm_bytes_per_device=rep.bytes,
        ici_bytes_per_device=rep.collective_bytes - rep.dcn_bytes,
        dcn_bytes_per_device=rep.dcn_bytes,
        model_flops_per_device=mflops / n_dev,
        model_bytes_per_device=mbytes / n_dev,
    )

    arg_b = ma.argument_size_in_bytes
    out_b = ma.output_size_in_bytes
    tmp_b = ma.temp_size_in_bytes
    alias_b = ma.alias_size_in_bytes
    peak = arg_b + out_b + tmp_b - alias_b
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "status": "ok",
        "devices": n_dev,
        "params_total": param_count(cfg),
        "params_active": active_param_count(cfg),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": arg_b, "output_bytes": out_b,
            "temp_bytes": tmp_b, "alias_bytes": alias_b,
            "peak_bytes_per_device": peak,
            "fits_16gb": bool(peak <= rl.HBM_PER_CHIP),
        },
        "cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")
                          if k in ca},
        "hlo": {
            "flops_per_device": rep.flops,
            "hbm_bytes_per_device": rep.bytes,
            "collective_bytes_per_device": rep.collective_bytes,
            "dcn_bytes_per_device": rep.dcn_bytes,
            "collective_counts": rep.coll_counts,
            "collective_bytes_by_kind": rep.coll_bytes,
        },
        "roofline": roof.as_dict(),
    }
    if keep_hlo:
        result["hlo_text_bytes"] = len(text)
    return result


def _tree_bytes_for(arch: str, shape, multi_pod: bool) -> float:
    """Global cache bytes (k+v+state read once per decode step)."""
    import numpy as np
    from repro.models import transformer as T
    cfg = registry.get_config(arch)
    ac = T.abstract_caches(cfg, shape.global_batch, shape.seq_len,
                           enc_len=cfg.num_audio_frames)
    return float(sum(np.prod(x.shape) * x.dtype.itemsize
                     for x in jax.tree.leaves(ac)))


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:8s} SKIP "
                f"({r['reason'][:60]}...)")
    if r["status"] == "FAILED":
        return (f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:8s} FAIL "
                f"{r['error'][:80]}")
    ro = r["roofline"]
    return (f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:8s} "
            f"mem={r['memory']['peak_bytes_per_device'] / 1e9:6.2f}GB"
            f"{'✓' if r['memory']['fits_16gb'] else '✗'} "
            f"C={ro['compute_s'] * 1e3:9.3f}ms "
            f"M={ro['memory_s'] * 1e3:9.3f}ms "
            f"X={ro['collective_s'] * 1e3:9.3f}ms "
            f"dom={ro['dominant'][:4]} "
            f"useful={ro['useful_flops_fraction']:5.1%} "
            f"roof={ro['roofline_fraction']:5.1%} "
            f"[{r['compile_s']:.0f}s compile]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                r = run_cell(arch, shape, multi)
                n_fail += r["status"] == "FAILED"
                print(fmt_row(r), flush=True)
                name = f"{arch}__{shape}__{r['mesh'].replace('x', '_')}.json"
                (outdir / name).write_text(json.dumps(r, indent=1))
    print(f"\ndone; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
