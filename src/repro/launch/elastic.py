"""Fault tolerance + elastic scaling: heartbeat failure detection, legal-mesh
replanning, and a restart supervisor.

At 1000+ nodes, node loss is routine; the contract here is:
  1. HeartbeatMonitor flags hosts silent past the timeout;
  2. plan_elastic_mesh() picks the largest legal (dp, model) grid on the
     surviving chips — the model axis is preserved (TP degree is a property
     of the checkpointed layout); the data axis shrinks, the global batch is
     kept by raising per-device batch or microbatch count;
  3. the supervisor restores the latest atomic checkpoint with the NEW
     shardings (Checkpointer.restore(shardings=...)) and resumes.

Straggler mitigation (distinct from failure): per-step host timings feed the
same EWMA estimator the data pipeline and serving router use — a slow host's
estimated rate decays, Balanced-PANDAS sheds load to its rack before the
host ever trips the failure timeout.  That graceful degradation under
mis-estimated rates is precisely the paper's robustness result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks worker liveness from heartbeat timestamps."""

    num_workers: int
    timeout_s: float = 60.0

    def __post_init__(self):
        now = time.monotonic()
        self._last: Dict[int, float] = {w: now for w in
                                        range(self.num_workers)}

    def beat(self, worker: int, t: Optional[float] = None) -> None:
        self._last[worker] = time.monotonic() if t is None else t

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self._last.items()
                if now - t > self.timeout_s]

    def alive(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed(now))
        return [w for w in range(self.num_workers) if w not in bad]


def plan_elastic_mesh(available_chips: int, model_axis: int,
                      chips_per_host: int = 4,
                      pod_size: int = 256) -> Tuple[Tuple[int, ...],
                                                    Tuple[str, ...]]:
    """Largest legal mesh on the surviving fleet.

    Keeps the model (TP) axis intact — checkpointed parameter shards are laid
    out per model-rank — and shrinks the data axis to the largest multiple
    that fits.  Returns (shape, axis_names); raises if not even one model
    group survives.
    """
    if available_chips < model_axis:
        raise RuntimeError(
            f"only {available_chips} chips left; cannot form one "
            f"model-parallel group of {model_axis}")
    data = available_chips // model_axis
    if available_chips >= 2 * pod_size and data % 2 == 0:
        pods = min(available_chips // pod_size, 2)
        return (pods, data // pods, model_axis), ("pod", "data", "model")
    return (data, model_axis), ("data", "model")


def rebalance_batch(global_batch: int, old_dp: int, new_dp: int,
                    microbatches: int) -> Tuple[int, int]:
    """Keep the global batch across a shrink: raise microbatch count so the
    per-device-per-microbatch batch stays >= 1 and divisibility holds."""
    n_mb = microbatches
    while global_batch % n_mb or (global_batch // n_mb) % new_dp:
        n_mb += 1
        if n_mb > global_batch:
            raise RuntimeError(
                f"cannot split batch {global_batch} over dp={new_dp}")
    return global_batch, n_mb


@dataclasses.dataclass
class ElasticSupervisor:
    """Drives fail -> replan -> restore -> resume for a training run.

    `build` is a factory: build(mesh_shape, axis_names, n_mb) ->
    (step_fn, state_template, shardings); `restore` loads the checkpoint
    into the new shardings.  The supervisor is exercised end-to-end (with
    simulated failures) in tests/test_fault_tolerance.py and
    examples/elastic_restart.py.
    """

    build: Callable
    checkpointer: "object"
    model_axis: int
    global_batch: int
    microbatches: int

    def replan(self, available_chips: int):
        shape, names = plan_elastic_mesh(available_chips, self.model_axis)
        dp = 1
        for s, n in zip(shape, names):
            if n in ("pod", "data"):
                dp *= s
        _, n_mb = rebalance_batch(self.global_batch, None, dp,
                                  self.microbatches)
        return shape, names, n_mb
