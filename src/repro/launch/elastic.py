"""Fault tolerance + elastic scaling: heartbeat failure detection, legal-mesh
replanning, and a restart supervisor.

At 1000+ nodes, node loss is routine; the contract here is:
  1. HeartbeatMonitor flags hosts silent past the timeout;
  2. plan_elastic_mesh() picks the largest legal (dp, model) grid on the
     surviving chips — the model axis is preserved (TP degree is a property
     of the checkpointed layout); the data axis shrinks, the global batch is
     kept by raising per-device batch or microbatch count;
  3. the supervisor restores the latest atomic checkpoint with the NEW
     shardings (Checkpointer.restore(shardings=...)) and resumes.

Straggler mitigation (distinct from failure): per-step host timings feed the
same EWMA estimator the data pipeline and serving router use — a slow host's
estimated rate decays, Balanced-PANDAS sheds load to its rack before the
host ever trips the failure timeout.  That graceful degradation under
mis-estimated rates is precisely the paper's robustness result.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks worker liveness from heartbeat timestamps."""

    num_workers: int
    timeout_s: float = 60.0

    def __post_init__(self):
        now = time.monotonic()
        self._last: Dict[int, float] = {w: now for w in
                                        range(self.num_workers)}

    def beat(self, worker: int, t: Optional[float] = None) -> None:
        self._last[worker] = time.monotonic() if t is None else t

    def failed(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self._last.items()
                if now - t > self.timeout_s]

    def alive(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed(now))
        return [w for w in range(self.num_workers) if w not in bad]


def plan_elastic_mesh(available_chips: int, model_axis: int,
                      chips_per_host: int = 4,
                      pod_size: int = 256) -> Tuple[Tuple[int, ...],
                                                    Tuple[str, ...]]:
    """Largest legal mesh on the surviving fleet.

    Keeps the model (TP) axis intact — checkpointed parameter shards are laid
    out per model-rank — and shrinks the data axis to the largest multiple
    that fits.  Returns (shape, axis_names); raises if not even one model
    group survives.
    """
    if available_chips < model_axis:
        raise RuntimeError(
            f"only {available_chips} chips left; cannot form one "
            f"model-parallel group of {model_axis}")
    data = available_chips // model_axis
    if available_chips >= 2 * pod_size and data % 2 == 0:
        pods = min(available_chips // pod_size, 2)
        return (pods, data // pods, model_axis), ("pod", "data", "model")
    return (data, model_axis), ("data", "model")


def rebalance_batch(global_batch: int, old_dp: int, new_dp: int,
                    microbatches: int) -> Tuple[int, int]:
    """Keep the global batch across a shrink: raise microbatch count so the
    per-device-per-microbatch batch stays >= 1 and divisibility holds."""
    n_mb = microbatches
    while global_batch % n_mb or (global_batch // n_mb) % new_dp:
        n_mb += 1
        if n_mb > global_batch:
            raise RuntimeError(
                f"cannot split batch {global_batch} over dp={new_dp}")
    return global_batch, n_mb


@dataclasses.dataclass
class Autoscaler:
    """Reactive fleet autoscaler: p95-threshold hysteresis + cooldown.

    The host projection of `repro.control`'s ``autoscale`` controller
    (the in-scan projection plans from the known rate track; this one
    reacts to the measured sojourn p95 the serving engine feeds it).

    Hysteresis: ``up_after`` CONSECUTIVE readings above ``p95_high``
    grow the active-server target by ``ceil(step_frac * current)``;
    ``down_after`` consecutive readings below ``p95_low`` shrink it by
    the same step.  Readings between the thresholds (or NaN — no data
    yet) reset both streaks, and after any action the ``cooldown``
    window ignores readings entirely, so a scale-up must prove itself
    before the next move.  The asymmetry (``down_after`` >
    ``up_after``) is deliberate: scaling up is cheap and urgent,
    scaling down risks re-breaching — the standard conservative-down
    rule.  Targets clamp to [min_servers, max_servers].

    `observe(step, p95)` returns the new target when it changes, else
    None; `current` always holds the live target.
    """

    min_servers: int
    max_servers: int
    p95_high: float = 64.0
    p95_low: float = 16.0
    up_after: int = 2
    down_after: int = 8
    cooldown: int = 16
    step_frac: float = 0.25

    def __post_init__(self):
        if not 1 <= self.min_servers <= self.max_servers:
            raise ValueError(
                f"need 1 <= min_servers <= max_servers, got "
                f"[{self.min_servers}, {self.max_servers}]")
        if self.p95_low > self.p95_high:
            raise ValueError(f"need p95_low <= p95_high, got "
                             f"{self.p95_low} > {self.p95_high}")
        if self.up_after < 1 or self.down_after < 1:
            raise ValueError("up_after/down_after must be >= 1")
        if not 0.0 < self.step_frac <= 1.0:
            raise ValueError(f"step_frac must be in (0, 1], got "
                             f"{self.step_frac}")
        self.current = self.max_servers
        self._hi_streak = 0
        self._lo_streak = 0
        self._cooldown_until = 0

    def _step(self) -> int:
        return max(1, int(-(-self.current * self.step_frac // 1)))

    def observe(self, step: int, p95: float) -> Optional[int]:
        """One p95 reading at engine step ``step``; returns the new
        target iff it changed."""
        if step < self._cooldown_until:
            return None
        if not (p95 == p95):  # NaN: no sojourn data yet
            self._hi_streak = self._lo_streak = 0
            return None
        if p95 > self.p95_high:
            self._hi_streak += 1
            self._lo_streak = 0
        elif p95 < self.p95_low:
            self._lo_streak += 1
            self._hi_streak = 0
        else:
            self._hi_streak = self._lo_streak = 0
            return None
        target = self.current
        if self._hi_streak >= self.up_after:
            target = min(self.current + self._step(), self.max_servers)
        elif self._lo_streak >= self.down_after:
            target = max(self.current - self._step(), self.min_servers)
        if target == self.current:
            return None
        self.current = target
        self._hi_streak = self._lo_streak = 0
        self._cooldown_until = step + self.cooldown
        return target


@dataclasses.dataclass
class ElasticSupervisor:
    """Drives fail -> replan -> restore -> resume for a training run.

    `build` is a factory: build(mesh_shape, axis_names, n_mb) ->
    (step_fn, state_template, shardings); `restore` loads the checkpoint
    into the new shardings.  The supervisor is exercised end-to-end (with
    simulated failures) in tests/test_fault_tolerance.py and
    examples/elastic_restart.py.
    """

    build: Callable
    checkpointer: "object"
    model_axis: int
    global_batch: int
    microbatches: int

    def replan(self, available_chips: int):
        shape, names = plan_elastic_mesh(available_chips, self.model_axis)
        dp = 1
        for s, n in zip(shape, names):
            if n in ("pod", "data"):
                dp *= s
        _, n_mb = rebalance_batch(self.global_batch, None, dp,
                                  self.microbatches)
        return shape, names, n_mb
