"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses DCN; "data"/"model" stay inside the ICI domain.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    dev = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests use subprocesses with
    --xla_force_host_platform_device_count=8)."""
    need = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:need]).reshape(shape)
    return Mesh(dev, axes)


def dp_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def model_axis_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))
