"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching engine with the Balanced-PANDAS request router
over N replica groups (smoke config on CPU; production mesh on a fleet).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3_6b")
    ap.add_argument("--requests", type=int, default=16)
    from repro.core.policy import available_routers
    ap.add_argument("--scheduler", default="balanced_pandas",
                    choices=list(available_routers()))
    ap.add_argument("--replicas", type=int, default=4)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config(args.arch)
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_replicas=args.replicas,
                        replicas_per_pod=max(args.replicas // 2, 1),
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,), scheduler=args.scheduler)
    eng = ServingEngine(cfg, prm, ecfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=6, prefix_id=i % 5)
            for i in range(args.requests)]
    out = eng.run_until_drained(reqs)
    lat = [r.finish_time - r.arrival for r in out]
    print(f"scheduler={args.scheduler} drained {len(out)} requests in "
          f"{eng.steps} engine steps; mean latency {np.mean(lat) * 1e3:.0f}ms; "
          f"tier mix {eng.assign_tiers}")


if __name__ == "__main__":
    main()
