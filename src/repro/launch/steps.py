"""Sharded step builders: train_step / prefill_step / serve_step.

Each builder closes over (cfg, mesh, policy) and returns
(jitted_fn, abstract_inputs, shardings) so the same code path serves real
execution (small models on the test mesh) and the multi-pod dry-run
(ShapeDtypeStructs on the 512-chip mesh).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.models import params as params_lib, transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding.rules import (ShardCtx, ShardingPolicy, make_rules,
                                  tree_axes_to_shardings)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RuntimePlan:
    """Per-(arch, shape) runtime knobs — see configs/runtime.py."""

    policy: ShardingPolicy
    microbatches: int = 1
    accum_dtype: str = "float32"
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    remat: bool = True
    max_len: int = 0  # decode cache length (shape.seq_len)
    pin_gathers: bool = False  # keep FSDP gathers inside the layer scan


def make_ctx(cfg: ModelConfig, mesh, policy: ShardingPolicy) -> ShardCtx:
    rules = make_rules(
        policy,
        num_experts=cfg.moe.num_experts if cfg.moe else 0,
        model_axis_size=mesh_lib.model_axis_size(mesh))
    ctx = ShardCtx(mesh, rules)
    ctx.dp_size = mesh_lib.dp_size(mesh)  # MoE shard-local dispatch chunks
    # GQA-expanded KV caches: when Hkv doesn't divide the TP axis but Hq
    # does, store/compute K/V at Hq heads so attention shards (layers.py).
    tp = mesh_lib.model_axis_size(mesh)
    ctx.kv_expand = bool(
        cfg.num_heads and cfg.num_kv_heads
        and cfg.num_kv_heads % tp != 0 and cfg.num_heads % tp == 0)
    # Sequence-parallel KV cache: when no head axis divides the model axis
    # (gemma2: 8q/4kv on tp=16), decode attention parallelizes over the
    # cache SEQ dim instead — logits stay local, only the tiny softmax
    # stats cross the model axis (flash-decode style).  long_500k
    # additionally spreads the cache over the (idle, batch=1) DP axes.
    heads_shardable = bool(cfg.num_heads) and (
        cfg.num_heads % tp == 0 or cfg.num_kv_heads % tp == 0)
    if cfg.num_heads and not heads_shardable:
        base = tuple(policy.dp_axes) if policy.seq_shard_cache else ()
        rules["act_cache"] = base + ("model",)
    return ctx


def effective_kv_heads(cfg: ModelConfig, ctx: ShardCtx) -> Optional[int]:
    return cfg.num_heads if getattr(ctx, "kv_expand", False) else None


def param_shardings(cfg: ModelConfig, ctx: ShardCtx):
    return tree_axes_to_shardings(
        ctx, params_lib.abstract_params(cfg), params_lib.logical_axes(cfg))


def _batch_axes(cfg: ModelConfig, kind: str) -> Dict[str, Tuple]:
    axes: Dict[str, Tuple] = {"tokens": ("act_batch", None)}
    if kind == "train":
        axes["labels"] = ("act_batch", None)
    if kind == "decode":
        axes = {"tokens": ("act_batch", None), "lengths": ("act_batch",)}
        return axes
    if cfg.frontend == "vision":
        axes["frontend"] = ("act_batch", None, None)
    if cfg.is_encdec:
        axes["frames"] = ("act_batch", None, None)
    return axes


def _shard_batch(ctx: ShardCtx, cfg: ModelConfig, kind: str, batch_specs):
    axes = _batch_axes(cfg, kind)
    return {k: ctx.sharding(axes[k], v.shape) for k, v in batch_specs.items()}


# ------------------------------------------------------------- train step --

def build_train_step(cfg: ModelConfig, mesh, plan: RuntimePlan,
                     global_batch: int, seq_len: int):
    """Returns (step_fn, abstract_state, abstract_batch, shardings).

    step_fn(state, batch) -> (state, metrics); microbatched gradient
    accumulation via lax.scan; remat inside the model's layer scans.
    """
    ctx = make_ctx(cfg, mesh, plan.policy)
    ctx.pin_gathers = plan.pin_gathers
    dp = mesh_lib.dp_size(mesh)
    n_mb = max(1, min(plan.microbatches, global_batch // dp))
    while global_batch % n_mb or (global_batch // n_mb) % dp:
        n_mb -= 1
    mb = global_batch // n_mb

    aparams = params_lib.abstract_params(cfg)
    p_sh = param_shardings(cfg, ctx)
    opt_sh = adamw.AdamWState(
        count=NamedSharding(mesh, P()),
        mu=p_sh, nu=p_sh)
    state_sh = TrainState(params=p_sh, opt=opt_sh,
                          step=NamedSharding(mesh, P()))
    abstract_batch = _train_batch_specs(cfg, global_batch, seq_len)
    b_sh = _shard_batch(ctx, cfg, "train", abstract_batch)
    abstract_state = TrainState(
        params=aparams, opt=adamw.abstract_state(plan.opt, aparams),
        step=jax.ShapeDtypeStruct((), jnp.int32))

    adt = jnp.dtype(plan.accum_dtype)

    def loss_fn(params, mb_batch):
        return T.lm_loss(params, cfg, mb_batch, ctx=ctx, remat=plan.remat)

    def to_microbatches(x):
        # (B, ...) -> (n_mb, B/n_mb, ...) keeping the device-sharded dim
        # inside each microbatch (see DESIGN.md §6).
        bshape = x.shape
        x = x.reshape((mb, n_mb) + bshape[1:]).swapaxes(0, 1)
        return x

    def train_step(state, batch):
        params = state.params

        def one_grad(mb_batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb_batch)
            return grads, loss, metrics

        if n_mb == 1:
            grads, loss, metrics = one_grad(batch)
        else:
            mbs = jax.tree.map(to_microbatches, batch)

            def accum(carry, mb_batch):
                g_acc, l_acc = carry
                g, l, _ = one_grad(mb_batch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), g_acc, g)
                return (g_acc, l_acc + l), ()

            # Accumulator pinned to the param shardings: without the
            # constraint XLA may keep per-microbatch grads in a layout that
            # forces all-reduce instead of reduce-scatter (2x traffic).
            g0 = jax.tree.map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, adt), sh), params, p_sh)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: (g / n_mb).astype(adt), grads)
            loss = loss_sum / n_mb
            metrics = {}

        new_params, new_opt, opt_metrics = adamw.update(
            plan.opt, grads, state.opt, params)
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    fn = jax.jit(train_step,
                 in_shardings=(state_sh, b_sh),
                 out_shardings=(state_sh, None),
                 donate_argnums=(0,))
    return fn, abstract_state, abstract_batch, (state_sh, b_sh)


def _train_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int):
    from repro.configs.shapes import RunShape, input_specs
    return input_specs(cfg, RunShape("train", "train", seq_len, global_batch))


# ----------------------------------------------------------- serve steps ---

def build_prefill_step(cfg: ModelConfig, mesh, plan: RuntimePlan,
                       batch: int, seq_len: int):
    """prefill(params, caches, batch) -> (logits_last, caches)."""
    ctx = make_ctx(cfg, mesh, plan.policy)
    max_len = plan.max_len or seq_len
    p_sh = param_shardings(cfg, ctx)
    acaches = T.abstract_caches(cfg, batch, max_len,
                                enc_len=cfg.num_audio_frames,
                                kv_heads=effective_kv_heads(cfg, ctx))
    c_sh = tree_axes_to_shardings(ctx, acaches, T.cache_axes(cfg))
    from repro.configs.shapes import RunShape, input_specs
    abstract_batch = input_specs(
        cfg, RunShape("prefill", "prefill", seq_len, batch))
    b_sh = _shard_batch(ctx, cfg, "prefill", abstract_batch)

    ctx.aligned_decode = True  # fresh prefill: slots start at 0

    def prefill(params, caches, batch_in):
        tokens = batch_in["tokens"]
        enc_out = None
        if cfg.is_encdec:
            enc_out = T.encode(params, cfg, batch_in["frames"], ctx=ctx)
        # positions default to arange over the FULL stream (frontend tokens
        # included for VLMs) inside forward().
        logits, caches, _ = T.forward(
            params, cfg, tokens, frontend=batch_in.get("frontend"),
            enc_out=enc_out, caches=caches, ctx=ctx,
            remat=plan.remat)
        return logits[:, -1], caches

    fn = jax.jit(prefill,
                 in_shardings=(p_sh, c_sh, b_sh),
                 out_shardings=(None, c_sh),
                 donate_argnums=(1,))
    aparams = params_lib.abstract_params(cfg)
    return fn, (aparams, acaches, abstract_batch), (p_sh, c_sh, b_sh)


def build_serve_step(cfg: ModelConfig, mesh, plan: RuntimePlan, batch: int,
                     max_len: int):
    """serve(params, caches, batch{tokens,lengths}) ->
    (next_token, logits, caches) — one decode step.

    aligned_decode: the engine aligns decode batches to a shared ring slot
    (per-row positions still differ; validity comes from the stored pos
    values), so the deferred cache commit is a single in-place
    dynamic-update-slice per stage instead of a batched scatter."""
    ctx = make_ctx(cfg, mesh, plan.policy)
    ctx.aligned_decode = True
    p_sh = param_shardings(cfg, ctx)
    acaches = T.abstract_caches(cfg, batch, max_len,
                                enc_len=cfg.num_audio_frames,
                                kv_heads=effective_kv_heads(cfg, ctx))
    c_sh = tree_axes_to_shardings(ctx, acaches, T.cache_axes(cfg))
    from repro.configs.shapes import RunShape, input_specs
    abstract_batch = input_specs(
        cfg, RunShape("decode", "decode", max_len, batch))
    b_sh = _shard_batch(ctx, cfg, "decode", abstract_batch)

    def serve(params, caches, batch_in):
        logits, caches = T.decode_step(params, cfg, batch_in["tokens"],
                                       batch_in["lengths"], caches, ctx=ctx)
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return next_tok, logits[:, 0], caches

    fn = jax.jit(serve,
                 in_shardings=(p_sh, c_sh, b_sh),
                 out_shardings=(None, None, c_sh),
                 donate_argnums=(1,))
    aparams = params_lib.abstract_params(cfg)
    return fn, (aparams, acaches, abstract_batch), (p_sh, c_sh, b_sh)
