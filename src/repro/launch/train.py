"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced (smoke) configs end-to-end with the
full substrate stack (pipeline -> sharded step -> checkpoints).  On a real
fleet the same entry point runs the full config on the production mesh
(--full --multi-pod).
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3_6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full (production) config, not the smoke one")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 for a local test mesh (default: 1x1)")
    args = ap.parse_args()

    import jax
    from repro.configs import registry, runtime
    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import RuntimePlan
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = (registry.get_config(args.arch) if args.full
           else registry.get_smoke_config(args.arch))
    if args.multi_pod or (args.full and args.mesh is None):
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    else:
        shape = tuple(int(x) for x in (args.mesh or "1x1").split("x"))
        mesh = mesh_lib.make_test_mesh(shape, ("data", "model"))
    plan = runtime.plan_for(cfg, "train_4k", "train",
                            dp_axes=mesh_lib.dp_axes(mesh))
    trainer = Trainer(cfg, TrainerConfig(
        seq_len=args.seq_len, global_batch=args.global_batch,
        steps=args.steps, ckpt_dir=args.ckpt_dir), mesh, plan)
    hist = trainer.run()
    for rec in hist:
        print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
              f"gnorm {rec['grad_norm']:.3f} {rec['wall_s'] * 1e3:.0f}ms "
              f"locality {tuple(round(x, 2) for x in rec['data_locality'])}")


if __name__ == "__main__":
    main()
