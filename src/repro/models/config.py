"""Model configuration system.

A model is a stack of *stages*; each stage scans a repeated *block* of
sub-layers (`LayerSpec`s).  This is what lets ten heterogeneous architectures
(uniform decoders, alternating local/global attention, 1:7 Mamba:attention
hybrids with interleaved MoE, encoder-decoder) share one scanned-layer
implementation with exact parameter counts — the block is unrolled once in
the HLO and scanned `repeats` times with stacked parameters (MaxText-style),
keeping compile time and HLO size flat in depth.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One sub-layer of a block."""

    kind: str = "attn"          # "attn" | "mamba"
    window: int = 0             # sliding-window size; 0 = full attention
    moe: bool = False           # MoE MLP instead of dense
    cross: bool = False         # adds cross-attention (decoder of enc-dec)
    causal: bool = True         # False for encoder self-attention
    rope_theta: float = 0.0     # 0 -> use model default (gemma3 local layers
                                # override with a shorter theta)


@dataclasses.dataclass(frozen=True)
class Stage:
    """`repeats` copies of `block`, executed as one scan with stacked params."""

    block: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.block) * self.repeats


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.5
    # "ep" shards the expert axis over the model mesh axis; "tp" shards the
    # per-expert ffn dim.  "auto" picks ep iff num_experts % model_axis == 0.
    sharding: str = "auto"
    # Below this many tokens, capacity = N (no drops): decode and small-batch
    # prefill stay exact; large training batches use capacity semantics.
    no_drop_threshold: int = 4096


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 128  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]                 # decoder / main stack
    enc_stages: Tuple[Stage, ...] = ()        # encoder stack (enc-dec only)

    # attention options
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0    # chatglm applies rotary to half the dims
    qk_norm: bool = False         # gemma3
    attn_softcap: float = 0.0     # gemma2
    attn_bias: bool = False       # qwen-family qkv bias
    attn_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    learned_pos: int = 0          # >0: learned positions (whisper), table size

    # output head
    final_softcap: float = 0.0    # gemma2 logit softcap
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: embeddings * sqrt(d_model)

    # substructure
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    post_norm: bool = False       # gemma2/3 post-block norms
    act: str = "swiglu"           # swiglu | geglu | gelu

    # modality frontend (stub: inputs arrive as precomputed embeddings)
    frontend: str = "none"        # none | vision | audio
    num_frontend_tokens: int = 0  # vision: patch tokens prepended
    num_audio_frames: int = 0     # audio: encoder frames (whisper: 1500)

    dtype: str = "bfloat16"

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    @property
    def num_enc_layers(self) -> int:
        return sum(s.num_layers for s in self.enc_stages)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so logits/embedding shard over TP: odd
        vocabs (internvl2 92553, granite 49155, whisper 51865, mamba2 50280)
        otherwise replicate the CE one-hot across the model axis — measured
        +11 GB/device on internvl2 train_4k.  Rows >= vocab_size are masked
        to -inf in the head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return bool(self.enc_stages)

    @property
    def attention_free(self) -> bool:
        return all(sl.kind != "attn" for st in self.stages for sl in st.block)

    @property
    def max_attention_window(self) -> int:
        """0 if any attention layer is full/global (unbounded cache)."""
        windows = [sl.window for st in self.stages for sl in st.block
                   if sl.kind == "attn"]
        if not windows:
            return -1  # attention-free
        return 0 if any(w == 0 for w in windows) else max(windows)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid or all-windowed attention."""
        return self.attention_free or self.max_attention_window > 0 or \
            self.family in ("ssm", "hybrid")

    def scaled(self, width: float = 1.0, layers: float = 1.0,
               vocab: int = 0) -> "ModelConfig":
        """Reduced copy for smoke tests: shrink width/depth/vocab but keep the
        structural pattern (block composition, MoE/SSM settings) intact."""
        def shrink_stage(s: Stage) -> Stage:
            return Stage(s.block, max(1, int(round(s.repeats * layers))))

        d = _round8(int(self.d_model * width))
        heads = max(1, int(self.num_heads * width))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        moe = self.moe
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(
                ssm, d_state=max(8, _round8(int(ssm.d_state * width))),
                head_dim=max(8, _round8(int(ssm.head_dim * width))), chunk=32)
        return dataclasses.replace(
            self,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=max(8, _round8(int(self.head_dim * width))),
            d_ff=_round8(max(16, int(self.d_ff * width))) if self.d_ff else 0,
            vocab_size=vocab or self.vocab_size,
            stages=tuple(shrink_stage(s) for s in self.stages),
            enc_stages=tuple(shrink_stage(s) for s in self.enc_stages),
            num_frontend_tokens=min(self.num_frontend_tokens, 8),
            num_audio_frames=min(self.num_audio_frames, 16),
            learned_pos=min(self.learned_pos, 4096) if self.learned_pos else 0,
            moe=moe,
            ssm=ssm,
            dtype="float32",
        )


def _round8(x: int) -> int:
    return max(8, (x // 8) * 8)


def uniform_stages(num_layers: int, spec: LayerSpec) -> Tuple[Stage, ...]:
    return (Stage((spec,), num_layers),)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embeddings + all stages + head)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += d * v
    total += d  # final norm
    if cfg.learned_pos:
        total += cfg.learned_pos * d

    def layer_params(sl: LayerSpec) -> int:
        n = 0
        if sl.kind == "attn":
            n += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
            if cfg.attn_bias:
                n += cfg.q_dim + 2 * cfg.kv_dim
            if cfg.qk_norm:
                n += 2 * cfg.head_dim
            if sl.cross:
                n += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
                n += d  # cross-attn norm
        else:
            ssm = cfg.ssm
            din = ssm.d_inner(d)
            gn = ssm.n_groups * ssm.d_state
            h = ssm.num_heads(d)
            proj_out = 2 * din + 2 * gn + h
            n += d * proj_out                     # in_proj
            n += (din + 2 * gn) * ssm.conv_kernel  # conv
            n += 3 * h                            # A_log, D, dt_bias
            n += din                              # gated norm
            n += din * d                          # out_proj
        # mlp
        has_mlp = sl.moe or ff > 0
        if sl.moe:
            e = cfg.moe.num_experts
            n += d * e  # router
            n += e * (2 * d * ff + ff * d) if cfg.act in ("swiglu", "geglu") \
                else e * 2 * d * ff
        elif has_mlp:
            n += (2 * d * ff + ff * d) if cfg.act in ("swiglu", "geglu") \
                else 2 * d * ff
        # norms (pre attn/mlp [+post])
        n_norms = (2 if has_mlp else 1) * (2 if cfg.post_norm else 1)
        n += n_norms * d
        if cfg.norm == "layernorm":
            n += n_norms * d  # biases
        return n

    for st in cfg.stages + cfg.enc_stages:
        total += st.repeats * sum(layer_params(sl) for sl in st.block)
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of num_experts)."""
    if cfg.moe is None:
        return param_count(cfg)
    full = param_count(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    per_expert = 3 * d * ff if cfg.act in ("swiglu", "geglu") else 2 * d * ff
    n_moe_layers = sum(st.repeats * sum(1 for sl in st.block if sl.moe)
                       for st in cfg.stages + cfg.enc_stages)
    inactive = n_moe_layers * (cfg.moe.num_experts - cfg.moe.top_k) * per_expert
    return full - inactive
