"""Model layers: norms, RoPE, attention (XLA and Pallas paths), MLP, MoE.

Everything is pure-functional: `fn(params_subtree, cfg, x, ...) -> y`.
Compute is f32 internally, activations flow in cfg.dtype.

The optional `ctx` argument is a sharding context (sharding/rules.ShardCtx)
whose `constrain(x, logical_axes)` inserts with_sharding_constraint under a
mesh and is a no-op otherwise — layers stay mesh-agnostic.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig


def _constrain(ctx, x, axes):
    return ctx.constrain(x, axes) if ctx is not None else x


# ------------------------------------------------------------------ norms --

def norm(p: Dict[str, Any], cfg: ModelConfig, x: jnp.ndarray,
         prefix: str) -> jnp.ndarray:
    scale = p[f"{prefix}_scale"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * scale \
            + p[f"{prefix}_bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * scale
    return out.astype(x.dtype)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm over the head_dim axis (gemma3 qk-norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + 1e-6)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- rope --

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         fraction: float = 1.0) -> jnp.ndarray:
    """Rotary embedding on the leading `fraction` of head dims.

    x: (B, H, T, D); positions: (B, T).
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freq  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


# -------------------------------------------------------------- attention --

# Above this many query positions, full-sequence attention switches to the
# query-chunked formulation (memory O(bq*T), window-limited K/V slices).
CHUNKED_ATTN_THRESHOLD = 8192
CHUNK_Q = 1024


def mha_chunked(q, k, v, qpos, kpos, *, causal: bool, window: int,
                softcap: float, scale: float, ctx=None,
                block_q: int = CHUNK_Q) -> jnp.ndarray:
    """Query-chunked attention for long prefill (XLA path).

    Scans over query blocks so logits never exceed (B, H, bq, S); for
    causal sliding-window layers each block only reads the K/V slice
    [block_end - window - bq, block_end), making SWA compute O(T*window)
    instead of the O(T^2)-then-mask a single einsum would do.  (The Pallas
    flash kernel is the TPU fast path; this keeps the lowered XLA graph
    memory-sane and flop-honest for the dry-run and CPU runs.)
    """
    b, h, t, d = q.shape
    s = k.shape[2]
    nb = -(-t // block_q)
    pad = nb * block_q - t
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)
    limited = causal and window > 0 and t == s
    kwin = min(_round_up(window + block_q, block_q), s) if limited else s

    def body(_, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * block_q, block_q, 2)
        qpi = jax.lax.dynamic_slice_in_dim(qpos, i * block_q, block_q, 1)
        if limited:
            start = jnp.clip((i + 1) * block_q - kwin, 0, s - kwin)
            ki = jax.lax.dynamic_slice_in_dim(k, start, kwin, 2)
            vi = jax.lax.dynamic_slice_in_dim(v, start, kwin, 2)
            kpi = jax.lax.dynamic_slice_in_dim(kpos, start, kwin, 1)
        else:
            ki, vi, kpi = k, v, kpos
        # qpos rows padded with -1 never attend validly; mask q side by
        # clamping their outputs via the kpos mask (output rows are sliced
        # off by the caller anyway).
        oi = mha_xla(qi, ki, vi, jnp.where(qpi < 0, 2**30, qpi), kpi,
                     causal=causal, window=window, softcap=softcap,
                     scale=scale, ctx=ctx)
        return None, oi

    _, blocks = jax.lax.scan(body, None, jnp.arange(nb))
    out = blocks.transpose(1, 2, 0, 3, 4).reshape(b, h, nb * block_q, d)
    return out[:, :, :t]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def mha_xla(q, k, v, qpos, kpos, *, causal: bool, window: int,
            softcap: float, scale: float, ctx=None) -> jnp.ndarray:
    """Masked GQA attention, pure XLA path.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, S, D); qpos: (B, Tq); kpos: (B, S)
    with kpos < 0 marking invalid (unfilled cache) slots.

    GQA is computed by broadcasting K/V to Hq heads (a local slice when the
    head axis is model-sharded) rather than reshaping Q to (Hkv, G, ...) —
    the reshape would break head sharding under TP and force XLA to gather
    the whole attention computation (measured: 16x FLOP replication on the
    granite decode cell; see EXPERIMENTS.md §Perf).
    """
    b, hq, tq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    # K/V stay in their storage dtype (bf16 caches!) — f32 accumulation via
    # preferred_element_type.  Upcasting K/V here makes XLA carry the whole
    # decode cache in f32 across the layer scan (2x HBM traffic, measured).
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _constrain(ctx, logits, ("act_batch", "act_heads", None, None))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = (kpos[:, None, :] >= 0)
    if causal:
        mask = mask & (kpos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask = mask & (kpos[:, None, :] > qpos[:, :, None] - window)
    mask = mask[:, None]  # (B,1,Tq,S)
    logits = jnp.where(mask, logits, -2.0e38)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - jax.lax.stop_gradient(m))
    e = jnp.where(mask, e, 0.0)
    den = jnp.sum(e, axis=-1, keepdims=True)
    p = (e / jnp.maximum(den, 1e-30)).astype(v.dtype)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def init_kv_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                  max_len: int, dtype,
                  kv_heads: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Per-layer KV cache.  Windowed layers get a ring buffer of size
    min(window, max_len) — this is what keeps mixtral/gemma long-context
    decode memory bounded.

    kv_heads overrides the stored head count: when Hkv doesn't divide the TP
    axis but Hq does, the cache is stored GQA-expanded (Hq heads) so it
    shards over "model" instead of being replicated — same bytes/device as
    replication, zero attention collectives (DESIGN.md §6).
    """
    s = min(spec.window, max_len) if spec.window > 0 else max_len
    h = kv_heads or cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, h, s, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, h, s, cfg.head_dim), dtype),
        "pos": jnp.full((batch, s), -1, jnp.int32),
    }


def commit_kv(cache, k_new, v_new, positions, aligned: bool = False):
    """Write T new entries at slots positions % S (ring for windowed).

    Called ONCE per stage after the layer scan ("deferred cache commit"):
    the scan emits only the new-token K/V per layer, so per-step cache
    traffic is O(new tokens), not O(cache).  With `aligned` (slot-uniform
    decode batches / fresh prefill from position 0) the write is a single
    in-place dynamic-update-slice; otherwise a batched scatter.

    Shapes (stacked over layers): cache k/v (L,B,H,S,D), pos (L,B,S);
    k_new/v_new (L,B,H,T,D); positions (B,T).  Unstacked 4-dim k/v are also
    accepted (single layer).  If T > S (prefilling past a ring) only the
    last S tokens are written — earlier ones would be evicted anyway.
    """
    s = cache["k"].shape[-2]
    t = k_new.shape[-2]
    if t > s:
        if t % s:
            aligned = False  # ring wrap lands mid-buffer: need the scatter
        k_new, v_new = k_new[..., -s:, :], v_new[..., -s:, :]
        positions = positions[:, -s:]
    dt = cache["k"].dtype
    k_new, v_new = k_new.astype(dt), v_new.astype(dt)
    slots = positions % s  # (B, T)
    if aligned:
        # All rows share the slot pattern starting at slots[0,0]; contiguous
        # because t == 1 (decode) or the prefill slots start at 0.
        slot = slots[0, 0]
        zeros = (jnp.int32(0),) * (cache["k"].ndim - 2)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                         zeros + (slot, jnp.int32(0)))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                         zeros + (slot, jnp.int32(0)))
        posb = jnp.broadcast_to(
            positions, cache["pos"].shape[:-1] + (positions.shape[-1],))
        pos = jax.lax.dynamic_update_slice(
            cache["pos"], posb.astype(cache["pos"].dtype),
            (jnp.int32(0),) * (cache["pos"].ndim - 1) + (slot,))
        return {"k": k, "v": v, "pos": pos}

    def one(bufk, bufv, bufp, nk, nv, sl, po):
        # bufk/bufv: (H,S,D); nk/nv: (H,T,D); sl/po: (T,)
        return (bufk.at[:, sl].set(nk), bufv.at[:, sl].set(nv),
                bufp.at[sl].set(po))

    upd = jax.vmap(one)  # over batch
    if cache["k"].ndim == 5:  # stacked layers: vmap over L too
        upd = jax.vmap(upd, in_axes=(0, 0, 0, 0, 0, None, None))
    k, v, pos = upd(cache["k"], cache["v"], cache["pos"], k_new, v_new,
                    slots, positions)
    return {"k": k, "v": v, "pos": pos}


def mha_decode(q, k_cache, v_cache, k_new, v_new, qpos, kpos, *,
               window: int, softcap: float, scale: float,
               ctx=None) -> jnp.ndarray:
    """One-token attention over a STALE cache plus the current token.

    Two-piece online softmax: logits over the cache (B,H,1,S) and over the
    self token (B,H,1,1) are normalized jointly, so attention never needs
    the new token written into the cache first (deferred commit).  A ring
    slot the current token would overwrite holds an entry exactly `window`
    steps old, which the window mask already hides.
    """
    b, hq, _, d = q.shape
    g = hq // k_cache.shape[1]
    if g > 1:
        k_cache = jnp.repeat(k_cache, g, axis=1)
        v_cache = jnp.repeat(v_cache, g, axis=1)
        k_new = jnp.repeat(k_new, g, axis=1)
        v_new = jnp.repeat(v_new, g, axis=1)
    lc = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache,
                    preferred_element_type=jnp.float32) * scale
    lc = _constrain(ctx, lc, ("act_batch", "act_heads", None, "act_cache"))
    ls = jnp.einsum("bhqd,bhqd->bhq", q, k_new,
                    preferred_element_type=jnp.float32)[..., None] * scale
    if softcap > 0:
        lc = softcap * jnp.tanh(lc / softcap)
        ls = softcap * jnp.tanh(ls / softcap)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask = mask & (kpos[:, None, :] > qpos[:, :, None] - window)
    mask = mask[:, None]
    lc = jnp.where(mask, lc, -2.0e38)
    m = jnp.maximum(jnp.max(lc, axis=-1, keepdims=True), ls)
    ec = jnp.where(mask, jnp.exp(lc - m), 0.0)
    es = jnp.exp(ls - m)
    den = jnp.sum(ec, axis=-1, keepdims=True) + es
    pc = (ec / den).astype(v_cache.dtype)
    out = jnp.einsum("bhqk,bhkd->bhqd", pc, v_cache,
                     preferred_element_type=jnp.float32)
    out = out + (es / den) * v_new.astype(jnp.float32)
    return out.astype(q.dtype)


def _full_attention(q, k, v, positions, spec, cfg, scale, ctx, impl):
    """Full-sequence attention dispatch: Pallas flash kernel on TPU,
    query-chunked XLA above the threshold, plain einsum otherwise."""
    if impl == "pallas" and spec.causal:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=spec.window,
                                    softcap=cfg.attn_softcap, scale=scale)
    if q.shape[2] >= CHUNKED_ATTN_THRESHOLD:
        return mha_chunked(q, k, v, positions, positions, causal=spec.causal,
                           window=spec.window, softcap=cfg.attn_softcap,
                           scale=scale, ctx=ctx)
    return mha_xla(q, k, v, positions, positions, causal=spec.causal,
                   window=spec.window, softcap=cfg.attn_softcap,
                   scale=scale, ctx=ctx)


def attention(p: Dict[str, Any], cfg: ModelConfig, spec: LayerSpec,
              x: jnp.ndarray, positions: jnp.ndarray,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              ctx=None, impl: str = "xla"):
    """Self-attention with optional KV cache.  Returns (out, new_cache)."""
    b, t, _ = x.shape
    ap = p["attn"]
    q = x @ ap["wq"].astype(x.dtype)
    k = x @ ap["wk"].astype(x.dtype)
    v = x @ ap["wv"].astype(x.dtype)
    if cfg.attn_bias:
        q = q + ap["bq"].astype(x.dtype)
        k = k + ap["bk"].astype(x.dtype)
        v = v + ap["bv"].astype(x.dtype)
    q = q.reshape(b, t, cfg.num_heads, cfg.head_dim).swapaxes(1, 2)
    k = k.reshape(b, t, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
    v = v.reshape(b, t, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
    q = _constrain(ctx, q, ("act_batch", "act_heads", "act_seq", None))
    k = _constrain(ctx, k, ("act_batch", "act_kv_heads", "act_seq", None))

    if cfg.qk_norm:
        q = rms_head_norm(ap["q_norm"], q)
        k = rms_head_norm(ap["k_norm"], k)
    theta = spec.rope_theta or cfg.rope_theta
    if cfg.rope_fraction > 0 and not cfg.learned_pos:
        q = rope(q, positions, theta, cfg.rope_fraction)
        k = rope(k, positions, theta, cfg.rope_fraction)

    if getattr(ctx, "kv_expand", False):
        g = cfg.num_heads // cfg.num_kv_heads
        if g > 1:
            k = jnp.repeat(k, g, axis=1)
            v = jnp.repeat(v, g, axis=1)
        k = _constrain(ctx, k, ("act_batch", "act_heads", "act_seq", None))
        v = _constrain(ctx, v, ("act_batch", "act_heads", "act_seq", None))

    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.head_dim ** -0.5

    kv_out = None
    if cache is not None and t == 1:
        # Decode: attend over the stale cache + current token; the cache
        # write is deferred to one post-scan commit (commit_kv).
        kpos = _constrain(ctx, cache["pos"], ("act_batch", "act_cache"))
        out = mha_decode(q, cache["k"], cache["v"], k, v, positions, kpos,
                         window=spec.window, softcap=cfg.attn_softcap,
                         scale=scale, ctx=ctx)
        kv_out = {"k": k, "v": v}
    elif cache is not None:
        # Fresh prefill: attend over the in-prefill keys (exact even when the
        # prefill exceeds a ring cache); the cache write is deferred.
        out = _full_attention(q, k, v, positions, spec, cfg, scale, ctx, impl)
        kv_out = {"k": k, "v": v}
    else:
        out = _full_attention(q, k, v, positions, spec, cfg, scale, ctx, impl)

    out = out.swapaxes(1, 2).reshape(b, t, cfg.q_dim)
    out = out @ ap["wo"].astype(x.dtype)
    return _constrain(ctx, out, ("act_batch", "act_seq", "act_embed")), kv_out


def cross_attention(p: Dict[str, Any], cfg: ModelConfig, x: jnp.ndarray,
                    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],
                    ctx=None) -> jnp.ndarray:
    """Decoder cross-attention over precomputed encoder K/V (B,Hkv,S,D)."""
    b, t, _ = x.shape
    ap = p["attn"]
    q = (x @ ap["xq"].astype(x.dtype)).reshape(
        b, t, cfg.num_heads, cfg.head_dim).swapaxes(1, 2)
    k, v = enc_kv
    s = k.shape[2]
    qpos = jnp.zeros((b, t), jnp.int32)
    kpos = jnp.zeros((b, s), jnp.int32)
    out = mha_xla(q, k, v, qpos, kpos, causal=False, window=0,
                  softcap=0.0, scale=cfg.head_dim ** -0.5)
    out = out.swapaxes(1, 2).reshape(b, t, cfg.q_dim)
    return out @ ap["xo"].astype(x.dtype)


def encode_cross_kv(p: Dict[str, Any], cfg: ModelConfig,
                    enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, _ = enc_out.shape
    ap = p["attn"]
    k = (enc_out @ ap["xk"].astype(enc_out.dtype)).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
    v = (enc_out @ ap["xv"].astype(enc_out.dtype)).reshape(
        b, s, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
    return k, v


# -------------------------------------------------------------------- MLP --

def _act(cfg: ModelConfig, gate: jnp.ndarray) -> jnp.ndarray:
    if cfg.act in ("swiglu",):
        return jax.nn.silu(gate)
    return jax.nn.gelu(gate, approximate=True)


def mlp(p: Dict[str, Any], cfg: ModelConfig, x: jnp.ndarray,
        ctx=None) -> jnp.ndarray:
    if cfg.act in ("swiglu", "geglu"):
        h = _act(cfg, x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype), approximate=True)
    h = _constrain(ctx, h, ("act_batch", "act_seq", "act_mlp"))
    out = h @ p["w_down"].astype(x.dtype)
    return _constrain(ctx, out, ("act_batch", "act_seq", "act_embed"))


# -------------------------------------------------------------------- MoE --

def moe_mlp(p: Dict[str, Any], cfg: ModelConfig, x: jnp.ndarray,
            ctx=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed MoE, capacity-based dispatch, DP-shard-local ranking.

    Returns (y, aux_loss).  Tokens are grouped into G = |DP| shard-local
    chunks; each chunk ranks its (token, choice) pairs within each expert by
    a LOCAL cumsum (a cross-shard cumsum would make XLA all-gather the whole
    one-hot tensor — measured 13.7s of link traffic on granite train_4k).
    Tokens past the per-chunk capacity are dropped (Switch semantics).  The
    (G, E, C, d) dispatch tensor is sharded G->data, E->model(EP), so the
    expert exchange lowers to the two canonical MoE all-to-alls.
    """
    mcfg = cfg.moe
    e, k = mcfg.num_experts, mcfg.top_k
    b, t, d = x.shape
    n = b * t
    g = getattr(ctx, "dp_size", 1) if ctx is not None else 1
    if n % g or (n // g) < 8:
        g = 1
    nl = n // g                                               # tokens/chunk
    xf = x.reshape(g, nl, d)
    xf = _constrain(ctx, xf, ("act_batch", None, "act_embed"))

    router_logits = (xf.astype(jnp.float32)
                     @ p["router"].astype(jnp.float32))       # (G, nl, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(router_logits, k)            # (G, nl, k)
    top_w = jax.nn.softmax(top_w, axis=-1)                    # renormalize

    # Load-balancing aux loss (Switch): E * <f_e * p_e>.
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (n * k))
    aux = e * jnp.sum(me * ce)

    if n <= mcfg.no_drop_threshold:
        cap = nl  # exact (drop-free) routing for decode / small batches
    else:
        cap = min(max(8, int(math.ceil(
            mcfg.capacity_factor * nl * k / e))), nl)

    flat_e = top_e.reshape(g, nl * k)
    flat_w = top_w.reshape(g, nl * k).astype(x.dtype)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (G, nl*k, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot               # chunk-local
    rank = jnp.take_along_axis(ranks, flat_e[..., None], axis=2)[..., 0]
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)      # drop -> trash

    token_of = jnp.broadcast_to(
        (jnp.arange(nl * k, dtype=jnp.int32) // k)[None], (g, nl * k))
    table = jnp.full((g, e * cap + 1), nl, jnp.int32)
    table = jax.vmap(lambda tb, sl, to: tb.at[sl].set(to))(table, slot,
                                                           token_of)
    wtab = jax.vmap(lambda wb, sl, w: wb.at[sl].set(w))(
        jnp.zeros((g, e * cap + 1), x.dtype), slot, flat_w)
    table, wtab = table[:, :e * cap], wtab[:, :e * cap]

    x_pad = jnp.concatenate([xf, jnp.zeros((g, 1, d), x.dtype)], 1)
    xe = jax.vmap(lambda xp, tb: xp[tb])(x_pad, table)
    xe = xe.reshape(g, e, cap, d)
    # G->data, E->model: this constraint IS the dispatch all-to-all.
    xe = _constrain(ctx, xe, ("act_batch", "act_experts", None, "act_embed"))

    if cfg.act in ("swiglu", "geglu"):
        h = _act(cfg, jnp.einsum("gecd,edf->gecf", xe,
                                 p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe,
                                   p["w_up"].astype(x.dtype)),
                        approximate=True)
    h = _constrain(ctx, h, ("act_batch", "act_experts", None, "act_mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    # combine all-to-all: back to chunk-major
    ye = _constrain(ctx, ye, ("act_batch", None, None, "act_embed"))

    ye_flat = ye.reshape(g, e * cap, d) * wtab[..., None]
    y = jax.vmap(lambda yb, tb, yf: yb.at[tb].add(yf))(
        jnp.zeros((g, nl + 1, d), x.dtype), table, ye_flat)[:, :nl]
    y = y.reshape(b, t, d)
    return _constrain(ctx, y, ("act_batch", "act_seq", "act_embed")), aux
