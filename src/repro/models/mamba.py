"""Mamba-2 block (SSD layer) — used by mamba2-1.3b and the Mamba sub-layers
of jamba.

Note (DESIGN.md §Arch-applicability): Jamba's original Mamba-1 layers are
modeled with Mamba-2 SSD blocks of the same state size.  The SSD dual form is
the TPU-native formulation (chunked matmuls on the MXU instead of a
per-channel sequential selective scan); state dimensions and parameter
budgets match.

Cache layout (decode): {"ssm": (B, H, P, N) f32, "conv": (B, K-1, C)}.
Constant-size state is what makes long_500k decode O(1) per token.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm_ops
from repro.models.config import ModelConfig


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    ssm = cfg.ssm
    d = cfg.d_model
    din = ssm.d_inner(d)
    gn = ssm.n_groups * ssm.d_state
    h = ssm.num_heads(d)
    return {
        "ssm": jnp.zeros((batch, h, ssm.head_dim, ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, ssm.conv_kernel - 1, din + 2 * gn), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    ssm = cfg.ssm
    din = ssm.d_inner(cfg.d_model)
    gn = ssm.n_groups * ssm.d_state
    h = ssm.num_heads(cfg.d_model)
    z, xs, b, c, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + gn, 2 * din + 2 * gn], axis=-1)
    assert dt.shape[-1] == h
    return z, xs, b, c, dt


def mamba_block(p: Dict[str, Any], cfg: ModelConfig, x: jnp.ndarray,
                cache: Optional[Dict[str, Any]] = None, ctx=None,
                use_kernel: bool = False) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full-sequence Mamba-2 block.  x: (B, T, d).  If `cache` is given and
    T == 1, runs the O(1) decode step instead."""
    mp = p["mamba"]
    ssm = cfg.ssm
    bsz, t, d = x.shape
    din = ssm.d_inner(d)
    gn = ssm.n_groups * ssm.d_state
    h = ssm.num_heads(d)

    proj = x @ mp["in_proj"].astype(x.dtype)        # (B,T,2din+2gn+H)
    z, xs, b, c, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, b, c], axis=-1)  # (B,T,din+2gn)
    if cache is not None and t == 1:
        conv_out, conv_state = ssm_ops.causal_conv_step(
            conv_in[:, 0], cache["conv"], mp["conv_w"], mp["conv_b"])
        conv_out = conv_out[:, None, :]
    else:
        conv_out = ssm_ops.causal_conv(conv_in, mp["conv_w"], mp["conv_b"])
        conv_state = conv_in[:, -(ssm.conv_kernel - 1):] if cache is not None \
            else None
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, b, c = jnp.split(conv_out, [din, din + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + mp["dt_bias"].astype(jnp.float32))   # (B,T,H)
    a_log_t = -jnp.exp(mp["a_log"].astype(jnp.float32)) * dt    # <= 0
    heads = xs.reshape(bsz, t, h, ssm.head_dim)
    x_eff = heads * dt[..., None].astype(x.dtype)

    if cache is not None and t == 1:
        y, ssm_state = ssm_ops.ssd_decode_step(
            x_eff[:, 0], a_log_t[:, 0], b[:, 0], c[:, 0], cache["ssm"])
        y = y[:, None]
    elif use_kernel:
        from repro.kernels import ops as kops
        y, ssm_state = kops.ssd(x_eff, a_log_t, b, c,
                                init_state=cache["ssm"] if cache else None,
                                block_t=ssm.chunk)
    else:
        y, ssm_state = ssm_ops.ssd_chunked_jnp(
            x_eff, a_log_t, b, c,
            init_state=cache["ssm"] if cache else None, chunk=ssm.chunk)

    y = y + heads * mp["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, t, din)

    # Gated RMSNorm (Mamba-2): norm(y * silu(z)) * scale.
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(ms + 1e-6) * mp["gate_norm_scale"].astype(jnp.float32)
    out = g.astype(x.dtype) @ mp["out_proj"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"ssm": ssm_state, "conv": conv_state}
    return out, new_cache
