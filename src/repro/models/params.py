"""Parameter-tree machinery: one structure definition drives three views.

Every parameter is declared once as a `ParamDef` (shape + logical axes +
init rule).  From that single tree we derive:

  * `init_params`     — materialized jnp arrays (smoke tests / real training)
  * `abstract_params` — ShapeDtypeStructs, NO allocation (multi-pod dry-run)
  * `logical_axes`    — logical-axis tuples consumed by sharding/rules.py

Logical axis vocabulary: "vocab", "embed", "q_heads", "kv_heads", "mlp",
"experts", "ssm_inner", "ssm_state", "conv", "pos", "layers" (stacked scan
dim — never sharded).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LayerSpec, ModelConfig, Stage


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    init: str = "normal"       # normal | zeros | ones | embed | a_log | dt_bias
    fan_in_dims: Tuple[int, ...] = (0,)  # dims treated as fan-in for scaling


def _norm_defs(cfg: ModelConfig, name: str) -> Dict[str, ParamDef]:
    d = {f"{name}_scale": ParamDef((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d[f"{name}_bias"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
    return d


def _attn_defs(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, ParamDef]:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    defs: Dict[str, ParamDef] = {
        "wq": ParamDef((d, qd), ("embed", "q_heads")),
        "wk": ParamDef((d, kvd), ("embed", "kv_heads")),
        "wv": ParamDef((d, kvd), ("embed", "kv_heads")),
        "wo": ParamDef((qd, d), ("q_heads", "embed")),
    }
    if cfg.attn_bias:
        defs["bq"] = ParamDef((qd,), ("q_heads",), "zeros")
        defs["bk"] = ParamDef((kvd,), ("kv_heads",), "zeros")
        defs["bv"] = ParamDef((kvd,), ("kv_heads",), "zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((cfg.head_dim,), (None,), "ones")
        defs["k_norm"] = ParamDef((cfg.head_dim,), (None,), "ones")
    if spec.cross:
        defs.update({
            "xq": ParamDef((d, qd), ("embed", "q_heads")),
            "xk": ParamDef((d, kvd), ("embed", "kv_heads")),
            "xv": ParamDef((d, kvd), ("embed", "kv_heads")),
            "xo": ParamDef((qd, d), ("q_heads", "embed")),
        })
    return defs


def _mlp_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, ff), ("embed", "mlp")),
            "w_up": ParamDef((d, ff), ("embed", "mlp")),
            "w_down": ParamDef((ff, d), ("mlp", "embed")),
        }
    return {
        "w_up": ParamDef((d, ff), ("embed", "mlp")),
        "w_down": ParamDef((ff, d), ("mlp", "embed")),
    }


def _moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    defs = {"router": ParamDef((d, e), ("embed", None))}
    if cfg.act in ("swiglu", "geglu"):
        defs.update({
            "w_gate": ParamDef((e, d, ff), ("experts", "embed", "mlp"),
                               fan_in_dims=(1,)),
            "w_up": ParamDef((e, d, ff), ("experts", "embed", "mlp"),
                             fan_in_dims=(1,)),
            "w_down": ParamDef((e, ff, d), ("experts", "mlp", "embed"),
                               fan_in_dims=(1,)),
        })
    else:
        defs.update({
            "w_up": ParamDef((e, d, ff), ("experts", "embed", "mlp"),
                             fan_in_dims=(1,)),
            "w_down": ParamDef((e, ff, d), ("experts", "mlp", "embed"),
                               fan_in_dims=(1,)),
        })
    return defs


def _mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    ssm = cfg.ssm
    d = cfg.d_model
    din = ssm.d_inner(d)
    gn = ssm.n_groups * ssm.d_state
    h = ssm.num_heads(d)
    conv_dim = din + 2 * gn
    return {
        "in_proj": ParamDef((d, 2 * din + 2 * gn + h), ("embed", "ssm_inner")),
        "conv_w": ParamDef((ssm.conv_kernel, conv_dim), ("conv", "ssm_inner"),
                           "normal", fan_in_dims=(0,)),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), "zeros"),
        "a_log": ParamDef((h,), (None,), "a_log"),
        "d_skip": ParamDef((h,), (None,), "ones"),
        "dt_bias": ParamDef((h,), (None,), "dt_bias"),
        "gate_norm_scale": ParamDef((din,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((din, d), ("ssm_inner", "embed")),
    }


def layer_defs(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    defs: Dict[str, Any] = {}
    defs.update(_norm_defs(cfg, "ln1"))
    if spec.kind == "attn":
        defs["attn"] = _attn_defs(cfg, spec)
        if spec.cross:
            defs.update(_norm_defs(cfg, "ln_cross"))
    else:
        defs["mamba"] = _mamba_defs(cfg)
    if spec.moe or cfg.d_ff > 0:  # mamba2-style layers have no MLP block
        defs.update(_norm_defs(cfg, "ln2"))
        defs["moe" if spec.moe else "mlp"] = (_moe_defs(cfg) if spec.moe
                                              else _mlp_defs(cfg))
        if cfg.post_norm:
            defs.update(_norm_defs(cfg, "post2"))
    if cfg.post_norm:
        defs.update(_norm_defs(cfg, "post1"))
    return defs


def _stack(defs: Dict[str, Any], repeats: int) -> Dict[str, Any]:
    """Add the leading stacked-layer axis for scan."""
    def f(d: ParamDef) -> ParamDef:
        return ParamDef((repeats,) + d.shape, ("layers",) + d.axes, d.init,
                        tuple(x + 1 for x in d.fan_in_dims))
    return jax.tree.map(f, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def stage_defs(cfg: ModelConfig, stage: Stage) -> Dict[str, Any]:
    return _stack({f"sub{i}": layer_defs(cfg, sl)
                   for i, sl in enumerate(stage.block)}, stage.repeats)


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                          "embed"),
        "stages": {f"stage{i}": stage_defs(cfg, st)
                   for i, st in enumerate(cfg.stages)},
    }
    defs.update(_norm_defs(cfg, "final"))
    if cfg.enc_stages:
        defs["enc_stages"] = {f"stage{i}": stage_defs(cfg, st)
                              for i, st in enumerate(cfg.enc_stages)}
        defs.update(_norm_defs(cfg, "enc_final"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                   ("embed", "vocab"))
    if cfg.learned_pos:
        defs["pos_embed"] = ParamDef((cfg.learned_pos, cfg.d_model),
                                     ("pos", "embed"), "embed")
        if cfg.enc_stages:
            defs["enc_pos_embed"] = ParamDef(
                (max(cfg.num_audio_frames, 1), cfg.d_model),
                ("pos", "embed"), "embed")
    return defs


# ---------------------------------------------------------------- views ----

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct tree — used by the dry-run; allocates nothing."""
    dt = jnp.dtype(dtype or cfg.dtype)
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dt),
                        model_defs(cfg), is_leaf=_is_def)


def logical_axes(cfg: ModelConfig):
    return jax.tree.map(lambda d: d.axes, model_defs(cfg), is_leaf=_is_def)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    """Materialize parameters (smoke tests, real training of small models)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    defs = model_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def make(d: ParamDef, k) -> jnp.ndarray:
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "a_log":
            # Mamba-2: A in [1, 16) -> a_log = log(A); decay = -exp(a_log)*dt.
            u = jax.random.uniform(k, d.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if d.init == "dt_bias":
            # inverse-softplus of dt ~ U(1e-3, 1e-1)
            u = jax.random.uniform(k, d.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dt)
        if d.init == "embed":
            return (jax.random.normal(k, d.shape, jnp.float32) * 0.02).astype(dt)
        fan_in = max(int(np.prod([d.shape[i] for i in d.fan_in_dims])), 1)
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt)

    params = [make(d, k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, params)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
