"""Pure-XLA chunked SSD used inside model forward passes.

Same math as kernels/ssd_scan.py (the Pallas TPU kernel), expressed as
einsums over (chunks, L, L) tiles with a lax.scan carrying the chunk-to-chunk
state.  This path is what the dry-run lowers (Pallas doesn't lower to the
host backend) and doubles as an independent implementation cross-checked
against both ref.ssd and the kernel in tests.

Shapes: x (B,T,H,P), a_log (B,T,H) <= 0, b,c (B,T,N); returns
(y (B,T,H,P), final_state (B,H,P,N)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked_jnp(x, a_log, b, c, init_state=None, *, chunk: int = 128):
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    lt = min(chunk, t)
    pad = (-t) % lt
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // lt

    xf = x.reshape(bsz, nc, lt, h, p).astype(jnp.float32)
    al = a_log.reshape(bsz, nc, lt, h).astype(jnp.float32)
    bf = b.reshape(bsz, nc, lt, n).astype(jnp.float32)
    cf = c.reshape(bsz, nc, lt, n).astype(jnp.float32)

    lcum = jnp.cumsum(al, axis=2)                     # (B,nc,L,H)
    total = lcum[:, :, -1]                            # (B,nc,H)

    # Intra-chunk: y[l] = sum_{s<=l} exp(lcum[l]-lcum[s]) <c_l, b_s> x_s
    cb = jnp.einsum("bcln,bcsn->bcls", cf, bf)        # shared across heads
    ldiff = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]   # (B,nc,L,L,H)
    tri = (jnp.arange(lt)[:, None] >= jnp.arange(lt)[None, :])
    decay = jnp.where(tri[None, None, :, :, None],
                      jnp.exp(jnp.minimum(ldiff, 0.0)), 0.0)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", cb, decay, xf)

    # Inter-chunk state: inj_c = sum_s exp(total-lcum[s]) x_s b_s^T
    w = jnp.exp(total[:, :, None, :] - lcum)          # (B,nc,L,H)
    inj = jnp.einsum("bclh,bclhp,bcln->bchpn", w, xf, bf)
    cdecay = jnp.exp(total)                           # (B,nc,H)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        dec_c, inj_c = inp                            # (B,H), (B,H,P,N)
        out = state                                   # state BEFORE this chunk
        state = state * dec_c[:, :, None, None] + inj_c
        return state, out

    final, h_prev = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (cdecay.swapaxes(0, 1), inj.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                    # (B,nc,H,P,N)

    y_state = jnp.einsum("bclh,bcln,bchpn->bclhp",
                         jnp.exp(lcum), cf, h_prev)
    y = (y_intra + y_state).reshape(bsz, nc * lt, h, p)[:, :t]
    return y.astype(x.dtype), final


def ssd_decode_step(x, a_log, b, c, state):
    """Single-token SSD update: x (B,H,P), a_log (B,H), b,c (B,N),
    state (B,H,P,N) -> (y (B,H,P), new_state)."""
    dec = jnp.exp(a_log.astype(jnp.float32))[:, :, None, None]
    state = state * dec + jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32),
                                     b.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    return y.astype(x.dtype), state


def causal_conv(x, w, bias):
    """Depthwise causal conv: x (B,T,C), w (K,C), bias (C,)."""
    k, c = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=c)
    return (out + bias.astype(jnp.float32)).astype(x.dtype)


def causal_conv_step(x_new, conv_state, w, bias):
    """Decode-time conv: x_new (B,C), conv_state (B,K-1,C) holding the last
    K-1 inputs -> (y (B,C), new_state)."""
    k = w.shape[0]
    full = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + bias.astype(jnp.float32)
    return y.astype(x_new.dtype), full[:, 1:]
