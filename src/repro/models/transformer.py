"""Model forward passes: stage-scanned decoder (and encoder-decoder) stacks.

Each Stage is executed as one `jax.lax.scan` over its stacked block params —
HLO size stays O(block) regardless of depth, which keeps 72-layer/398B
configs compilable.  `jax.checkpoint` wraps the scan body (one block), so a
Stage with a K-sub-layer block natively gives the sqrt-remat pattern: one
saved carry per block, recompute inside.

Entry points:
  forward(...)        — full-sequence logits (training / prefill)
  decode_step(...)    — one token against caches
  init_caches(...)    — stacked per-stage cache pytrees
  lm_loss(...)        — next-token CE (+ MoE aux), vocab-sharding friendly
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.config import LayerSpec, ModelConfig, Stage


# ------------------------------------------------------------- sub-layer ---

def _sublayer(lp: Dict[str, Any], cfg: ModelConfig, spec: LayerSpec,
              x: jnp.ndarray, positions: jnp.ndarray,
              cache: Optional[Dict[str, Any]], enc_out: Optional[jnp.ndarray],
              ctx, impl: str):
    """One residual block: (attn | mamba) [+ cross-attn] + (mlp | moe)."""
    aux = jnp.float32(0.0)
    h = L.norm(lp, cfg, x, "ln1")
    if spec.kind == "attn":
        h, kv_new = L.attention(lp, cfg, spec, h, positions,
                                cache=_get(cache, "kv"), ctx=ctx, impl=impl)
    else:
        h, mcache = M.mamba_block(lp, cfg, h, cache=_get(cache, "ssm_cache"),
                                  ctx=ctx, use_kernel=(impl == "pallas_ssd"))
    if cfg.post_norm:
        h = L.norm(lp, cfg, h, "post1")
    x = x + h

    out_cache: Dict[str, Any] = {}
    if spec.kind == "attn" and cache is not None:
        out_cache["kv_new"] = kv_new  # committed post-scan (commit_kv)
    elif spec.kind == "mamba" and cache is not None:
        out_cache["ssm_cache"] = mcache

    if spec.cross:
        h = L.norm(lp, cfg, x, "ln_cross")
        # Prefill passes enc_out (cross K/V computed and cached); decode
        # passes enc_out=None and reads the cached projections.
        if enc_out is None:
            kv = (cache["cross"]["k"], cache["cross"]["v"])
        else:
            kv = L.encode_cross_kv(lp, cfg, enc_out)
        h = L.cross_attention(lp, cfg, h, kv, ctx=ctx)
        x = x + h
        if cache is not None:
            out_cache["cross"] = {"k": kv[0], "v": kv[1]}

    if spec.moe or cfg.d_ff > 0:  # mamba2-style layers have no MLP block
        h = L.norm(lp, cfg, x, "ln2")
        if spec.moe:
            h, a = L.moe_mlp(lp["moe"], cfg, h, ctx=ctx)
            aux = aux + a
        else:
            h = L.mlp(lp["mlp"], cfg, h, ctx=ctx)
        if cfg.post_norm:
            h = L.norm(lp, cfg, h, "post2")
        x = x + h
    return x, out_cache, aux


def _get(cache, key):
    if cache is None:
        return None
    return cache.get(key)


# ----------------------------------------------------------------- stage ---

@jax.custom_vjp
def _pin_gathers(tree):
    """Identity that blocks XLA's loop-invariant hoisting of FSDP weight
    all-gathers (see the pin_gathers comment below).  `lax.optimization_
    barrier` has no autodiff rule (NotImplementedError under grad as of jax
    0.4.37), so this wrapper supplies the obvious one: barrier on the
    forward, barrier on the (equally hoistable) cotangent gathers."""
    return jax.lax.optimization_barrier(tree)


def _pin_gathers_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _pin_gathers_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_pin_gathers.defvjp(_pin_gathers_fwd, _pin_gathers_bwd)


def _stage_forward(sp: Dict[str, Any], cfg: ModelConfig, stage: Stage,
                   x: jnp.ndarray, positions: jnp.ndarray,
                   cache: Optional[Dict[str, Any]],
                   enc_out: Optional[jnp.ndarray], ctx, impl: str,
                   remat: bool):
    """Scan the stacked block.  cache leaves carry a leading (repeats,) dim."""

    def body(carry, xs):
        x, aux = carry
        layer_p, layer_cache = xs
        if getattr(ctx, "pin_gathers", False):
            # Pin FSDP weight all-gathers inside the loop: without this XLA
            # hoists loop-invariant gathers out of the (microbatch x layer)
            # scans and materializes EVERY layer's gathered weights at once
            # (~49 GB/device for jamba-398B; see EXPERIMENTS.md §Perf P8).
            layer_p = _pin_gathers(layer_p)
        new_cache: Dict[str, Any] = {}
        for i, spec in enumerate(stage.block):
            sub_cache = (layer_cache.get(f"sub{i}")
                         if isinstance(layer_cache, dict) else None)
            x, c_i, a_i = _sublayer(layer_p[f"sub{i}"], cfg, spec, x,
                                    positions, sub_cache, enc_out, ctx, impl)
            if c_i:
                new_cache[f"sub{i}"] = c_i
            aux = aux + a_i
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed")) \
            if ctx is not None else x
        return (x, aux), new_cache

    if remat:
        body = jax.checkpoint(body)

    if stage.repeats == 1:
        # No scan needed; avoids degenerate (1,)-leading stacked ops.
        sp1 = jax.tree.map(lambda a: a[0], sp)
        c1 = None if cache is None else jax.tree.map(lambda a: a[0], cache)
        (x, aux), nc = body((x, jnp.float32(0.0)), (sp1, c1))
        ys = jax.tree.map(lambda a: a[None], nc)
    else:
        (x, aux), ys = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (sp, cache))
    if cache is None:
        return x, None, aux
    new_cache = _commit_stage_cache(cfg, stage, cache, ys, positions, ctx)
    return x, new_cache, aux


def _commit_stage_cache(cfg: ModelConfig, stage: Stage, old_cache, ys,
                        positions, ctx):
    """Apply the deferred KV commits (one in-place write per stage) and pass
    through scan-produced mamba/cross cache entries."""
    aligned = bool(getattr(ctx, "aligned_decode", False))
    new_cache: Dict[str, Any] = {}
    for i, spec in enumerate(stage.block):
        e_old = old_cache.get(f"sub{i}", {})
        e_ys = ys.get(f"sub{i}", {}) if isinstance(ys, dict) else {}
        entry: Dict[str, Any] = {}
        if "kv_new" in e_ys:
            kvn = e_ys["kv_new"]  # k/v: (L, B, H, T, D)
            entry["kv"] = L.commit_kv(e_old["kv"], kvn["k"], kvn["v"],
                                      positions, aligned=aligned)
        if "ssm_cache" in e_ys:
            entry["ssm_cache"] = e_ys["ssm_cache"]
        if "cross" in e_ys:
            entry["cross"] = e_ys["cross"]
        elif "cross" in e_old:
            entry["cross"] = e_old["cross"]
        if entry:
            new_cache[f"sub{i}"] = entry
    return new_cache


# ----------------------------------------------------------------- model ---

def _embed(params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head(params, cfg: ModelConfig, x: jnp.ndarray, ctx) -> jnp.ndarray:
    x = L.norm(params, cfg, x, "final")
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding rows
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -2.0e38)
    if ctx is not None:
        logits = ctx.constrain(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits


def encode(params, cfg: ModelConfig, frames: jnp.ndarray, ctx=None,
           impl: str = "xla") -> jnp.ndarray:
    """Encoder stack over stub frame embeddings (B, S, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    if cfg.learned_pos and "enc_pos_embed" in params:
        s = x.shape[1]
        x = x + params["enc_pos_embed"][:s].astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                           x.shape[:2])
    for i, st in enumerate(cfg.enc_stages):
        x, _, _ = _stage_forward(params["enc_stages"][f"stage{i}"], cfg, st,
                                 x, pos, None, None, ctx, impl, remat=True)
    return L.norm(params, cfg, x, "enc_final")


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray, *,
            frontend: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            caches: Optional[Dict[str, Any]] = None,
            ctx=None, impl: str = "xla", remat: bool = True):
    """Full-sequence forward.  tokens: (B, T) int32.

    frontend: (B, Nf, d) precomputed patch embeddings (VLM) prepended to the
    token embeddings.  enc_out: (B, S, d) encoder output (enc-dec).
    Returns (logits (B, T', V) f32, new_caches, aux) with
    T' = Nf + T for VLM, T otherwise.
    """
    x = _embed(params, cfg, tokens)
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (b, t))
    if cfg.learned_pos:
        x = x + params["pos_embed"][positions].astype(x.dtype)
    if ctx is not None:
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))

    aux = jnp.float32(0.0)
    new_caches: Dict[str, Any] = {}
    for i, st in enumerate(cfg.stages):
        stage_cache = None if caches is None else caches[f"stage{i}"]
        x, nc, a = _stage_forward(params["stages"][f"stage{i}"], cfg, st, x,
                                  positions, stage_cache, enc_out, ctx, impl,
                                  remat)
        aux = aux + a
        if nc is not None:
            new_caches[f"stage{i}"] = nc
    logits = _head(params, cfg, x, ctx)
    return logits, (new_caches or None), aux


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray,
                lengths: jnp.ndarray, caches: Dict[str, Any], *,
                ctx=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step: tokens (B, 1), lengths (B,) current cache lengths.
    Returns (logits (B, 1, V), new_caches)."""
    positions = lengths[:, None].astype(jnp.int32)
    logits, new_caches, _ = forward(params, cfg, tokens, positions=positions,
                                    caches=caches, ctx=ctx, impl="xla",
                                    remat=False)
    return logits, new_caches


# ---------------------------------------------------------------- caches ---

def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                enc_len: int = 0,
                kv_heads: Optional[int] = None) -> Dict[str, Any]:
    """Stacked cache pytree matching the stage structure.  kv_heads overrides
    the stored head count (GQA-expanded caches under TP; see layers)."""
    dt = jnp.dtype(dtype or cfg.dtype)
    caches: Dict[str, Any] = {}
    for i, st in enumerate(cfg.stages):
        sub: Dict[str, Any] = {}
        for j, spec in enumerate(st.block):
            entry: Dict[str, Any] = {}
            if spec.kind == "attn":
                kv = L.init_kv_cache(cfg, spec, batch, max_len, dt,
                                     kv_heads=kv_heads)
                entry["kv"] = _stack_tree(kv, st.repeats)
            else:
                mc = M.init_mamba_cache(cfg, batch, dt)
                entry["ssm_cache"] = _stack_tree(mc, st.repeats)
            if spec.cross:
                s = enc_len or cfg.num_audio_frames
                z = jnp.zeros((st.repeats, batch, cfg.num_kv_heads, s,
                               cfg.head_dim), dt)
                entry["cross"] = {"k": z, "v": z}
            sub[f"sub{j}"] = entry
        caches[f"stage{i}"] = sub
    return caches


def _stack_tree(tree, repeats: int):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), tree)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                    enc_len: int = 0, kv_heads: Optional[int] = None):
    """ShapeDtypeStruct view of init_caches — dry-run path, no allocation."""
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, dtype, enc_len, kv_heads))


def cache_axes(cfg: ModelConfig):
    """Logical sharding axes mirroring the init_caches structure."""
    axes: Dict[str, Any] = {}
    for i, st in enumerate(cfg.stages):
        sub: Dict[str, Any] = {}
        for j, spec in enumerate(st.block):
            entry: Dict[str, Any] = {}
            if spec.kind == "attn":
                entry["kv"] = {
                    "k": ("layers", "act_batch", "kv_heads", "act_cache", None),
                    "v": ("layers", "act_batch", "kv_heads", "act_cache", None),
                    "pos": ("layers", "act_batch", "act_cache"),
                }
            else:
                entry["ssm_cache"] = {
                    "ssm": ("layers", "act_batch", "ssm_heads", None, None),
                    "conv": ("layers", "act_batch", None, "ssm_inner"),
                }
            if spec.cross:
                entry["cross"] = {
                    "k": ("layers", "act_batch", "kv_heads", None, None),
                    "v": ("layers", "act_batch", "kv_heads", None, None),
                }
            sub[f"sub{j}"] = entry
        axes[f"stage{i}"] = sub
    return axes


# ------------------------------------------------------------------ loss ---

def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            ctx=None, impl: str = "xla", remat: bool = True,
            aux_weight: float = 0.01):
    """Next-token cross-entropy.  batch: tokens (B,T), labels (B,T) with -1
    for ignored positions, optional frontend/frames.

    The label log-prob is taken with a one-hot einsum, which stays sharded
    when the vocab axis is model-sharded (no logits all-gather).
    """
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"], ctx=ctx, impl=impl)
    logits, _, aux = forward(params, cfg, batch["tokens"],
                             frontend=batch.get("frontend"),
                             enc_out=enc_out, ctx=ctx, impl=impl, remat=remat)
    labels = batch["labels"]
    if cfg.num_frontend_tokens and batch.get("frontend") is not None:
        logits = logits[:, batch["frontend"].shape[1]:]
    valid = (labels >= 0)
    labels_c = jnp.clip(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)                      # (B, T)
    onehot = jax.nn.one_hot(labels_c, cfg.padded_vocab, dtype=logits.dtype)
    ll = jnp.einsum("btv,btv->bt", logits, onehot)
    ce = jnp.where(valid, logz - ll, 0.0)
    ntok = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(ce) / ntok
    total = loss + aux_weight * aux
    return total, {"ce": loss, "moe_aux": aux, "ntokens": ntok}
