"""AdamW with shard-friendly state and configurable moment dtype.

Optimizer state mirrors the parameter pytree (same shapes, same shardings →
ZeRO-1/3 falls out of the FSDP param sharding for free).  `moment_dtype`
trades memory for precision: the ≥100B configs (jamba) run bf16 moments to
fit the single-pod HBM budget (see DESIGN.md §6 memory policy); everything
else defaults to f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # dtype of the update arithmetic; bf16 for >=100B models halves the
    # optimizer's transient f32 working set (peak-memory critical)
    update_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def abstract_state(cfg: AdamWConfig, abstract_params: Any) -> AdamWState:
    """ShapeDtypeStruct view (dry-run)."""
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(z, abstract_params),
                      nu=jax.tree.map(z, abstract_params))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any,
           lr: Optional[jnp.ndarray] = None):
    """Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    if lr is None:
        lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    mdt = jnp.dtype(cfg.moment_dtype)
    udt = jnp.dtype(cfg.update_dtype)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = (1 - b1 ** c).astype(udt)
    bc2 = (1 - b2 ** c).astype(udt)

    def upd(g, m, v, p):
        g = g.astype(udt) * scale.astype(udt)
        mu = (b1 * m.astype(udt) + (1 - b1) * g)
        nu = (b2 * v.astype(udt) + (1 - b2) * jnp.square(g))
        step = (mu / bc1) * jax.lax.rsqrt(
            jnp.maximum(nu / bc2, jnp.asarray(cfg.eps ** 2, udt)))
        if cfg.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(udt)
        new_p = p.astype(udt) - lr.astype(udt) * step
        return new_p.astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(count, new_mu, new_nu), metrics
