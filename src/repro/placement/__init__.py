"""Replica-placement subsystem: hierarchy-aware chunk placement driving
locality on every layer.

`PlacementPolicy` (see `repro.placement.policy`) projects one placement
rule onto both substrates — a fixed-shape per-task replica sampling
distribution for the JAX simulator, and a deterministic host-side
placement map for the serving engine and data pipeline.  Built-ins
(`repro.placement.policies`): ``uniform`` (the pre-placement behavior,
bitwise-pinned), ``hdfs`` (rack-aware primary/same-rack/off-rack),
``spread`` (greedy max-distance anti-affinity), ``hot_aware``
(popularity-skewed replication factor with deterministic rebalance).
`placement_capacity` (`repro.placement.capacity`) computes the fluid
capacity a placement induces via a sampled-type LP.
"""

from repro.placement.policy import (  # noqa: F401
    PlacementConfig,
    PlacementLike,
    PlacementPolicy,
    available_placements,
    get_placement_cls,
    make_placement,
    placement_descriptions,
    register_placement,
)
from repro.placement.capacity import (  # noqa: F401
    placement_capacity,
    sample_placement_types,
)
