"""Fluid capacity under an arbitrary replica placement.

`locality.capacity_hot_rack` has a water-filling closed form because the
uniform placement confines every hot task's replicas to one rack.  A
placement policy breaks that structure (an `hdfs` hot chunk keeps one
replica off-rack; `spread` scatters all three), so the capacity region
must be computed from the *distribution of replica sets* the placement
induces: sample task types from the compiled placement sampler, collapse
them into type classes, and solve the fluid LP

    max Λ  s.t.  Σ_m x[t, m] = freq_t · Λ          (demand split)
                 Σ_t x[t, m] / r[t, m] ≤ 1          (server utilisation)

where ``r[t, m] = rates[tier of m w.r.t. type t]``.  The uniform
placement recovers `capacity_hot_rack` up to Monte-Carlo error on the
type frequencies (checked in tests/test_placement.py); the deltas
between placements are the §Placement capacity numbers in
EXPERIMENTS.md.

Needs scipy (the LP); callers that may run without it (CI smoke) should
pass ``strict=False`` and handle the ``None``.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Sequence, Union

import numpy as np

from repro.placement.policy import PlacementLike, make_placement

if TYPE_CHECKING:  # annotation-only: keeps this package import-light so
    from repro.core.locality import Rates, Topology  # core can import it


def sample_placement_types(topo: Topology, placement: PlacementLike,
                           p_hot: float, n_samples: int = 2000,
                           hot_rack: int = 0, seed: int = 0) -> np.ndarray:
    """(n_samples, NUM_REPLICAS) replica sets drawn from the placement's
    compiled simulator sampler under static knobs."""
    import jax
    import jax.numpy as jnp
    sampler = make_placement(placement).build_sampler(topo)
    types = sampler(jax.random.PRNGKey(seed), jnp.float32(p_hot),
                    jnp.int32(hot_rack), int(n_samples))
    return np.asarray(types)


def placement_capacity(topo: Topology, rates: Union[Rates, Sequence[float]],
                       p_hot: float, placement: PlacementLike,
                       n_samples: int = 2000, hot_rack: int = 0,
                       seed: int = 0, strict: bool = True
                       ) -> Optional[float]:
    """Monte-Carlo fluid capacity Λ* (tasks/slot) under `placement`.

    Returns None (instead of raising) when scipy is unavailable and
    ``strict=False`` — the CI smoke path.
    """
    try:
        import scipy.optimize as sopt
        import scipy.sparse as ssp
    except ImportError as e:
        if strict:
            raise ImportError(
                "placement_capacity solves a fluid LP and needs scipy, "
                "which is an *optional* dependency of repro.placement "
                "(everything else in the package runs without it).  "
                "Install scipy, or pass strict=False to get None instead."
            ) from e
        return None
    from repro.core.cluster import worker_tiers
    from repro.core.locality import Rates

    r = np.asarray(rates.values if isinstance(rates, Rates) else rates,
                   np.float64)
    if r.size != topo.num_tiers:
        raise ValueError(f"rates have {r.size} tiers but topology has "
                         f"{topo.num_tiers}")
    types = sample_placement_types(topo, placement, p_hot, n_samples,
                                   hot_rack, seed)
    uniq, counts = np.unique(types, axis=0, return_counts=True)
    freq = counts / counts.sum()
    t_count, m = uniq.shape[0], topo.num_servers
    # (T, M) service rate of each server for each type class
    rate_tm = np.stack([r[worker_tiers(topo, row.tolist())] for row in uniq])

    # variables: [Λ, x[0,0..M-1], x[1,:], ...] — maximize Λ
    nvar = 1 + t_count * m
    c = np.zeros(nvar)
    c[0] = -1.0
    # demand split: Σ_m x[t, m] - freq_t Λ = 0
    rows = np.repeat(np.arange(t_count), m + 1)
    cols = np.concatenate([np.concatenate(([0], 1 + t * m + np.arange(m)))
                           for t in range(t_count)])
    vals = np.concatenate([np.concatenate(([-freq[t]], np.ones(m)))
                           for t in range(t_count)])
    a_eq = ssp.csr_matrix((vals, (rows, cols)), shape=(t_count, nvar))
    # utilisation: Σ_t x[t, m] / r[t, m] <= 1
    rows = np.tile(np.arange(m), t_count)
    cols = 1 + np.arange(t_count * m)
    vals = (1.0 / rate_tm).ravel()
    a_ub = ssp.csr_matrix((vals, (rows, cols)), shape=(m, nvar))
    res = sopt.linprog(c, A_ub=a_ub, b_ub=np.ones(m), A_eq=a_eq,
                       b_eq=np.zeros(t_count), bounds=(0, None),
                       method="highs")
    if not res.success:
        raise RuntimeError(f"placement fluid LP failed: {res.message}")
    return float(-res.fun)
