"""Built-in replica-placement policies: uniform (the pre-placement
behavior, bitwise), HDFS-style rack-aware, max-distance spread, and
popularity-aware variable replication.

Each policy is one class with the two projections of
`repro.placement.policy.PlacementPolicy`:

  * the **simulator sampler** draws a task's replica set per arrival with
    fixed shapes (Gumbel-argmax picks over masked logits, Gumbel-top-k for
    without-replacement pools), consuming the traced per-slot scenario
    knobs (``p_hot``, ``hot_rack``, ``rack_weights``) exactly like the
    classic sampler — so hot-rack drift (`hot_shift`) moves the *placement*
    too;
  * the **host rule** derives a deterministic replica list per chunk from
    the same rendezvous (HRW) ranking the pipeline always used — the
    policies differ only in how they walk that ranking against the
    `Topology` ancestor table, so any two hosts agree on every chunk's
    placement without coordination.

The hierarchy enters K-generically through `Topology.ancestors`: "rack"
below means level-0 groups, and `spread` walks levels from the coarsest
down, so the same four policies run unchanged on flat (K=2), rack (K=3)
and pod (K=4+) topologies, heterogeneous group sizes included.
"""

from __future__ import annotations

import hashlib
from typing import List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import locality as loc
from repro.core.locality import NUM_REPLICAS, Topology
from repro.placement.policy import PlacementPolicy, register_placement

# ---------------------------------------------------------------------------
# Shared primitives
# ---------------------------------------------------------------------------


def hrw_ranking(chunk_id: int, num_hosts: int, seed: int) -> List[int]:
    """Rendezvous (HRW) ranking of all hosts for one chunk: every placement
    policy walks this ranking, so placement stays stable under fleet
    resizes (only chunks whose top ranks change move).  The first
    `replication` entries, sorted, are exactly the classic
    `chunk_replicas` assignment."""
    scores = []
    for h in range(num_hosts):
        digest = hashlib.blake2s(
            f"{seed}:{chunk_id}:{h}".encode(), digest_size=8).digest()
        scores.append((int.from_bytes(digest, "big"), h))
    scores.sort(reverse=True)
    return [h for _, h in scores]


def chunk_replicas(chunk_id: int, num_hosts: int, replication: int,
                   seed: int) -> List[int]:
    """Classic uniform rendezvous placement (the pre-placement behavior,
    kept bitwise: `data.pipeline.chunk_replicas` re-exports this)."""
    return sorted(hrw_ranking(chunk_id, num_hosts, seed)[:replication])


def _hot_split(key: jax.Array, p_hot, hot_rack, batch: int,
               rack_weights: Optional[jnp.ndarray]):
    """Shared hot-task assignment: returns (hot (B,) bool, hot_racks (B,)
    int32, key for the placement draws).  Mirrors the key discipline of
    `locality.sample_task_types_at`: the weighted path splits differently
    and only activates when a segment opts into rack weights."""
    if rack_weights is None:
        k_hot, k_rest = jax.random.split(key)
        hot_racks = jnp.broadcast_to(jnp.asarray(hot_rack, jnp.int32),
                                     (batch,))
    else:
        k_hot, k_rack, k_rest = jax.random.split(key, 3)
        logw = jnp.log(jnp.asarray(rack_weights, jnp.float32))
        hot_racks = jax.random.categorical(k_rack, logw, shape=(batch,)
                                           ).astype(jnp.int32)
    hot = jax.random.bernoulli(k_hot, p_hot, (batch,))
    return hot, hot_racks, k_rest


def _pick(key: jax.Array, logits: jnp.ndarray) -> jnp.ndarray:
    """(B,) Gumbel-argmax draw per row of (B, M) logits (uniform over the
    0-logit support when the support is masked with -inf)."""
    g = jax.random.gumbel(key, logits.shape)
    return jnp.argmax(logits + g, axis=1).astype(jnp.int32)


def _pick_max(key: jax.Array, score: jnp.ndarray) -> jnp.ndarray:
    """(B,) uniform draw among each row's exact maxima of (B, M) scores."""
    is_max = score == jnp.max(score, axis=1, keepdims=True)
    g = jax.random.gumbel(key, score.shape)
    return jnp.argmax(jnp.where(is_max, g, -jnp.inf), axis=1).astype(jnp.int32)


def _tiers_wrt(chosen: jnp.ndarray, anc: jnp.ndarray) -> jnp.ndarray:
    """(B, M) tier of every server w.r.t. a *partial* replica set
    ``chosen`` (B, i): 0 on chosen servers, else 1 + deepest level shared
    with any of them, else K-1 — the batched generalization of
    `locality.server_tiers` the greedy max-distance pick scores against."""
    d, m = anc.shape
    b = chosen.shape[0]
    tier = jnp.full((b, m), d + 1, jnp.int32)
    for lvl in range(d - 1, -1, -1):
        row = anc[lvl]
        share = jnp.any(row[None, :, None] == row[chosen][:, None, :], axis=-1)
        tier = jnp.where(share, lvl + 1, tier)
    sid = jnp.arange(m, dtype=chosen.dtype)
    local = jnp.any(sid[None, :, None] == chosen[:, None, :], axis=-1)
    return jnp.where(local, 0, tier)


def _primary_logits(hot: jnp.ndarray, in_hot_rack: jnp.ndarray) -> jnp.ndarray:
    """(B, M) logits of the primary replica: uniform over the hot rack for
    hot tasks, uniform over the fleet otherwise (the same mixture the
    classic sampler applies to all three replicas at once)."""
    return jnp.where(hot[:, None],
                     jnp.where(in_hot_rack, 0.0, -jnp.inf),
                     jnp.zeros_like(in_hot_rack, jnp.float32))


# ---------------------------------------------------------------------------
# uniform — the pre-placement behavior, bitwise
# ---------------------------------------------------------------------------


@register_placement
class UniformPlacement(PlacementPolicy):
    """I.i.d.-uniform replicas (the pre-placement default, bitwise-pinned):
    the simulator draws all replicas from the hot-rack mixture at once and
    the host side takes the top rendezvous ranks."""

    name = "uniform"

    def build_sampler(self, topo: Topology):
        rack_of = jnp.asarray(topo.rack_of, jnp.int32)

        def sample(key, p_hot, hot_rack, batch, rack_weights=None):
            # Verbatim delegation: same ops, same key splits -> the draws
            # are bitwise identical to the pre-placement sampler.
            return loc.sample_task_types_at(key, rack_of, p_hot, hot_rack,
                                            batch, rack_weights)
        return sample

    def replicas(self, spec: Topology, chunk_id: int, replication: int,
                 seed: int) -> List[int]:
        return chunk_replicas(chunk_id, spec.num_servers, replication, seed)


# ---------------------------------------------------------------------------
# hdfs — primary + same-rack second + off-rack third
# ---------------------------------------------------------------------------


@register_placement
class HdfsPlacement(PlacementPolicy):
    """HDFS-style rack-aware placement: primary, a second replica in the
    primary's rack, and a third off-rack (fault-domain isolation), rack
    meaning the level-0 group of the `Topology` ancestor table at any K.

    A hot task's primary lands in the hot rack, so — unlike `uniform` —
    one replica of every hot chunk escapes the hot rack: hot traffic is no
    longer confined to one rack's servers, which trades peak locality for
    capacity headroom under skew.  On a topology that cannot express the
    rule (a single rack, or a rack of one server) the sampler degrades to
    `uniform`.  Host side: the primary is the chunk's top rendezvous rank;
    the second/third are the top ranks inside / outside its rack;
    replication factors beyond 3 follow the remaining ranking.
    """

    name = "hdfs"

    def build_sampler(self, topo: Topology):
        if topo.num_racks < 2 or topo.min_rack_size < 2:
            return UniformPlacement().build_sampler(topo)
        rack_of = jnp.asarray(topo.rack_of, jnp.int32)
        m = topo.num_servers

        def sample(key, p_hot, hot_rack, batch, rack_weights=None):
            hot, hot_racks, k = _hot_split(key, p_hot, hot_rack, batch,
                                           rack_weights)
            k1, k2, k3 = jax.random.split(k, 3)
            in_hot_rack = rack_of[None, :] == hot_racks[:, None]
            primary = _pick(k1, _primary_logits(hot, in_hot_rack))
            same = rack_of[None, :] == rack_of[primary][:, None]
            not_prim = jnp.arange(m)[None, :] != primary[:, None]
            second = _pick(k2, jnp.where(same & not_prim, 0.0, -jnp.inf))
            third = _pick(k3, jnp.where(~same, 0.0, -jnp.inf))
            types = jnp.stack([primary, second, third], axis=1)
            return jnp.sort(types, axis=1).astype(jnp.int32)
        return sample

    def replicas(self, spec: Topology, chunk_id: int, replication: int,
                 seed: int) -> List[int]:
        ranking = hrw_ranking(chunk_id, spec.num_servers, seed)
        if spec.num_racks < 2 or spec.min_rack_size < 2:
            return sorted(ranking[:replication])
        rack = np.asarray(spec.rack_of)
        primary = ranking[0]
        chosen = [primary]
        second = next((h for h in ranking[1:] if rack[h] == rack[primary]),
                      None)
        third = next((h for h in ranking[1:] if rack[h] != rack[primary]),
                     None)
        for h in (second, third):
            if h is not None and len(chosen) < replication:
                chosen.append(h)
        for h in ranking[1:]:  # replication > 3 follows the ranking
            if len(chosen) >= replication:
                break
            if h not in chosen:
                chosen.append(h)
        return sorted(chosen)


# ---------------------------------------------------------------------------
# spread — greedy max-distance anti-affinity
# ---------------------------------------------------------------------------


@register_placement
class SpreadPlacement(PlacementPolicy):
    """Max-distance anti-affinity: after the primary, each replica lands
    uniformly among the servers *farthest* (highest locality tier w.r.t.
    the partial replica set) from the replicas placed so far.

    On the flat-rack topology the three replicas occupy three distinct
    racks; on a pod topology the second crosses pods and the third takes
    the deepest level that still has room (off-rack in the other pod when
    only two pods exist) — the K-generic reading of "anti-affinity across
    the deepest level", with no special-casing: at K=2 it reduces to
    distinct uniform servers.  Host side: walk the chunk's rendezvous
    ranking greedily, accepting each host iff it maximizes the tier
    w.r.t. the hosts already chosen.
    """

    name = "spread"

    def build_sampler(self, topo: Topology):
        rack_of = jnp.asarray(topo.rack_of, jnp.int32)
        anc = jnp.asarray(topo.ancestors, jnp.int32)

        def sample(key, p_hot, hot_rack, batch, rack_weights=None):
            hot, hot_racks, k = _hot_split(key, p_hot, hot_rack, batch,
                                           rack_weights)
            keys = jax.random.split(k, NUM_REPLICAS)
            in_hot_rack = rack_of[None, :] == hot_racks[:, None]
            chosen = _pick(keys[0], _primary_logits(hot, in_hot_rack))[:, None]
            for i in range(1, NUM_REPLICAS):
                tier = _tiers_wrt(chosen, anc)
                nxt = _pick_max(keys[i], tier)
                chosen = jnp.concatenate([chosen, nxt[:, None]], axis=1)
            return jnp.sort(chosen, axis=1).astype(jnp.int32)
        return sample

    def replicas(self, spec: Topology, chunk_id: int, replication: int,
                 seed: int) -> List[int]:
        from repro.core.cluster import tier_of
        ranking = hrw_ranking(chunk_id, spec.num_servers, seed)
        chosen = [ranking[0]]
        while len(chosen) < replication:
            best = max(ranking, key=lambda h: (-1 if h in chosen
                                               else tier_of(spec, chosen, h),
                                               -ranking.index(h)))
            if best in chosen:
                break
            chosen.append(best)
        for h in ranking:  # degenerate fleets: fill by rank
            if len(chosen) >= replication:
                break
            if h not in chosen:
                chosen.append(h)
        return sorted(chosen)


# ---------------------------------------------------------------------------
# hot_aware — popularity-skewed replication factor + wider spread
# ---------------------------------------------------------------------------


@register_placement
class HotAwarePlacement(PlacementPolicy):
    """Popularity-aware placement: hot chunks carry a higher replication
    factor ``r_hot`` whose extra replicas are rebalanced off the home
    rack, so a hot task's replica set occasionally escapes the hot rack.

    Simulator projection: a hot chunk keeps `NUM_REPLICAS` home replicas
    in the hot rack plus ``r_hot - NUM_REPLICAS`` rebalanced ones spread
    uniformly over the other racks; a task's type is `NUM_REPLICAS`
    distinct replicas drawn without replacement from that pool (Gumbel
    top-k over the induced per-server weights) — fixed shapes, so the
    policies and both kernels consume the types unchanged.  Cold tasks
    stay uniform.  Host projection: hot chunks' extra replicas walk the
    rendezvous ranking greedily into racks the chunk does not cover yet,
    padded to ``r_hot`` in the placement map via the max-R + mask
    convention.  Popularity starts from a deterministic hash prior
    (`hot_frac` of chunks) and `rebalance()` re-derives the hot set from
    the read counts observed via `note_read` — the deterministic
    rebalance step drift scenarios exercise.
    """

    name = "hot_aware"

    def __init__(self, r_hot: int = 6, hot_frac: float = 0.125):
        if r_hot < NUM_REPLICAS:
            raise ValueError(f"r_hot must be >= {NUM_REPLICAS}, got {r_hot}")
        if not 0.0 < hot_frac <= 1.0:
            raise ValueError(f"hot_frac must be in (0, 1], got {hot_frac}")
        self.r_hot = int(r_hot)
        self.hot_frac = float(hot_frac)
        self._counts: dict = {}
        self._hot: Optional[Set[int]] = None  # None -> hash prior

    # -- simulator ----------------------------------------------------------
    def build_sampler(self, topo: Topology):
        rack_of = jnp.asarray(topo.rack_of, jnp.int32)
        m = topo.num_servers
        extra = float(self.r_hot - NUM_REPLICAS)

        def sample(key, p_hot, hot_rack, batch, rack_weights=None):
            hot, hot_racks, k = _hot_split(key, p_hot, hot_rack, batch,
                                           rack_weights)
            in_hot_rack = rack_of[None, :] == hot_racks[:, None]
            n_hot = jnp.sum(in_hot_rack, axis=1, keepdims=True)  # (B, 1)
            n_cold = jnp.maximum(m - n_hot, 1)
            # per-server replica mass: NUM_REPLICAS home replicas share the
            # hot rack, the rebalanced extras share everything else
            w = jnp.where(in_hot_rack, NUM_REPLICAS / n_hot,
                          jnp.where(m - n_hot > 0, extra / n_cold, 0.0))
            logits = jnp.where(hot[:, None], jnp.log(w),
                               jnp.zeros((1, m)))
            gumbel = jax.random.gumbel(k, (batch, m))
            _, idx = jax.lax.top_k(logits + gumbel, NUM_REPLICAS)
            return jnp.sort(idx, axis=1).astype(jnp.int32)
        return sample

    # -- host ---------------------------------------------------------------
    def _is_hot(self, chunk_id: int, seed: int) -> bool:
        if self._hot is not None:
            return chunk_id in self._hot
        digest = hashlib.blake2s(f"hot:{seed}:{chunk_id}".encode(),
                                 digest_size=4).digest()
        return int.from_bytes(digest, "big") % 10_000 < self.hot_frac * 10_000

    def replicas(self, spec: Topology, chunk_id: int, replication: int,
                 seed: int) -> List[int]:
        base = chunk_replicas(chunk_id, spec.num_servers, replication, seed)
        if not self._is_hot(chunk_id, seed):
            return base
        rack = np.asarray(spec.rack_of)
        target = max(self.r_hot, replication)
        chosen = list(base)
        for h in hrw_ranking(chunk_id, spec.num_servers, seed):
            if len(chosen) >= target:
                break
            if h not in chosen and rack[h] not in {rack[c] for c in chosen}:
                chosen.append(h)  # rebalanced extras land in uncovered racks
        for h in hrw_ranking(chunk_id, spec.num_servers, seed):
            if len(chosen) >= target:
                break
            if h not in chosen:  # racks exhausted: fill by rank
                chosen.append(h)
        return sorted(chosen)

    def max_replication(self, replication: int) -> int:
        return max(self.r_hot, replication)

    def note_read(self, chunk_id: int) -> None:
        # Coerce to a plain int: callers hand over numpy integers (batch
        # indices, prefix ids), and a np.int64 key would poison
        # state_dict() — json.dumps of the checkpoint manifest crashes on
        # numpy scalars, killing the trainer's save mid-run.
        chunk_id = int(chunk_id)
        self._counts[chunk_id] = self._counts.get(chunk_id, 0) + 1

    def state_dict(self):
        # parallel lists keep the chunk ids intact through JSON (dict keys
        # would come back as strings); values re-coerced to plain ints so
        # the dict stays json.dumps-safe whatever fed note_read
        keys = sorted(self._counts)
        return {"count_ids": [int(c) for c in keys],
                "counts": [int(self._counts[c]) for c in keys],
                "hot": None if self._hot is None
                else [int(c) for c in sorted(self._hot)]}

    def load_state_dict(self, s) -> None:
        self._counts = {int(c): int(n)
                        for c, n in zip(s["count_ids"], s["counts"])}
        self._hot = None if s["hot"] is None else {int(c) for c in s["hot"]}

    def rebalance(self) -> int:
        """Recompute the hot set from the observed read counts: the top
        ``hot_frac`` fraction of *observed* chunks (ties broken toward the
        smaller id) become hot.  Deterministic in the count history."""
        if not self._counts:
            return 0
        n_hot = max(1, int(round(self.hot_frac * len(self._counts))))
        ranked = sorted(self._counts, key=lambda c: (-self._counts[c], c))
        new_hot = set(ranked[:n_hot])
        old = self._hot
        self._hot = new_hot
        if old is None:
            return len(new_hot)
        return len(new_hot.symmetric_difference(old))
