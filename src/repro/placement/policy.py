"""Replica-placement subsystem: where chunk replicas live on the hierarchy.

The paper's whole local / rack-local / remote hierarchy exists because
each chunk is replicated on a handful of servers — yet *which* servers
was the one knob the repo still hard-coded: the simulator sampled replica
sets i.i.d.-uniform (`locality.sample_task_types_at`) and the host fleet
rendezvous-hashed uniformly (`data.pipeline.chunk_replicas`).  Hadoop's
own rack-aware placement and replication-factor tuning are known to
dominate locality outcomes, so placement x scheduling is its own axis of
the comparison.

A `PlacementPolicy` projects one placement rule onto both execution
substrates, mirroring the two-sided `SlotPolicy`/`Router` contract of
`core/policy.py`:

  * **JAX simulator** — `build_sampler(topo)` compiles the policy into a
    per-task replica *sampling distribution*: a pure function
    ``sample_types(key, p_hot, hot_rack, batch, rack_weights) ->
    (batch, NUM_REPLICAS) int32`` with fixed shapes, safe inside
    `lax.scan`/`vmap`, consuming the same traced per-slot scenario knobs
    as the classic sampler.  The resulting ``task_locals`` feed every
    `SlotPolicy` and both Pallas kernels unchanged.
  * **host fleet** — `replicas(spec, chunk_id, replication, seed)`
    deterministically places one chunk on the serving-engine / data-
    pipeline fleet (replacing direct `chunk_replicas` calls), and
    `placement_map(spec, num_chunks, replication, seed)` materializes
    the whole catalogue as a padded ``(C, R_max)`` id array plus a
    ``(C, R_max)`` bool mask — the same max-shape + mask convention the
    kernels use for variable-size batches, here covering variable
    replication factors (`hot_aware`).

`@register_placement` mirrors `@register_policy`: registering a class
makes it instantly selectable by name from `simulate`/`sweep`/
`run_study`/`placement_study`, the serving engine, the data pipeline,
the benches and the examples.  The ``"uniform"`` policy reproduces the
pre-placement behavior **bitwise** on both substrates (pinned by
tests/test_placement.py), so placement is opt-in with a zero-cost
default.
"""

from __future__ import annotations

import abc
import dataclasses
import importlib
from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Mapping,
                    Tuple, Type, Union)

import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # annotation-only: `repro.core` imports this package
    from repro.core.locality import Topology  # (via the simulator seam)

# The compiled simulator projection: sample_types(key, p_hot, hot_rack,
# batch, rack_weights) -> (batch, NUM_REPLICAS) int32, sorted per row.
TypeSampler = Callable[..., jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    """Name + per-policy constructor options, e.g.
    ``PlacementConfig("hot_aware", {"r_hot": 6})`` — the placement
    analogue of `PolicyConfig`."""

    name: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


PlacementLike = Union[str, PlacementConfig, "PlacementPolicy", None]


class PlacementPolicy(abc.ABC):
    """One replica-placement rule, projected onto both substrates.

    Implementations are stateless w.r.t. the simulator (the compiled
    sampler is a pure function of the topology) but may carry host-side
    popularity state for deterministic rebalancing (`hot_aware`).
    """

    name: str = ""

    # -- JAX simulator projection ------------------------------------------
    @abc.abstractmethod
    def build_sampler(self, topo: Topology) -> TypeSampler:
        """Compile this placement against `topo` into a per-task replica
        sampling distribution (see module docstring for the signature).
        `p_hot`, `hot_rack` and `rack_weights` may be traced per-slot
        scenario knobs; shapes must be fixed."""

    # -- host projection ----------------------------------------------------
    @abc.abstractmethod
    def replicas(self, spec: Topology, chunk_id: int, replication: int,
                 seed: int) -> List[int]:
        """Sorted host ids holding `chunk_id` (length >= `replication` for
        policies that widen popular chunks; deterministic in all args)."""

    def max_replication(self, replication: int) -> int:
        """Upper bound over chunks — the R_max the placement map pads to."""
        return replication

    def placement_map(self, spec: Topology, num_chunks: int,
                      replication: int, seed: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize the whole catalogue: ``(ids, mask)`` with ids
        ``(C, R_max) int32`` (pad slots hold the row's first replica so
        every entry is a valid host id) and mask ``(C, R_max) bool``."""
        r_max = self.max_replication(replication)
        ids = np.zeros((num_chunks, r_max), np.int32)
        mask = np.zeros((num_chunks, r_max), bool)
        for c in range(num_chunks):
            locs = self.replicas(spec, c, replication, seed)
            ids[c, :len(locs)] = locs
            ids[c, len(locs):] = locs[0]
            mask[c, :len(locs)] = True
        return ids, mask

    # -- popularity feedback (optional) -------------------------------------
    def note_read(self, chunk_id: int) -> None:
        """Popularity feedback from the host consumers (no-op by default)."""

    def rebalance(self) -> int:
        """Deterministically re-derive any popularity-driven placement from
        the counts observed so far; returns the number of chunks whose
        placement changed (0 for static policies)."""
        return 0

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-safe popularity state ({} for stateless policies) — part of
        the data pipeline's checkpoint, so a restored pipeline resumes
        with the same placement a continuous run would have."""
        return {}

    def load_state_dict(self, s: Mapping[str, Any]) -> None:
        if s:
            raise ValueError(f"{self.name!r} placement carries no state, "
                             f"got {dict(s)}")


# ---------------------------------------------------------------------------
# Registry (mirrors core/policy.py)
# ---------------------------------------------------------------------------

_PLACEMENTS: Dict[str, Type[PlacementPolicy]] = {}
_BUILTIN_MODULES = ("repro.placement.policies",)
_builtins_loaded = False


def _load_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    _builtins_loaded = True


def register_placement(cls: Type[PlacementPolicy]) -> Type[PlacementPolicy]:
    """Class decorator: add a PlacementPolicy under `cls.name`."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"placement class {cls.__name__} has no `name`")
    if name in _PLACEMENTS:
        raise ValueError(f"duplicate placement registration: {name!r}")
    _PLACEMENTS[name] = cls
    return cls


def available_placements() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_PLACEMENTS))


def placement_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered placement,
    from the first sentence of each class docstring — the self-describing
    registry surface behind ``benchmarks/run.py --help``."""
    from repro.utils.doc import first_doc_line
    _load_builtins()
    return {n: first_doc_line(c) for n, c in sorted(_PLACEMENTS.items())}


def get_placement_cls(name: str) -> Type[PlacementPolicy]:
    _load_builtins()
    try:
        return _PLACEMENTS[name]
    except KeyError:
        raise ValueError(f"unknown placement {name!r}; "
                         f"registered: {available_placements()}") from None


def make_placement(spec: PlacementLike, **options) -> PlacementPolicy:
    """Resolve a name / PlacementConfig / instance; None -> "uniform"."""
    if spec is None:
        spec = "uniform"
    if isinstance(spec, PlacementPolicy):
        if options:
            raise ValueError("options only apply when building by name")
        return spec
    if isinstance(spec, PlacementConfig):
        if options:
            raise ValueError("options only apply when building by name")
        spec, options = spec.name, dict(spec.options)
    return get_placement_cls(spec)(**options)
