"""Replication lifecycle: migration, adaptive replication, and
failure/recovery as first-class actions on both substrates.

See `repro.replication.lifecycle` for the controller contract and the
`MigrationModel`, `repro.replication.controllers` for the built-ins
(``fixed`` / ``repair`` / ``popularity``), `repro.replication.simproj`
for the fixed-shape `lax.scan` machinery, and `repro.replication.host`
for the engine / pipeline mirror.
"""

from repro.replication.lifecycle import (  # noqa: F401
    MigrationModel,
    ReplicationConfig,
    ReplicationController,
    ReplicationLike,
    available_replications,
    get_replication_cls,
    make_replication,
    register_replication,
    replication_descriptions,
)
from repro.replication.host import HostReplication  # noqa: F401
