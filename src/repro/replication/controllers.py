"""Built-in replication controllers, registered by name.

Each controller reduces to one function — the target replica count per
chunk — evaluated on both substrates from the same inputs (liveness and
read popularity).  The lifecycle machinery (wipe / repair / drop /
migrate under the bandwidth cap) is shared; see
`repro.replication.simproj` and `repro.replication.host`.
"""

from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp
import numpy as np

from repro.replication.lifecycle import (ReplicationController,
                                         register_replication)


@register_replication
class FixedReplication(ReplicationController):
    """The paper's static default: every chunk keeps whatever replicas the
    placement policy gave it — never migrates, widens, or repairs.  With
    no failure scenario this is bitwise-identical to the pre-replication
    code path (the lifecycle machinery is skipped entirely); under
    ``server_loss`` / ``rack_loss`` it only *observes* the damage, which
    is exactly what makes it the availability baseline."""

    name = "fixed"
    is_static = True

    def sim_targets(self, pop, live, base_tgt):
        # Target == live: deficits and surpluses are both zero by
        # construction, so the machinery never starts a move or drops a
        # replica — failures just reduce `live` (and the target with it).
        return live

    def host_targets(self, counts: Mapping[int, int], live: np.ndarray,
                     base_tgt: np.ndarray) -> np.ndarray:
        return live.astype(np.int64)


@register_replication
class RepairReplication(ReplicationController):
    """Failure-driven re-replication: after a server or rack dies, rebuild
    every chunk back to its initial replication factor from the surviving
    copies, paying migration bandwidth through the repair lanes.  The
    ``lanes`` cap is the repair-bandwidth budget — a storm after a rack
    loss queues behind it and contends with foreground traffic instead of
    saturating the fabric (HDFS-style re-replication)."""

    name = "repair"

    def sim_targets(self, pop, live, base_tgt):
        return base_tgt

    def host_targets(self, counts: Mapping[int, int], live: np.ndarray,
                     base_tgt: np.ndarray) -> np.ndarray:
        return base_tgt.astype(np.int64)


@register_replication
class PopularityReplication(ReplicationController):
    """Adaptive replication factor: chunks in the top ``hot_frac`` of
    (decayed) read popularity hold ``r_hot`` replicas, the rest ``r_cold``
    — extra copies of hot data buy locality and failure headroom where
    reads actually land, at the cost of migration bandwidth when
    popularity drifts.  Subsumes repair: a dead replica of any chunk is
    rebuilt toward the popularity-driven target."""

    name = "popularity"

    def __init__(self, r_hot: int = 5, r_cold: int = 3,
                 hot_frac: float = 0.125, decay: float = 0.02, **common):
        super().__init__(**common)
        if r_cold < 1 or r_hot < r_cold:
            raise ValueError(f"need 1 <= r_cold <= r_hot, "
                             f"got r_cold={r_cold}, r_hot={r_hot}")
        if not 0.0 < hot_frac < 1.0:
            raise ValueError(f"hot_frac must be in (0, 1), got {hot_frac}")
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.r_hot = int(r_hot)
        self.r_cold = int(r_cold)
        self.hot_frac = float(hot_frac)
        self.decay = float(decay)

    def max_target(self, base: int) -> int:
        return max(int(base), self.r_hot)

    def sim_targets(self, pop, live, base_tgt):
        thr = jnp.quantile(pop, 1.0 - self.hot_frac)
        hot = (pop >= thr) & (pop > 0.0)
        return jnp.where(hot, self.r_hot, self.r_cold).astype(live.dtype)

    def host_targets(self, counts: Mapping[int, int], live: np.ndarray,
                     base_tgt: np.ndarray) -> np.ndarray:
        tgt = np.full(live.shape[0], self.r_cold, np.int64)
        if counts:
            n_hot = max(1, round(self.hot_frac * len(counts)))
            # ties toward the smaller chunk id, mirroring hot_aware
            ranked = sorted(counts, key=lambda c: (-counts[c], c))
            for c in ranked[:n_hot]:
                if 0 <= c < tgt.shape[0]:
                    tgt[c] = self.r_hot
        return tgt
