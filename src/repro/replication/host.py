"""Host-side replication lifecycle for the serving engine and the data
pipeline.

The numpy mirror of `repro.replication.simproj`: the same chunk
catalogue (padded ids + liveness mask from the placement policy), the
same wipe / commit / drop / start sequence, but on the hosts' continuous
clock — a move started at time ``t`` between endpoints at pair-tier
``k`` commits at ``t + ceil(chunk_size / rate[k])`` (`MigrationModel`),
and until then both endpoints serve foreground work at the contention
multiplier.  Consumers call `observe(t, alive_mask)` once per step with
the scenario playback's liveness mask, then read placements through
`replicas_for` instead of the static `PlacementPolicy.replicas`.

State round-trips through `state_dict()` / `load_state_dict()` as plain
JSON types, riding the data pipeline's checkpoint exactly like the
placement popularity state does.
"""

from __future__ import annotations

from typing import Any, Dict, List, Mapping

import numpy as np

from repro.core.cluster import tier_of
from repro.telemetry import CLOCK_UNIT_US


class HostReplication:
    """Replication lifecycle on the host fleet (engine / pipeline)."""

    def __init__(self, ctrl, spec, placement, num_chunks: int,
                 replication: int, seed: int, tier_rates):
        base = min(int(replication), spec.num_workers)
        ids, mask = placement.placement_map(spec, num_chunks, base, seed)
        r_max = max(ids.shape[1], ctrl.max_target(base))
        if r_max > ids.shape[1]:
            pad = r_max - ids.shape[1]
            ids = np.concatenate(
                [ids, np.repeat(ids[:, :1], pad, axis=1)], axis=1)
            mask = np.concatenate(
                [mask, np.zeros((mask.shape[0], pad), bool)], axis=1)
        self.ctrl = ctrl
        self.spec = spec
        self.ids = ids.astype(np.int64)
        self.mask = mask.copy()
        self.base_tgt = mask.sum(1).astype(np.int64)
        self.cost = ctrl.migration.cost_table(tier_rates)
        self.counts: Dict[int, int] = {}
        self.lanes: List[Dict[str, Any]] = []  # chunk/slot/src/dst/done_t
        self.ever_lost: set = set()
        self.moves = 0
        self.dropped = 0
        self.lost_reads = 0
        self._alive = np.ones(spec.num_workers, bool)
        self._busy: set = set()
        # Structured event tracing: consumers (engine / pipeline) install
        # their EventRecorder here; None -> no events emitted.
        self.tracer = None

    @property
    def num_chunks(self) -> int:
        return self.ids.shape[0]

    # -- lifecycle -----------------------------------------------------------
    def observe(self, t: float, alive) -> None:
        """Advance the lifecycle to time `t` under liveness mask `alive`:
        wipe replicas on dead hosts, kill/commit in-flight moves, drop
        surpluses, start deficit repairs within the lane cap."""
        alive = np.asarray(alive, bool)
        if self.tracer is not None:
            ts = float(t) * CLOCK_UNIT_US
            for h in np.nonzero(self._alive & ~alive)[0]:
                self.tracer.instant("server_down", cat="failure", ts_us=ts,
                                    tid=int(h))
            for h in np.nonzero(~self._alive & alive)[0]:
                self.tracer.instant("server_up", cat="failure", ts_us=ts,
                                    tid=int(h))
        self._alive = alive
        self.mask &= alive[self.ids]
        survivors = []
        for ln in self.lanes:
            if not (alive[ln["src"]] and alive[ln["dst"]]):
                continue  # killed with its endpoint
            if ln["done_t"] <= t:
                self.ids[ln["chunk"], ln["slot"]] = ln["dst"]
                self.mask[ln["chunk"], ln["slot"]] = True
                self.moves += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "repair_commit", cat="replication",
                        ts_us=float(t) * CLOCK_UNIT_US, tid=ln["dst"],
                        chunk=ln["chunk"], src=ln["src"])
            else:
                survivors.append(ln)
        self.lanes = survivors

        live = self.mask.sum(1)
        self.ever_lost.update(int(c) for c in np.nonzero(live == 0)[0])
        tgt = np.clip(self.ctrl.host_targets(self.counts, live,
                                             self.base_tgt),
                      1, self.ids.shape[1])
        for c in np.nonzero(live > tgt)[0]:  # free drops, keep first tgt
            cols = np.nonzero(self.mask[c])[0]
            self.mask[c, cols[int(tgt[c]):]] = False
            self.dropped += len(cols) - int(tgt[c])
        live = self.mask.sum(1)

        infl = np.zeros(self.num_chunks, np.int64)
        for ln in self.lanes:
            infl[ln["chunk"]] += 1
        deficit = np.clip(tgt - live - infl, 0, None)
        deficit[live == 0] = 0  # no live source to copy from
        held = np.bincount(self.ids[self.mask],
                           minlength=self.spec.num_workers).astype(float)
        started = 0
        for c in sorted(np.nonzero(deficit > 0)[0],
                        key=lambda c: (-int(deficit[c]), int(c))):
            for _ in range(int(deficit[c])):
                if len(self.lanes) >= self.ctrl.lanes \
                        or started >= self.ctrl.moves_per_slot:
                    self._rebuild_busy()
                    return
                row = self.ids[c]
                src = int(row[self.mask[c]].min())
                excluded = set(int(h) for h in row[self.mask[c]])
                excluded |= {ln["dst"] for ln in self.lanes
                             if ln["chunk"] == c}
                cand = [h for h in range(self.spec.num_workers)
                        if alive[h] and h not in excluded]
                if not cand:
                    break
                dst = min(cand, key=lambda h: (held[h], h))
                taken = {ln["slot"] for ln in self.lanes
                         if ln["chunk"] == c}
                slot = next(s for s in range(self.ids.shape[1])
                            if not self.mask[c, s] and s not in taken)
                tier = tier_of(self.spec, [src], dst)
                self.lanes.append({"chunk": int(c), "slot": int(slot),
                                   "src": src, "dst": int(dst),
                                   "done_t": float(t)
                                   + float(self.cost[tier])})
                if self.tracer is not None:
                    self.tracer.instant(
                        "repair_start", cat="replication",
                        ts_us=float(t) * CLOCK_UNIT_US, tid=int(dst),
                        chunk=int(c), src=src,
                        eta=self.lanes[-1]["done_t"])
                held[dst] += 1.0
                started += 1
        self._rebuild_busy()

    def _rebuild_busy(self) -> None:
        self._busy = {ln["src"] for ln in self.lanes} \
            | {ln["dst"] for ln in self.lanes}

    # -- consumer surface ----------------------------------------------------
    def replicas_for(self, chunk_id: int) -> List[int]:
        """Sorted live hosts of `chunk_id` — empty when every replica is
        gone (the consumer falls back to a cold-store refetch and the
        read is counted as lost)."""
        c = int(chunk_id) % self.num_chunks
        locs = sorted(int(h) for h in self.ids[c][self.mask[c]])
        if not locs:
            self.lost_reads += 1
        return locs

    def note_read(self, chunk_id: int) -> None:
        c = int(chunk_id) % self.num_chunks
        self.counts[c] = self.counts.get(c, 0) + 1

    def contention_mult(self, host: int) -> float:
        """Foreground rate multiplier on `host` (migration contention)."""
        return self.ctrl.migration.contention if host in self._busy else 1.0

    def is_alive(self, host: int) -> bool:
        return bool(self._alive[host])

    # -- metrics -------------------------------------------------------------
    def availability(self) -> float:
        """Fraction of chunks with >= 1 live replica right now."""
        return float((self.mask.sum(1) > 0).mean())

    def mean_replication(self) -> float:
        return float(self.mask.sum(1).mean())

    def data_loss_frac(self) -> float:
        """Fraction of chunks that ever had zero live replicas."""
        return len(self.ever_lost) / self.num_chunks

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """JSON-safe lifecycle state (catalogue, lanes, popularity,
        counters) — part of the pipeline checkpoint."""
        keys = sorted(self.counts)
        return {
            "ids": self.ids.tolist(),
            "mask": self.mask.astype(int).tolist(),
            "count_ids": [int(k) for k in keys],
            "counts": [int(self.counts[k]) for k in keys],
            "lanes": [[int(ln["chunk"]), int(ln["slot"]), int(ln["src"]),
                       int(ln["dst"]), float(ln["done_t"])]
                      for ln in self.lanes],
            "ever_lost": sorted(int(c) for c in self.ever_lost),
            "moves": int(self.moves),
            "dropped": int(self.dropped),
            "lost_reads": int(self.lost_reads),
        }

    def load_state_dict(self, s: Mapping[str, Any]) -> None:
        ids = np.asarray(s["ids"], np.int64)
        mask = np.asarray(s["mask"], bool)
        if ids.shape != self.ids.shape:
            raise ValueError(f"catalogue shape mismatch: checkpoint "
                             f"{ids.shape} vs configured {self.ids.shape}")
        self.ids, self.mask = ids, mask
        self.counts = {int(k): int(v)
                       for k, v in zip(s["count_ids"], s["counts"])}
        self.lanes = [{"chunk": int(c), "slot": int(sl), "src": int(a),
                       "dst": int(b), "done_t": float(d)}
                      for c, sl, a, b, d in s["lanes"]]
        self.ever_lost = set(int(c) for c in s["ever_lost"])
        self.moves = int(s["moves"])
        self.dropped = int(s["dropped"])
        self.lost_reads = int(s["lost_reads"])
        self._rebuild_busy()
