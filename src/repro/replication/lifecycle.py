"""Replication-lifecycle subsystem: the replica set of every chunk as a
mutable, costed object.

The paper's locality hierarchy rests on one design fact — "each data
chunk is replicated over 3 servers for increasing availability of data
and decreasing probability of data loss" — but placement (PR 5) only
chooses the *initial* replica sets.  This layer makes the replica map a
living object on both substrates:

  * a `MigrationModel` charges every replica move ``size / rate(tier)``
    slots of occupied bandwidth on source and destination, with the tier
    taken from the K-level `Topology` pair hierarchy (core-switch hops
    cost more than ToR hops, per the paper's network model);
  * a `ReplicationController` decides the *target* replication factor of
    every chunk from liveness and read popularity, and the lifecycle
    machinery closes the gap — wiping replicas on server/rack death,
    re-replicating from survivors under a tunable bandwidth cap (the
    repair-lane budget), and dropping surplus replicas for free;
  * failure/recovery events arrive through the scenario seam
    (``server_loss`` / ``rack_loss`` segments carry ``down_servers`` /
    ``down_racks``), so the fixed-shape `lax.scan` simulator and the
    host-side engine/pipeline replay the *same* incidents.

`@register_replication` mirrors the `@register_policy` /
`@register_placement` registries: controllers are selectable by name
from `simulate`/`sweep`/`replication_study`, the serving engine, the
data pipeline, the benches and the examples.  The ``"fixed"`` controller
with no failure scenario reproduces the pre-replication sample paths
**bitwise** on both substrates (pinned by tests/test_replication.py),
so the whole subsystem is opt-in with a zero-cost default.
"""

from __future__ import annotations

import abc
import dataclasses
import importlib
from typing import (TYPE_CHECKING, Any, Dict, Mapping, Tuple, Type, Union)

import numpy as np

if TYPE_CHECKING:  # annotation-only: core/serve/data import this package
    from repro.core.locality import Topology
    from repro.placement import PlacementPolicy


# ---------------------------------------------------------------------------
# Migration cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MigrationModel:
    """Bandwidth cost of moving one replica across the hierarchy.

    A move of a chunk of ``chunk_size`` (in units of tier-0 service work)
    between servers at pair-tier ``k`` occupies both endpoints for
    ``ceil(chunk_size / rate[k])`` slots — the same strictly decreasing
    `Rates` ladder that prices task service, so a cross-core copy costs
    more than a ToR-local one.  While a server is an endpoint of an
    in-flight move, its foreground TRUE service rates are multiplied by
    ``contention`` (< 1): repair storms contend with traffic.
    """

    chunk_size: float = 8.0
    contention: float = 0.5

    def __post_init__(self):
        if self.chunk_size <= 0.0:
            raise ValueError(f"chunk_size must be > 0, got {self.chunk_size}")
        if not 0.0 < self.contention <= 1.0:
            raise ValueError(f"contention must be in (0, 1], "
                             f"got {self.contention}")

    def cost_table(self, tier_rates) -> np.ndarray:
        """(K,) f32 slots of occupied bandwidth per move, by pair tier."""
        rates = np.asarray(tier_rates, np.float64)
        if rates.ndim != 1 or rates.size == 0 or np.any(rates <= 0.0):
            raise ValueError(f"tier_rates must be positive, got {rates}")
        return np.ceil(self.chunk_size / rates).astype(np.float32)

    def cost(self, tier_rates, tier: int) -> float:
        """Slots to move one replica between endpoints at `tier`."""
        return float(self.cost_table(tier_rates)[int(tier)])


# ---------------------------------------------------------------------------
# Controller contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Name + per-controller constructor options, e.g.
    ``ReplicationConfig("repair", {"lanes": 2})`` — the replication
    analogue of `PolicyConfig` / `PlacementConfig`."""

    name: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


ReplicationLike = Union[str, ReplicationConfig, "ReplicationController", None]


class ReplicationController(abc.ABC):
    """One replication rule: a target replica count per chunk, closed by
    the shared lifecycle machinery on both substrates.

    Common options (the lifecycle knobs every controller shares):

    num_chunks     -- catalogue size C tracked by the simulator projection
                      (the host consumers size theirs from their configs)
    lanes          -- max concurrent replica moves: the repair-bandwidth
                      cap.  Storms queue behind it instead of saturating
                      the fabric.
    moves_per_slot -- max moves *started* per slot (host: per observe())
    read_skew      -- Zipf exponent of the simulator's chunk-read
                      popularity (0 = uniform reads); gives popularity-
                      driven controllers a signal to adapt to
    catalogue_seed -- seed for the initial placement map
    chunk_size / contention -- forwarded to `MigrationModel`
    """

    name: str = ""
    #: True when the controller never moves, drops, or widens replicas on
    #: its own — with no failure track the lifecycle machinery is skipped
    #: entirely (a compile-time Python branch), preserving the
    #: pre-replication sample paths bitwise.
    is_static: bool = False

    def __init__(self, num_chunks: int = 64, lanes: int = 4,
                 moves_per_slot: int = 2, read_skew: float = 1.1,
                 catalogue_seed: int = 0, chunk_size: float = 8.0,
                 contention: float = 0.5):
        if num_chunks < 1:
            raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
        if lanes < 1:
            raise ValueError(f"lanes (repair-bandwidth cap) must be >= 1, "
                             f"got {lanes}")
        if moves_per_slot < 1:
            raise ValueError(f"moves_per_slot must be >= 1, "
                             f"got {moves_per_slot}")
        if read_skew < 0.0:
            raise ValueError(f"read_skew must be >= 0, got {read_skew}")
        self.num_chunks = int(num_chunks)
        self.lanes = int(lanes)
        self.moves_per_slot = int(moves_per_slot)
        self.read_skew = float(read_skew)
        self.catalogue_seed = int(catalogue_seed)
        self.migration = MigrationModel(chunk_size, contention)

    # -- target policy -------------------------------------------------------
    def max_target(self, base: int) -> int:
        """Widest replication factor this controller may request — the
        R_max the catalogue pads to."""
        return int(base)

    @abc.abstractmethod
    def sim_targets(self, pop, live, base_tgt):
        """Target replica count per chunk on the simulator substrate.

        ``pop`` (C,) f32 decayed read counts, ``live`` (C,) int32 live
        replicas, ``base_tgt`` (C,) int32 initial factors.  Pure jnp
        function of its inputs (traced inside `lax.scan`)."""

    @abc.abstractmethod
    def host_targets(self, counts: Mapping[int, int], live: np.ndarray,
                     base_tgt: np.ndarray) -> np.ndarray:
        """Target replica count per chunk on the host substrate.

        ``counts`` are cumulative `note_read` observations keyed by chunk
        id; ``live`` / ``base_tgt`` as above (numpy)."""

    # -- substrate projections ----------------------------------------------
    def build_sim(self, topo: "Topology", tier_rates,
                  placement: "PlacementPolicy"):
        """Compile the lifecycle machinery for the `lax.scan` simulator."""
        from repro.replication.simproj import SimReplication
        return SimReplication(self, topo, tier_rates, placement)

    def build_host(self, spec: "Topology", placement: "PlacementPolicy",
                   num_chunks: int, replication: int, seed: int,
                   tier_rates):
        """Instantiate the host-side lifecycle (engine / pipeline)."""
        from repro.replication.host import HostReplication
        return HostReplication(self, spec, placement, num_chunks,
                               replication, seed, tier_rates)


# ---------------------------------------------------------------------------
# Registry (mirrors core/policy.py and placement/policy.py)
# ---------------------------------------------------------------------------

_REPLICATIONS: Dict[str, Type[ReplicationController]] = {}
_BUILTIN_MODULES = ("repro.replication.controllers",)
_builtins_loaded = False


def _load_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    _builtins_loaded = True


def register_replication(cls: Type[ReplicationController]
                         ) -> Type[ReplicationController]:
    """Class decorator: add a ReplicationController under `cls.name`."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"replication class {cls.__name__} has no `name`")
    if name in _REPLICATIONS:
        raise ValueError(f"duplicate replication registration: {name!r}")
    _REPLICATIONS[name] = cls
    return cls


def available_replications() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_REPLICATIONS))


def replication_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered controller,
    from the first sentence of each class docstring — the self-describing
    registry surface behind ``benchmarks/run.py --help``."""
    from repro.utils.doc import first_doc_line
    _load_builtins()
    return {n: first_doc_line(c) for n, c in sorted(_REPLICATIONS.items())}


def get_replication_cls(name: str) -> Type[ReplicationController]:
    _load_builtins()
    try:
        return _REPLICATIONS[name]
    except KeyError:
        raise ValueError(f"unknown replication {name!r}; "
                         f"registered: {available_replications()}") from None


def make_replication(spec: ReplicationLike, **options
                     ) -> ReplicationController:
    """Resolve a name / ReplicationConfig / instance; None -> "fixed"."""
    if spec is None:
        spec = "fixed"
    if isinstance(spec, ReplicationController):
        if options:
            raise ValueError("options only apply when building by name")
        return spec
    if isinstance(spec, ReplicationConfig):
        if options:
            raise ValueError("options only apply when building by name")
        spec, options = spec.name, dict(spec.options)
    return get_replication_cls(spec)(**options)
