"""Fixed-shape replication lifecycle for the `lax.scan` simulator.

The machinery tracks an explicit chunk catalogue — ``ids (C, R) int32``
replica hosts plus a ``mask (C, R) bool`` liveness map, materialized
once from the placement policy — and evolves it every slot:

  wipe    -- replicas on dead servers (scenario ``alive`` track) vanish;
  commit  -- in-flight moves whose countdown hit zero land on their
             destination (moves with a dead endpoint are killed);
  drop    -- surplus replicas over the controller's target are released
             for free (rank-order within the row, keep the first
             ``target`` live copies);
  start   -- the largest-deficit chunks claim free migration lanes, a
             live source, and the least-loaded eligible destination; the
             move then occupies both endpoints for
             ``ceil(chunk_size / rate[pair_tier(src, dst)])`` slots
             (`MigrationModel`), multiplying their foreground TRUE rates
             by the contention factor while it runs.

Everything is fixed-shape and branch-free: L migration lanes (the
repair-bandwidth cap) are a static unrolled loop, catalogue scatters go
through a scratch row (index C) so lanes that did not commit write
nowhere, and the whole state is a NamedTuple threaded through the
simulator's scan carry — `sweep()` still vmaps the load x error x seed
grid over it untouched.

Chunk reads are sampled per-slot from a static Zipf(``read_skew``)
popularity over chunk ids with a dedicated fold of the slot key, so the
foreground arrival stream (and every policy's routing randomness) keeps
the exact same random bits as a run without replication — common random
numbers hold across controllers.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import locality as loc

#: fold_in tag for the chunk-read sub-stream of each slot key (disjoint
#: from the (k_arr, k_algo) split the simulator already consumes).
READ_KEY_TAG = 0x5EED


class RepState(NamedTuple):
    """Lifecycle state threaded through the scan carry (fixed shapes)."""

    ids: jnp.ndarray         # (C+1, R) int32 replica hosts (row C: scratch)
    mask: jnp.ndarray        # (C+1, R) bool  live replicas (row C: False)
    pop: jnp.ndarray         # (C,) f32 decayed read counts
    lane_chunk: jnp.ndarray  # (L,) int32 chunk being moved (C = idle)
    lane_slot: jnp.ndarray   # (L,) int32 catalogue column being filled
    lane_src: jnp.ndarray    # (L,) int32 source server
    lane_dst: jnp.ndarray    # (L,) int32 destination server
    lane_left: jnp.ndarray   # (L,) f32 slots remaining (0 = idle)
    ever_lost: jnp.ndarray   # (C,) bool chunk ever had zero live replicas
    moves: jnp.ndarray       # () i32 committed moves
    dropped: jnp.ndarray     # () i32 surplus replicas released
    lost_tasks: jnp.ndarray  # () i32 in-window arrivals for dead chunks
    busy_slots: jnp.ndarray  # () f32 server-slots occupied by migration
    max_conc: jnp.ndarray    # () i32 peak concurrent moves (<= L)
    avail_sum: jnp.ndarray   # () f32 window sum of availability
    repl_sum: jnp.ndarray    # () f32 window sum of mean replication
    win_cnt: jnp.ndarray     # () f32 measured slots


class SimReplication:
    """Compiled lifecycle machinery for one controller on one topology."""

    def __init__(self, ctrl, topo, tier_rates, placement):
        self.ctrl = ctrl
        base = min(loc.NUM_REPLICAS, topo.num_servers)
        ids, mask = placement.placement_map(topo, ctrl.num_chunks, base,
                                            ctrl.catalogue_seed)
        r_max = max(ids.shape[1], ctrl.max_target(base))
        if r_max > ids.shape[1]:  # widen for controllers that over-replicate
            pad = r_max - ids.shape[1]
            ids = np.concatenate(
                [ids, np.repeat(ids[:, :1], pad, axis=1)], axis=1)
            mask = np.concatenate(
                [mask, np.zeros((mask.shape[0], pad), bool)], axis=1)
        self.C, self.R = ids.shape
        self.L = ctrl.lanes
        self.M = topo.num_servers
        # scratch row C: catalogue scatters from non-committing lanes land
        # here (the kernels' max-shape + guard-row idiom)
        self.ids0 = jnp.asarray(
            np.concatenate([ids, np.zeros((1, self.R), np.int32)]))
        self.mask0 = jnp.asarray(
            np.concatenate([mask, np.zeros((1, self.R), bool)]))
        self.base_tgt = jnp.asarray(mask.sum(1).astype(np.int32))
        self.ancestors = topo.ancestors
        self.cost_table = jnp.asarray(
            ctrl.migration.cost_table(tier_rates))
        self.contention = ctrl.migration.contention
        self.decay = float(getattr(ctrl, "decay", 0.02))
        # static Zipf read popularity over chunk ids (0 = uniform)
        w = (np.arange(self.C, dtype=np.float64) + 1.0) ** -ctrl.read_skew
        self.read_logits = jnp.asarray(np.log(w / w.sum()), jnp.float32)

    def init(self) -> RepState:
        i32, f32 = jnp.int32, jnp.float32
        z = lambda: jnp.zeros((), i32)  # noqa: E731
        zf = lambda: jnp.zeros((), f32)  # noqa: E731
        return RepState(
            ids=self.ids0, mask=self.mask0,
            pop=jnp.zeros(self.C, f32),
            lane_chunk=jnp.full(self.L, self.C, i32),
            lane_slot=jnp.zeros(self.L, i32),
            lane_src=jnp.zeros(self.L, i32),
            lane_dst=jnp.zeros(self.L, i32),
            lane_left=jnp.zeros(self.L, f32),
            ever_lost=jnp.zeros(self.C, bool),
            moves=z(), dropped=z(), lost_tasks=z(),
            busy_slots=zf(), max_conc=z(),
            avail_sum=zf(), repl_sum=zf(), win_cnt=zf())

    def step(self, st: RepState, alive: jnp.ndarray, key: jnp.ndarray,
             active: jnp.ndarray, in_window: jnp.ndarray):
        """One slot of lifecycle; returns ``(state, fg_mult)`` where
        ``fg_mult (M,)`` multiplies the foreground TRUE rates (0 for dead
        servers, ``contention`` for busy migration endpoints)."""
        i32, f32 = jnp.int32, jnp.float32
        C, R, L = self.C, self.R, self.L
        alive_b = alive > 0.5
        ids, mask = st.ids, st.mask

        # wipe: replicas on dead servers are gone (and stay gone until a
        # repair move recreates them — recovery restores the server, empty)
        mask = mask & alive_b[ids]

        # lanes: kill moves with a dead endpoint, then advance survivors
        live_lane = (st.lane_left > 0.0) \
            & alive_b[st.lane_src] & alive_b[st.lane_dst]
        n_act = jnp.sum(live_lane.astype(i32))
        busy = jnp.zeros(self.M, i32) \
            .at[st.lane_src].max(live_lane.astype(i32)) \
            .at[st.lane_dst].max(live_lane.astype(i32)) > 0
        left = jnp.where(live_lane, st.lane_left - 1.0, 0.0)
        commit = live_lane & (left <= 0.0)
        wc = jnp.where(commit, st.lane_chunk, C)  # scratch row if no commit
        ids = ids.at[wc, st.lane_slot].set(
            jnp.where(commit, st.lane_dst, ids[wc, st.lane_slot]))
        mask = mask.at[wc, st.lane_slot].max(commit)

        # reads: skewed chunk popularity on a dedicated key fold (the
        # foreground arrival/routing streams keep their exact bits)
        k_read = jax.random.fold_in(key, READ_KEY_TAG)
        c_ids = jax.random.categorical(k_read, self.read_logits,
                                       shape=active.shape)
        reads = jnp.zeros(C, f32).at[c_ids].add(active.astype(f32))
        pop = (1.0 - self.decay) * st.pop + reads
        live = mask[:C].sum(1).astype(i32)
        lost_now = jnp.sum((active & (live[c_ids] == 0)).astype(i32))

        # targets and free drops (keep the first `tgt` live replicas)
        tgt = jnp.clip(self.ctrl.sim_targets(pop, live, self.base_tgt),
                       1, R).astype(i32)
        tgt_ext = jnp.concatenate([tgt, jnp.full((1,), R, i32)])
        rank = jnp.cumsum(mask.astype(i32), axis=1)
        keep = mask & (rank <= tgt_ext[:, None])
        n_dropped = jnp.sum(mask[:C].astype(i32)) \
            - jnp.sum(keep[:C].astype(i32))
        mask = keep
        live = mask[:C].sum(1).astype(i32)

        # deficit-driven move starts: largest deficit first (ties toward
        # the smaller chunk id), budgeted per slot, one destination slot
        # per in-flight move, bandwidth-capped by the L lanes themselves
        infl = jnp.zeros(C + 1, i32).at[st.lane_chunk].add(
            (left > 0.0).astype(i32))
        deficit = jnp.clip(tgt - live - infl[:C], 0, R)
        deficit = jnp.where(live > 0, deficit, 0)  # need a live source
        held = jnp.zeros(self.M, f32).at[ids[:C]].add(mask[:C].astype(f32))
        taken = mask.astype(i32).at[st.lane_chunk, st.lane_slot].max(
            (left > 0.0).astype(i32))
        lane_chunk, lane_slot = st.lane_chunk, st.lane_slot
        lane_src, lane_dst = st.lane_src, st.lane_dst
        started = jnp.zeros((), i32)
        score_tie = jnp.arange(C, dtype=f32)
        for i in range(L):  # static unroll: L is the bandwidth cap
            can = deficit > 0
            score = deficit.astype(f32) * (C + 1.0) - score_tie
            c = jnp.argmax(jnp.where(can, score, -jnp.inf)).astype(i32)
            row_ids, row_mask = ids[c], mask[c]
            slot = jnp.argmin(taken[c]).astype(i32)
            src = row_ids[jnp.argmax(row_mask)]
            holders = jnp.zeros(self.M, i32).at[row_ids].add(
                row_mask.astype(i32))
            pending = jnp.zeros(self.M, i32).at[lane_dst].add(
                ((left > 0.0) & (lane_chunk == c)).astype(i32))
            eligible = alive_b & (holders == 0) & (pending == 0)
            dst = jnp.argmin(jnp.where(eligible, held, jnp.inf)).astype(i32)
            ok = (left[i] <= 0.0) & jnp.any(can) & jnp.any(eligible) \
                & (started < self.ctrl.moves_per_slot)
            cost = self.cost_table[loc.pair_tiers(src, dst, self.ancestors)]
            lane_chunk = lane_chunk.at[i].set(
                jnp.where(ok, c, lane_chunk[i]))
            lane_slot = lane_slot.at[i].set(jnp.where(ok, slot, lane_slot[i]))
            lane_src = lane_src.at[i].set(jnp.where(ok, src, lane_src[i]))
            lane_dst = lane_dst.at[i].set(jnp.where(ok, dst, lane_dst[i]))
            left = left.at[i].set(jnp.where(ok, cost, left[i]))
            deficit = deficit.at[c].add(-ok.astype(i32))
            held = held.at[dst].add(ok.astype(f32))
            taken = taken.at[c, slot].max(ok.astype(i32))
            started = started + ok.astype(i32)

        in_w = in_window.astype(f32)
        new_st = RepState(
            ids=ids, mask=mask, pop=pop,
            lane_chunk=lane_chunk, lane_slot=lane_slot,
            lane_src=lane_src, lane_dst=lane_dst, lane_left=left,
            ever_lost=st.ever_lost | (live == 0),
            moves=st.moves + jnp.sum(commit.astype(i32)),
            dropped=st.dropped + n_dropped,
            lost_tasks=st.lost_tasks
            + jnp.where(in_window, lost_now, 0).astype(i32),
            busy_slots=st.busy_slots + 2.0 * n_act.astype(f32),
            max_conc=jnp.maximum(st.max_conc, n_act),
            avail_sum=st.avail_sum + in_w * jnp.mean((live > 0).astype(f32)),
            repl_sum=st.repl_sum + in_w * jnp.mean(live.astype(f32)),
            win_cnt=st.win_cnt + in_w)
        fg_mult = alive * jnp.where(busy, self.contention, 1.0)
        return new_st, fg_mult

    def metrics(self, st: RepState):
        """Availability / data-loss / migration metrics, all f32 scalars
        (merged into the simulator's output dict in machinery mode)."""
        f32 = jnp.float32
        win = jnp.maximum(st.win_cnt, 1.0)
        live = st.mask[:self.C].sum(1)
        return {
            "availability": st.avail_sum / win,
            "data_loss_frac": jnp.mean(st.ever_lost.astype(f32)),
            "mean_replication": st.repl_sum / win,
            "final_replication": jnp.mean(live.astype(f32)),
            "repair_moves": st.moves.astype(f32),
            "dropped_replicas": st.dropped.astype(f32),
            "lost_tasks": st.lost_tasks.astype(f32),
            "migration_busy_slots": st.busy_slots,
            "max_concurrent_moves": st.max_conc.astype(f32),
        }
