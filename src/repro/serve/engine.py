"""Continuous-batching serving engine with a Balanced-PANDAS request router.

Cluster model (the paper's data center, one level up the stack):
  * R replica groups ("servers"), grouped into pods ("racks");
  * every request carries a prefix id whose KV/prompt artifacts are resident
    on 3 replicas — placed by the configured `PlacementPolicy`
    (`repro.placement`, ``EngineConfig.placement``; the "uniform" default
    is the classic rendezvous placement, bitwise) — those are its *local*
    replicas; same-pod replicas are *rack-local* (prefix transfer over
    ICI), the rest *remote* (DCN);
  * the router assigns each incoming request to a replica by weighted
    workload over estimated service rates; rates are measured online per
    (replica, tier) with the EWMA estimator (Blind GB-PANDAS), so a slow or
    throttled replica sheds load without any configuration — the robustness
    property the paper measures is what makes this safe.

The engine actually runs the model: per-replica prefill (bucketed lengths to
bound recompiles) and batched decode steps over slotted KV caches with
per-slot lengths.  Any router registered in `core/policy.py` is selectable
by name (`EngineConfig.scheduler`) — the engine drives them all through the
uniform `route -> Decision` / `claim -> Claim` surface, with no per-router
branching; the robustness experiment at the serving level lives in
benchmarks/bench_serving.py and examples/serve_cluster.py.

Scenario playback (`EngineConfig.scenario`, `repro.workloads`): the same
declarative scenarios the simulator runs drive time-varying replica
slowdowns here — straggler windows and congestion sags inflate the observed
service times the EWMA estimator consumes, so a blind router re-routes
around a fault while it lasts.  bench_serving additionally uses the
playback's arrival-rate track to time request submission.  The loop closes
in the other direction too: every submit is logged on the engine-step
clock, and `ServingEngine.recorded_trace` re-records a run as a
`workloads.Trace` that replays deterministically through the whole stack
(``scenario="trace"``).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import ControlLike, resolve_control, scale_priority
from repro.core.cluster import tier_of
from repro.core.estimator import EwmaRateEstimator
from repro.core.locality import Topology
from repro.core.policy import make_router
from repro.placement import PlacementLike, make_placement
from repro.replication import ReplicationLike, make_replication
from repro.telemetry import (CLOCK_UNIT_US, EventRecorder,
                             percentiles_from_hist)
from repro.workloads import (ScenarioLike, Trace, host_playback,
                             make_scenario, trace_from_arrivals)

# Observed-service-time inflation for a request admitted on a DEAD replica
# (failure scenarios): large enough that the EWMA estimator sheds the
# replica within a few observations, finite so the engine still drains.
DEAD_SLOWDOWN = 25.0
from repro.models import params as params_lib, transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int
    prefix_id: int = 0
    arrival: float = 0.0
    # filled by the engine
    replica: int = -1
    tier: int = -1
    generated: Optional[List[int]] = None
    finish_time: float = 0.0
    start_time: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    num_replicas: int = 4
    replicas_per_pod: int = 2
    slots_per_replica: int = 4
    max_len: int = 256
    prefill_buckets: Sequence[int] = (32, 64, 128)
    scheduler: str = "balanced_pandas"
    # prior service rates (requests/step) per tier; measured online
    rate_local: float = 1.0
    rate_rack: float = 0.7
    rate_remote: float = 0.4
    # K-tier overrides: a full `locality.Topology` for the replica fleet
    # (num_replicas/replicas_per_pod are then derived from it) and a (K,)
    # tier-rate prior replacing the three rate_* fields.
    topology: Optional[Topology] = None
    tier_rates: Optional[Sequence[float]] = None
    seed: int = 0
    # scenario playback (repro.workloads): time-varying replica slowdowns
    # on the engine-step clock; None -> "static" (all multipliers 1.0)
    scenario: ScenarioLike = None
    scenario_horizon: int = 400  # engine steps per playback cycle
    # replica placement (repro.placement): which replicas hold each
    # prefix's KV/prompt artifacts.  None -> "uniform" (the classic
    # rendezvous placement, bitwise identical to the old
    # `chunk_replicas` calls).
    placement: PlacementLike = None
    # deterministic placement rebalance cadence (routed requests between
    # `PlacementPolicy.rebalance()` calls; 0 disables) — only meaningful
    # for popularity-driven placements (hot_aware)
    rebalance_every: int = 0
    # replication lifecycle (repro.replication): migration, adaptive
    # replication and failure repair over the prefix catalogue.  None ->
    # "fixed"; the lifecycle machinery only engages when a dynamic
    # controller is selected or the scenario carries a failure track
    # (server_loss / rack_loss), so the default stays bitwise identical.
    replication: ReplicationLike = None
    # prefix-catalogue size tracked by the replication lifecycle
    # (prefix ids wrap mod this when the lifecycle is active)
    num_prefixes: int = 64
    # structured event tracing (repro.telemetry.EventRecorder): route /
    # admit / request / decode events on the engine-step virtual clock
    # (1 step == 1 ms in the exported Chrome trace; decode X-event
    # durations are measured wall-clock for kernel-vs-host attribution).
    # None -> no events recorded, zero overhead on the hot path.
    tracer: Optional[EventRecorder] = None
    # control plane (repro.control): admission sheds requests at submit
    # time (finish_time = -1.0, never routed), autoscaling parks replicas
    # off the routing mask driven by the measured sojourn p95.  None ->
    # no control, the exact pre-control engine.
    control: ControlLike = None
    # host-side sojourn histogram (submit -> finish, engine steps): same
    # fixed-bin + overflow layout as the in-scan recorder, feeding
    # `sojourn_percentiles()` and the autoscaler's p95 signal.
    sojourn_hist_bins: int = 512
    sojourn_hist_max: float = 512.0


class Replica:
    """One replica group: slotted KV caches + jitted prefill/decode."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        b = ecfg.slots_per_replica
        self.caches = T.init_caches(cfg, b, ecfg.max_len)
        self.lengths = np.zeros(b, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * b
        self._decode = jax.jit(
            lambda p, tok, ln, c: T.decode_step(p, cfg, tok, ln, c))
        self._prefill = {}

    def free_slots(self) -> int:
        return sum(r is None for r in self.slot_req)

    def admit(self, req: Request) -> None:
        slot = self.slot_req.index(None)
        self.slot_req[slot] = req
        t = min(len(req.prompt), self.ecfg.max_len - req.max_new_tokens - 1)
        bucket = next((b for b in self.ecfg.prefill_buckets if b >= t),
                      self.ecfg.prefill_buckets[-1])
        t = min(t, bucket)
        prompt = np.zeros(bucket, np.int32)
        prompt[:t] = req.prompt[-t:]
        # Right-padded: pad positions are negative -> masked during prefill
        # and committed into invalid (-marked) ring slots.
        pos = np.where(np.arange(bucket) < t, np.arange(bucket),
                       -(np.arange(bucket) - t + 1)).astype(np.int32)
        if bucket not in self._prefill:
            cfg, max_len = self.cfg, self.ecfg.max_len

            def prefill(p, tokens, positions, last):
                caches1 = T.init_caches(cfg, 1, max_len)
                logits, sub, _ = T.forward(p, cfg, tokens,
                                           positions=positions,
                                           caches=caches1, remat=False)
                return logits[0, last], sub
            self._prefill[bucket] = jax.jit(prefill)
        logits, sub = self._prefill[bucket](self.params, prompt[None],
                                            pos[None], t - 1)
        # merge the freshly prefilled rows into this slot (eager scatter)
        self.caches = jax.tree.map(
            lambda full, one: full.at[:, slot:slot + 1].set(
                one.astype(full.dtype)), self.caches, sub)
        self.lengths[slot] = t
        req.generated = [int(jnp.argmax(logits))]
        req.start_time = time.monotonic()

    def decode_once(self) -> List[Request]:
        """One batched decode step; returns the requests that finished."""
        finished: List[Request] = []
        if all(r is None for r in self.slot_req):
            return finished
        tokens = np.zeros((len(self.slot_req), 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.generated:
                tokens[i, 0] = r.generated[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens),
            jnp.asarray(self.lengths, jnp.int32), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.lengths[i] += 1
            r.generated.append(int(nxt[i]))
            if (len(r.generated) > r.max_new_tokens
                    or self.lengths[i] >= self.ecfg.max_len - 1):
                r.finish_time = time.monotonic()
                finished.append(r)
                self.slot_req[i] = None
                self.lengths[i] = 0
        return finished


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 slow_replicas: Optional[Dict[int, float]] = None):
        self.cfg, self.ecfg = cfg, ecfg
        # The fleet layout is the same `Topology` the JAX simulator uses
        # (the host-only ClusterSpec is retired): K-tier hierarchies run
        # through the engine unchanged.
        self.spec = ecfg.topology if ecfg.topology is not None else \
            Topology(ecfg.num_replicas, ecfg.replicas_per_pod)
        n_rep = self.spec.num_servers
        prior = np.asarray(
            ecfg.tier_rates if ecfg.tier_rates is not None
            else (ecfg.rate_local, ecfg.rate_rack, ecfg.rate_remote),
            np.float32)
        if prior.shape != (self.spec.num_tiers,):
            raise ValueError(f"engine prior has {prior.size} tier rates but "
                             f"the fleet has {self.spec.num_tiers} tiers")
        self.estimator = EwmaRateEstimator(n_rep, prior)
        self.router = make_router(ecfg.scheduler, self.spec, prior,
                                  estimator=self.estimator, seed=ecfg.seed)
        # Prefix artifacts live where the placement policy puts them
        # (uniform == the classic rendezvous placement).
        self.placement = make_placement(ecfg.placement)
        if ecfg.rebalance_every < 0:
            raise ValueError(f"rebalance_every must be >= 0, got "
                             f"{ecfg.rebalance_every}")
        self.routed = 0
        self.rebalanced = 0
        self.replicas = [Replica(cfg, params, ecfg) for _ in range(n_rep)]
        self.queue: deque = deque()            # not-yet-routed arrivals
        self.waiting: List[deque] = [deque()   # routed, awaiting a slot
                                     for _ in range(n_rep)]
        self.pending: deque = deque()          # deferred-assignment (global)
        self.slow = slow_replicas or {}
        # One scenario seam for every scheduler: the playback inflates the
        # observed service times the estimator sees, exactly like the static
        # `slow_replicas` dict but time-varying (stragglers open and close).
        self.playback = host_playback(make_scenario(ecfg.scenario),
                                      n_rep, float(ecfg.scenario_horizon),
                                      num_tiers=self.spec.num_tiers,
                                      rack_of=np.asarray(self.spec.rack_of))
        # Replication lifecycle: engaged only when a controller is
        # configured or the scenario kills servers — otherwise replica
        # lookups go straight to the placement policy (bitwise pinned).
        ctrl = make_replication(ecfg.replication)
        if ctrl.is_static and self.playback.alive is None:
            self.replication = None
        else:
            self.replication = ctrl.build_host(
                self.spec, self.placement, ecfg.num_prefixes, 3,
                ecfg.seed, prior)
        self.lost_routes = 0  # arrivals whose prefix had no live replica
        # Host control plane (repro.control): admission + autoscaling on
        # the engine-step clock.  None -> the exact pre-control paths.
        plane = resolve_control(ecfg.control)
        self.control = None if plane is None else \
            plane.build_host(self.spec, float(prior[0]), seed=ecfg.seed)
        # Host sojourn histogram (submit -> finish, steps): fixed bins +
        # overflow, mirroring the in-scan recorder's layout so the same
        # percentile estimator reads both.
        if ecfg.sojourn_hist_bins < 1 or ecfg.sojourn_hist_max <= 0:
            raise ValueError("sojourn_hist_bins must be >= 1 and "
                             "sojourn_hist_max > 0")
        self._soj_width = float(ecfg.sojourn_hist_max) / ecfg.sojourn_hist_bins
        self.sojourn_hist = np.zeros(ecfg.sojourn_hist_bins + 1, np.int64)
        self.completed = 0
        # Autoscale parking: rank r server is the r-th kept on shrink.
        self._scale_rank = scale_priority(self.spec)
        self._parked = np.zeros(n_rep, bool)
        self.steps = 0
        self.assign_tiers = {t: 0 for t in range(self.spec.num_tiers)}
        # engine-step index of every submit, for trace export (recorded_trace)
        self.arrival_log: List[int] = []
        # Structured event tracing: router/control events on tid 0, each
        # replica on tid i+1; virtual clock is the engine-step counter.
        self.tracer = ecfg.tracer
        if self.replication is not None:
            self.replication.tracer = self.tracer
        if self.tracer is not None:
            self.tracer.metadata("process_name", name="serving_engine")
            self.tracer.metadata("thread_name", tid=0, name="router")
            for i in range(n_rep):
                self.tracer.metadata("thread_name", tid=i + 1,
                                     name=f"replica{i}")

    def _ts(self) -> float:
        """Virtual-clock timestamp (µs) of the current engine step."""
        return self.steps * CLOCK_UNIT_US

    def submit(self, req: Request) -> None:
        req.arrival = time.monotonic()
        req._submit_step = self.steps  # type: ignore[attr-defined]
        self.arrival_log.append(self.steps)
        if self.control is not None and \
                not self.control.admit(self.steps, self.in_system):
            # Shed BEFORE routing: the request never touches a queue.
            # finish_time = -1.0 marks it settled (run_until_drained waits
            # on == 0.0) without ever having started.
            req.finish_time = -1.0
            if self.tracer is not None:
                self.tracer.instant("shed", cat="engine", ts_us=self._ts(),
                                    rid=req.rid, prefix=req.prefix_id)
            return
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.instant("submit", cat="engine", ts_us=self._ts(),
                                rid=req.rid, prefix=req.prefix_id)

    @property
    def in_system(self) -> int:
        """Admitted-but-unfinished requests (queued, waiting, or decoding)
        — the engine-side conservation counter: admitted == completed +
        in_system at every step."""
        if self.control is not None:
            return self.control.admitted - self.completed
        return len(self.arrival_log) - self.completed

    def _note_finished(self, finished: List[Request]) -> None:
        """Sojourn accounting for requests that finished this step
        (submit -> finish on the engine-step clock), shared by the traced
        and untraced decode branches."""
        for r in finished:
            self.completed += 1
            s = getattr(r, "_submit_step", None)
            if s is None:
                continue
            b = min(int((self.steps - s) / self._soj_width),
                    len(self.sojourn_hist) - 1)
            self.sojourn_hist[b] += 1

    def sojourn_percentiles(self, qs=(0.5, 0.95, 0.99)) -> np.ndarray:
        """Sojourn quantiles (engine steps) from the host histogram —
        upper-bin-edge estimates, exactly like the in-scan recorder (NaN
        before the first completion, inf from the overflow bin)."""
        return percentiles_from_hist(self.sojourn_hist, self._soj_width, qs)

    @property
    def sojourn_overflow_frac(self) -> float:
        """Fraction of completions whose sojourn exceeded
        ``sojourn_hist_max`` (quantiles landing there report inf)."""
        total = int(self.sojourn_hist.sum())
        return float(self.sojourn_hist[-1]) / max(total, 1)

    def recorded_trace(self, num_intervals: int = 32,
                       name: str = "engine") -> Trace:
        """Re-record this run's arrival stream as a replayable `Trace`
        (per-interval submit counts on the engine-step clock).  Save it
        with `workloads.save_trace` and the same traffic replays — through
        this engine, the simulator, or the benches — via
        ``scenario="trace"``."""
        horizon = float(max([self.steps, 1]
                            + [s + 1 for s in self.arrival_log]))
        return trace_from_arrivals(self.arrival_log, num_intervals,
                                   name=name, horizon=horizon)

    # -- scheduling ----------------------------------------------------------
    def _route_arrivals(self) -> None:
        while self.queue:
            req = self.queue.popleft()
            if self.replication is not None:
                # live replica set from the lifecycle catalogue; an
                # all-dead prefix falls back to the placement's static
                # set (a cold-store refetch) and counts as a lost route
                locs = self.replication.replicas_for(req.prefix_id)
                self.replication.note_read(req.prefix_id)
                if not locs:
                    self.lost_routes += 1
                    if self.tracer is not None:
                        self.tracer.instant("lost_route", cat="engine",
                                            ts_us=self._ts(), rid=req.rid,
                                            prefix=req.prefix_id)
                    locs = self.placement.replicas(self.spec, req.prefix_id,
                                                   3, self.ecfg.seed)
            else:
                locs = self.placement.replicas(self.spec, req.prefix_id, 3,
                                               self.ecfg.seed)
            self.placement.note_read(req.prefix_id)
            self.routed += 1
            if self.ecfg.rebalance_every and \
                    self.routed % self.ecfg.rebalance_every == 0:
                self.rebalanced += self.placement.rebalance()
            req._locs = locs  # type: ignore[attr-defined]
            decision = self.router.route(locs)
            if self.tracer is not None:
                self.tracer.instant(
                    "route", cat="engine", ts_us=self._ts(), rid=req.rid,
                    replica=-1 if decision.deferred else decision.worker)
            if decision.deferred:
                self.pending.append(req)  # assigned at claim time
            else:
                req.replica = decision.worker
                self.waiting[decision.worker].append(req)

    def _admit(self) -> None:
        for i, rep in enumerate(self.replicas):
            # A parked (descaled) replica drains its already-routed queue,
            # then stops claiming — it must not pull from the global
            # deferred queue or steal other replicas' work.
            if self._parked[i] and not self.waiting[i]:
                continue
            while rep.free_slots():
                claim = self.router.claim(i)
                if claim is None:
                    break
                # claim.source names the queue the task came from: a
                # replica's routed queue, or the global deferred queue (-1).
                src = self.pending if claim.source < 0 \
                    else self.waiting[claim.source]
                req = src.popleft()
                req.replica = i
                req.tier = tier_of(self.spec, req._locs, req.replica)
                self.assign_tiers[req.tier] += 1
                req._admit_step = self.steps  # type: ignore[attr-defined]
                if self.tracer is not None:
                    self.tracer.instant("admit", cat="engine",
                                        ts_us=self._ts(), tid=i + 1,
                                        rid=req.rid, tier=req.tier)
                t0 = time.monotonic()
                self.replicas[req.replica].admit(req)
                slow = self.slow.get(req.replica, 1.0) * self.playback.slowdown(
                    self.steps, req.replica, req.tier)
                if self.replication is not None:
                    # migration endpoints serve slower (contention); dead
                    # replicas inflate hard so the EWMA sheds them
                    slow /= self.replication.contention_mult(req.replica)
                    if not self.replication.is_alive(req.replica):
                        slow *= DEAD_SLOWDOWN
                elapsed = (time.monotonic() - t0) * slow
                self.router.on_complete(req.replica, req.tier,
                                        max(elapsed, 1e-4))

    # -- execution -----------------------------------------------------------
    def step(self) -> None:
        """One engine tick: route arrivals, admit into free slots, one decode
        step on every replica."""
        if self.replication is not None:
            self.replication.observe(float(self.steps),
                                     self.playback.alive_mask_at(self.steps))
        self._route_arrivals()
        self._admit()
        if self.tracer is None:
            for rep in self.replicas:
                self._note_finished(rep.decode_once())
        else:
            self.tracer.counter(
                "queued", len(self.queue) + len(self.pending)
                + sum(len(w) for w in self.waiting), ts_us=self._ts())
            for i, rep in enumerate(self.replicas):
                active = sum(r is not None for r in rep.slot_req)
                t0 = self.tracer.now_us()
                finished = rep.decode_once()
                self._note_finished(finished)
                if active:
                    # virtual-clock placement, wall-clock width: the dur
                    # is real kernel-dispatch time attributed to this step
                    self.tracer.complete("decode", self._ts(),
                                         self.tracer.now_us() - t0,
                                         cat="kernel", tid=i + 1,
                                         batch=active)
                for r in finished:
                    a = getattr(r, "_admit_step", self.steps)
                    self.tracer.complete(
                        f"request{r.rid}", a * CLOCK_UNIT_US,
                        (self.steps - a + 1) * CLOCK_UNIT_US, cat="request",
                        tid=r.replica + 1, rid=r.rid, tier=r.tier,
                        tokens=len(r.generated or ()))
        if self.control is not None and self.control.autoscaler is not None:
            # Reactive autoscaling: feed the measured sojourn p95; a new
            # target reshapes the routing mask (parked replicas drain).
            p95 = float(self.sojourn_percentiles((0.95,))[0])
            target = self.control.observe(self.steps, p95)
            if target is not None:
                mask = self._scale_rank < target
                self.router.set_active(mask)
                self._parked = ~mask
                if self.tracer is not None:
                    self.tracer.instant("autoscale", cat="engine",
                                        ts_us=self._ts(), target=int(target))
        self.steps += 1

    def run_until_drained(self, all_requests: Sequence[Request],
                          max_steps: int = 10_000) -> List[Request]:
        for r in all_requests:
            self.submit(r)
        outstanding = list(all_requests)
        while any(r.finish_time == 0.0 for r in outstanding):
            self.step()
            if self.steps > max_steps:
                raise RuntimeError("engine did not drain")
        return outstanding

    @property
    def queue_depths(self) -> np.ndarray:
        return self.router.queue_depths()
