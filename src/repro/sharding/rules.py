"""Logical-axis sharding: one rule table maps logical axes (declared next to
every parameter in models/params.py and at activation constraint points) to
mesh axes, with automatic divisibility fallback.

Parallelism coverage:
  DP    — batch over ("pod", "data")
  FSDP  — parameter "embed" dim additionally sharded over "data" (ZeRO-3;
          per-layer all-gather amortized by the layer scan)
  TP    — heads / mlp / vocab / ssm_inner over "model"
  EP    — expert axis over "model" when divisible (granite 32e, jamba 16e),
          else TP-within-expert (mixtral 8e on a 16-way model axis)
  SP    — KV-cache sequence dim over the DP axes for long-context decode
          (long_500k, batch=1: the batch axes are idle, the cache is not)

The divisibility fallback (dim % mesh-extent != 0 -> replicate) is what lets
one rule table serve ten architectures: gemma3's 4 q-heads or kv=1 simply
fall back to replicated attention while its 6912-wide mlp still shards 16
ways.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Per-run parallelism switches (chosen per arch x shape in configs/runtime)."""

    fsdp: bool = True              # shard param embed-dim over data (ZeRO-3)
    expert_parallel: str = "auto"  # "auto" | "ep" | "tp"
    seq_shard_cache: bool = False  # SP: shard KV cache seq over DP axes
    dp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on multi-pod


def make_rules(policy: ShardingPolicy, *, num_experts: int = 0,
               model_axis_size: int = 1) -> Dict[str, AxisVal]:
    ep = (policy.expert_parallel == "ep" or
          (policy.expert_parallel == "auto" and num_experts > 0
           and num_experts % model_axis_size == 0))
    dp = tuple(policy.dp_axes)
    return {
        # parameters
        "vocab": "model",
        "embed": dp if policy.fsdp else None,
        "q_heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model" if ep else None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "conv": None,
        "pos": None,
        "layers": None,
        None: None,
        # activations
        "act_batch": dp,
        "act_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model" if ep else None,
        "act_cap": None if ep else dp,
        "act_cache": dp if policy.seq_shard_cache else None,
    }


class ShardCtx:
    """Threads (mesh, rules) through model code; `constrain` is the only
    integration point layers need."""

    def __init__(self, mesh: Mesh, rules: Dict[str, AxisVal]):
        self.mesh = mesh
        self.rules = rules

    def spec(self, axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        """PartitionSpec for logical `axes` against `shape`, dropping any
        mesh axis that does not divide its dim or is already used."""
        used: set = set()
        parts = []
        for dim, ax in zip(shape, axes):
            val = self.rules.get(ax)
            if val is None:
                parts.append(None)
                continue
            mesh_axes = (val,) if isinstance(val, str) else tuple(val)
            if any(a in used for a in mesh_axes):
                parts.append(None)
                continue
            extent = int(np.prod([self.mesh.shape[a] for a in mesh_axes]))
            if extent == 0 or dim % extent != 0:
                parts.append(None)
                continue
            used.update(mesh_axes)
            parts.append(val if isinstance(val, str) else tuple(val))
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x, axes: Sequence[Optional[str]]):
        if len(axes) != x.ndim:
            raise ValueError(f"axes {axes} vs shape {x.shape}")
        return jax.lax.with_sharding_constraint(
            x, self.sharding(axes, x.shape))


def tree_axes_to_shardings(ctx: ShardCtx, shape_tree, axes_tree):
    """NamedSharding pytree for a (ShapeDtypeStruct | array) tree and a
    parallel logical-axes tree whose leaves are tuples of axis names.  (Tuples
    are pytree-internal nodes, so this flattens the two trees separately.)"""
    flat_s, tdef = jax.tree.flatten(shape_tree)
    flat_a = _flatten_axes(axes_tree, tdef)
    return jax.tree.unflatten(
        tdef, [ctx.sharding(a, s.shape) for s, a in zip(flat_s, flat_a)])


def _flatten_axes(axes_tree, treedef):
    leaves = jax.tree.flatten(
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))[0]
    if len(leaves) != treedef.num_leaves:
        raise ValueError("axes tree does not match value tree")
    return leaves
