"""Fleet-scale fast path for the discrete-time simulator (10k+ servers).

The faithful simulator (`core/simulator.py`) routes each slot's arrivals
*sequentially* — a `fori_loop` of B ≈ 2·lam O(M) argmins — and samples
task types with (B, M) Gumbel top-k.  At M = 10^4 that is ~50k tiny XLA
ops and ~5·10^7 Gumbels dispatched per slot on CPU: the path *runs* but
at under 1 slot/s.  This module is the fleet-engaged backend: same
discrete-time model, same metrics keys, O(B + M·depth) work per slot and
a few hundred fused ops per slot, so 10k-server studies run at hundreds
of slots/s (see docs/scaling.md for the before/after curve and the
dispatch-bound performance model).

What changes (and what is pinned to hold still):

* **Arrivals** — O(B) distinct-3 sampler (uniform-offset trick) instead
  of (B, M) Gumbel top-k.  Statistically identical task-type law; the
  sample path is NOT bitwise the dense path's (different RNG layout), so
  the fleet path is held to the *delay bands* of tests/test_fleet_scale.py
  rather than bitwise pins.  The dense sub-threshold path is untouched
  and stays bitwise (pinned per policy).
* **Routing** — one workload snapshot per round instead of per-arrival
  updates.  The private phase (every tier better than remote) is an
  exact per-level `segment_min`: a server whose true tier is deeper than
  the level scanned always scores strictly lower at its true tier (rates
  decrease in the tier and the -rate*1e-6 term breaks toward the faster
  tier), so per-group minima at each level combine into the exact
  private argmin — no exclusion machinery.  (Assumes per-server estimated
  rates decrease in the tier, which every shipped error model preserves.)
  On TPU the fused Pallas kernel (`kernels/slot_step.py`) computes the
  same surface in one launch; on CPU the segment-min form wins (it is
  O(M·depth), not O(B·M)).
* **The remote pool** — a snapshot argmin would pile every pool-bound
  task of a slot onto one server.  Instead the slot's pool assignment is
  solved as a *water-filling fixed point*: server m enters the pool at
  score p_m = W_m/r_m - r_m*1e-6 and each absorbed task raises it by
  d_m = 1/r_m^2, so at water level y it absorbs
  c_m(y) = max(0, ceil((y - p_m)/d_m)) tasks; tasks prefer their private
  option iff s_priv <= y.  Bisecting y to the smallest level with
  sum_m c_m(y) >= #{active: s_priv > y} reproduces the sequential
  greedy's fluid limit.  Private fill-up is modeled the same way: the
  r-th task (0-based) claiming private server m stays private only while
  s_priv + r/rate^2 <= y — the rank clamp that stops a hot rack from
  absorbing a whole slot's hot batch in one snapshot.
* **The scan hot loop** — the horizon is cut into fixed-size chunks run
  by one jitted function with a *donated* carry (`donate_argnums=0`), so
  per-chunk buffers are reused instead of reallocated; inside each chunk
  `lax.scan(..., unroll=)` amortizes dispatch.  Slots past the horizon
  are frozen (the carry is re-selected), so ragged horizons compile
  exactly one chunk program.  Arrival scatters touch B rows
  (`q.at[srv, tier].add`), never an (M, K)-dense one-hot — the
  event-driven update shape.
* **Sweeps** — `fleet_sweep` vmaps the chunk function over the flattened
  (load x error x seed) grid: one compile for the whole study.

Service/scheduling dynamics reuse `core.balanced_pandas.serve_and_schedule`
verbatim (vectorized already).  Supported configurations: policies
`balanced_pandas` / `pandas_po2`, static scenario, uniform placement,
static replication, no telemetry — `fleet_supported` reports why anything
else must take the dense path, and `core.simulator.simulate/sweep` fall
back (or raise, when ``fleet=True`` was explicit).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balanced_pandas as bp
from repro.core import locality as loc
from repro.core.policy import PolicyLike, make_policy
from repro.kernels import ops as kops

# Auto-engagement floor for core.simulator's ``fleet=None``: every
# paper-scale configuration (M <= a few hundred) stays on the faithful
# dense path; only genuinely fleet-sized topologies switch.
FLEET_AUTO_THRESHOLD = 1024

_SUPPORTED_POLICIES = ("balanced_pandas", "pandas_po2")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs of the fleet fast path.

    chunk      -- slots per donated-carry jit call (the horizon is cut
                  into ceil(horizon/chunk) identical chunk programs)
    unroll     -- lax.scan unroll factor inside a chunk
    rounds     -- private-routing retry passes per slot (Balanced-PANDAS
                  only): each pass commits the clamp winners and the
                  losers re-route against the updated workload, so
                  collision overflow lands on its next-best private
                  option instead of spilling to the remote pool.  2 is
                  enough to hold the delay bands pinned in
                  tests/test_fleet_scale.py; 1 is the cheapest/loosest.
    fill_iters -- bisection iterations for the pool water level
    use_pallas -- force the fused Pallas route kernel on/off
                  (None = auto: on only on TPU; the CPU hot loop uses
                  the O(M·depth) segment-min form)
    """

    chunk: int = 128
    unroll: int = 4
    rounds: int = 2
    fill_iters: int = 32
    use_pallas: Optional[bool] = None

    def __post_init__(self):
        if self.chunk < 1 or self.unroll < 1 or self.rounds < 1:
            raise ValueError(f"chunk/unroll/rounds must be >= 1, got "
                             f"{self.chunk}/{self.unroll}/{self.rounds}")
        if self.fill_iters < 8:
            raise ValueError(f"fill_iters must be >= 8 for a usable water "
                             f"level, got {self.fill_iters}")


FleetLike = Union[None, bool, FleetConfig]


def as_fleet_config(spec: FleetLike) -> FleetConfig:
    """None/True -> defaults; a FleetConfig passes through."""
    if isinstance(spec, FleetConfig):
        return spec
    return FleetConfig()


@dataclasses.dataclass(frozen=True)
class FleetCtx:
    """Static per-topology constants the hot loop closes over."""

    num_servers: int
    num_tiers: int
    depth: int
    group_counts: Tuple[int, ...]   # groups per level
    hot_rack_size: int              # rack 0 size (M for a depth-0 fleet)
    anc: Any                        # (depth, M) int32 device array
    gids: Tuple[Any, ...]           # per-level (M,) group-id rows


def make_ctx(topo: loc.Topology) -> FleetCtx:
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    return FleetCtx(
        num_servers=topo.num_servers,
        num_tiers=topo.num_tiers,
        depth=topo.depth,
        group_counts=tuple(len(topo.group_sizes[l])
                           for l in range(topo.depth)),
        hot_rack_size=(topo.group_sizes[0][0] if topo.depth
                       else topo.num_servers),
        anc=anc,
        gids=tuple(anc[l] for l in range(topo.depth)),
    )


def fleet_supported(policy_like: PolicyLike, cfg, scenario=None,
                    placement=None, replication=None,
                    telemetry=None) -> Optional[str]:
    """None when the fleet path can run this configuration, else the
    reason it cannot (the dense path must be used)."""
    policy = make_policy(policy_like)
    if policy.name not in _SUPPORTED_POLICIES:
        return (f"policy {policy.name!r} has no fleet step "
                f"(supported: {_SUPPORTED_POLICIES})")
    if telemetry is not None and telemetry is not False:
        return "telemetry recorders require the dense in-scan step"
    from repro import workloads as wl
    if wl.make_scenario(scenario).name != "static":
        return "only the static scenario is fleet-compiled"
    from repro.placement import make_placement
    if make_placement(placement).name != "uniform":
        return "only uniform placement has a fleet sampler"
    from repro.replication import make_replication
    if not make_replication(replication).is_static:
        return "dynamic replication rides the dense scan carry"
    if cfg.topo.num_servers < loc.NUM_REPLICAS:
        return "need at least NUM_REPLICAS servers"
    return None


# ---------------------------------------------------------------------------
# O(B) arrival sampling (distinct-3 via the uniform-offset trick)
# ---------------------------------------------------------------------------


def _sample_arrivals(key: jax.Array, ctx: FleetCtx, lam, p_hot: float,
                     batch: int):
    """(types (B,3) i32 sorted, active (B,) bool) — same arrival law as
    `locality.sample_arrivals_at` under the static scenario (truncated
    Poisson count; hot tasks replica-set inside rack 0, the rest uniform)
    in O(B) work instead of (B, M) Gumbels."""
    k_n, k_t = jax.random.split(key)
    n = jnp.minimum(jax.random.poisson(k_n, lam), batch)
    active = jnp.arange(batch) < n
    k_hot, k_u = jax.random.split(k_t)
    hot = jax.random.bernoulli(k_hot, p_hot, (batch,))
    size = jnp.where(hot, ctx.hot_rack_size, ctx.num_servers
                     ).astype(jnp.float32)
    r = jax.random.uniform(k_u, (batch, 3))
    x0 = jnp.minimum(jnp.floor(r[:, 0] * size), size - 1)
    x1 = jnp.minimum(jnp.floor(r[:, 1] * (size - 1)), size - 2)
    x1 = x1 + (x1 >= x0)
    lo, hi = jnp.minimum(x0, x1), jnp.maximum(x0, x1)
    x2 = jnp.minimum(jnp.floor(r[:, 2] * (size - 2)), size - 3)
    x2 = x2 + (x2 >= lo)
    x2 = x2 + (x2 >= hi)
    types = jnp.stack([x0, x1, x2], axis=1).astype(jnp.int32)
    return jnp.sort(types, axis=1), active


# ---------------------------------------------------------------------------
# Private-phase routing: exact per-level segment-min (CPU) / fused kernel
# ---------------------------------------------------------------------------


def _segment_argmin(score, gid, ngroups: int, m: int):
    """Per-group (min, lowest index achieving it); gid rows are the
    contiguous `Topology.ancestors` levels, so indices are sorted."""
    gmin = jax.ops.segment_min(score, gid, num_segments=ngroups,
                               indices_are_sorted=True)
    hit = score == gmin[gid]
    sid = jnp.arange(score.shape[0], dtype=jnp.int32)
    gidx = jax.ops.segment_min(jnp.where(hit, sid, m), gid,
                               num_segments=ngroups, indices_are_sorted=True)
    return gmin, gidx


def _private_route_segmin(w, est, ctx: FleetCtx, locs):
    """Exact private argmin per task from per-level group minima.

    Level l's candidate scores every member of a local's level-l group at
    the tier-(l+1) rate.  A member whose true tier is shallower scores
    strictly lower at its true tier — rates decrease in the tier, and the
    -rate*1e-6 term also favors the faster tier — and that true-tier
    score is itself a candidate at the shallower level, so any candidate
    achieving the overall minimum is at its true tier.  Combining levels
    (locals first) by lexicographic (score, server index) therefore
    reproduces the full (B, M) surface's lowest-index argmin exactly,
    including cross-tier score ties.  Semantics contract:
    kernels/ref.fleet_route.
    """
    m = ctx.num_servers
    e0 = est[:, 0]
    sc_loc = w[locs] / e0[locs] - e0[locs] * 1e-6          # (B, 3)
    best_v = jnp.min(sc_loc, axis=1)
    hit = sc_loc == best_v[:, None]
    best_i = jnp.min(jnp.where(hit, locs, m), axis=1)
    best_t = jnp.zeros_like(best_i)
    for lvl in range(ctx.depth):
        rate = est[:, lvl + 1]
        sc = w / rate - rate * 1e-6                        # (M,)
        gmin, gidx = _segment_argmin(sc, ctx.gids[lvl],
                                     ctx.group_counts[lvl], m)
        tg = ctx.gids[lvl][locs]                           # (B, 3)
        cand_v = gmin[tg]
        cand_i = gidx[tg]
        cv = jnp.min(cand_v, axis=1)
        chit = cand_v == cv[:, None]
        ci = jnp.min(jnp.where(chit, cand_i, m), axis=1)
        better = (cv < best_v) | ((cv == best_v) & (ci < best_i))
        best_v = jnp.where(better, cv, best_v)
        best_i = jnp.where(better, ci, best_i)
        best_t = jnp.where(better, lvl + 1, best_t)
    return (best_i.astype(jnp.int32), best_t.astype(jnp.int32), best_v)


def _water_level(p, d, demand_fn, hi0, batch: int, iters: int):
    """Smallest y with sum_m c_m(y) >= demand(y), by bisection.

    c_m(y) = clip(ceil((y - p_m)/d_m), 0, B).  demand_fn must be
    non-increasing in y; returns the upper end (capacity >= demand
    guaranteed there)."""
    lo = jnp.min(p)
    hi = jnp.maximum(jnp.max(p), hi0) + batch * jnp.max(d)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cap = jnp.sum(jnp.clip(jnp.ceil((mid - p) / d), 0.0, float(batch)))
        ok = cap >= demand_fn(mid)
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def _route_batch_pandas(s: bp.PandasState, est, ctx: FleetCtx, locs, active,
                        fc: FleetConfig, use_pallas: bool):
    """One slot of Balanced-PANDAS fleet routing: `fc.rounds` retry passes
    of (private argmin + rank clamp) with the workload recomputed between
    passes, then one pool water-fill for whatever is left.

    Each pass commits the tasks whose filled private score stays under
    the water level; the losers retry against the *updated* workload, so
    a collision's overflow lands on its second-best private option —
    the sequential simulator's fallback behavior — instead of spilling
    straight to the (slower) remote pool.
    """
    m, k = ctx.num_servers, ctx.num_tiers
    batch = locs.shape[0]
    pending = active
    for r in range(fc.rounds):
        w = bp.workload(s, est)
        if use_pallas:
            best_i, best_t, best_v = kops.fleet_route(s.q, s.serving, est,
                                                      ctx.anc, locs)
        else:
            best_i, best_t, best_v = _private_route_segmin(w, est, ctx, locs)

        # pool (remote tier) water-fill parameters from the same snapshot
        pr = est[:, k - 1]
        p = w / pr - pr * 1e-6
        d = 1.0 / (pr * pr)
        s_priv = jnp.where(pending, best_v, jnp.float32(-3e38))

        def demand(y):
            return jnp.sum((pending & (best_v > y)).astype(jnp.float32))

        y1 = _water_level(p, d, demand, jnp.max(s_priv), batch,
                          fc.fill_iters)

        # private rank clamp: the r-th claimant of a server stays private
        # only while its filled score is still under the water level
        go_raw = pending & (best_v <= y1)
        key_m = jnp.where(go_raw, best_i, m)
        order = jnp.argsort(key_m, stable=True)
        sk = key_m[order]
        first = jnp.searchsorted(sk, sk, side="left")
        rank = jnp.zeros((batch,), jnp.int32).at[order].set(
            (jnp.arange(batch) - first).astype(jnp.int32))
        e_at = est[best_i, best_t]
        stay = go_raw & (best_v + rank / (e_at * e_at) <= y1)

        if r < fc.rounds - 1:
            # commit this pass's winners; losers retry against updated W
            s = bp.PandasState(
                q=s.q.at[best_i, best_t].add(stay.astype(jnp.int32)),
                serving=s.serving)
            pending = pending & ~stay

    # final pass: pool assignment at the re-raised level
    pool = pending & ~stay
    n_pool = jnp.sum(pool.astype(jnp.float32))
    y2 = _water_level(p, d, lambda y: n_pool, jnp.max(s_priv), batch,
                      fc.fill_iters)
    caps = jnp.clip(jnp.ceil((y2 - p) / d), 0.0, float(batch)
                    ).astype(jnp.int32)
    cum = jnp.cumsum(caps)
    pool_rank = jnp.cumsum(pool.astype(jnp.int32)) - 1
    pool_srv = jnp.clip(jnp.searchsorted(cum, pool_rank, side="right"),
                        0, m - 1).astype(jnp.int32)

    srv = jnp.where(stay, best_i, pool_srv)
    tier = jnp.where(stay, best_t, k - 1)
    inc = pending.astype(jnp.int32)
    return bp.PandasState(q=s.q.at[srv, tier].add(inc), serving=s.serving)


def _route_batch_po2(s: bp.PandasState, est, ctx: FleetCtx, locs, active,
                     key: jax.Array, d_choices: int):
    """One snapshot round of power-of-d fleet routing: each task argmins
    over {3 locals} ∪ {d uniform candidates} directly (remote candidates
    allowed — no pool is needed, the d samples spread load by
    construction)."""
    m, k = ctx.num_servers, ctx.num_tiers
    batch = locs.shape[0]
    w = bp.workload(s, est)
    cand = jnp.floor(jax.random.uniform(key, (batch, d_choices)) * m
                     ).astype(jnp.int32)
    cand = jnp.minimum(cand, m - 1)
    cset = jnp.concatenate([locs, cand], axis=1)           # (B, 3+d)
    tier = jnp.full(cset.shape, k - 1, jnp.int32)
    for lvl in range(ctx.depth - 1, -1, -1):
        row = ctx.gids[lvl]
        share = jnp.any(row[cset][:, :, None] == row[locs][:, None, :],
                        axis=-1)
        tier = jnp.where(share, lvl + 1, tier)
    tier = jnp.where(jnp.any(cset[:, :, None] == locs[:, None, :], axis=-1),
                     0, tier)
    rate = est[cset, tier]                                 # (B, 3+d)
    score = w[cset] / rate - rate * 1e-6
    j = jnp.argmin(score, axis=1)
    rows = jnp.arange(batch)
    srv = cset[rows, j]
    inc = active.astype(jnp.int32)
    return bp.PandasState(q=s.q.at[srv, tier[rows, j]].add(inc),
                          serving=s.serving)


# ---------------------------------------------------------------------------
# Chunked donated-carry runner
# ---------------------------------------------------------------------------


def _build_fleet_chunk(policy_like: PolicyLike, cfg, fc: FleetConfig):
    """Returns (init() -> carry, chunk(carry, t0, lam, est, seed) -> carry).

    carry = (q (M,K) i32, serving (M,) i32, mean_n f32, n_meas f32,
    completions i32).  `chunk` advances `fc.chunk` slots starting at slot
    t0; slots at t >= horizon are frozen (the carry re-selected), so the
    tail chunk reuses the same compiled program.  Jit it with
    ``donate_argnums=0`` and drive the horizon from a Python loop.
    """
    policy = make_policy(policy_like)
    if policy.name not in _SUPPORTED_POLICIES:
        raise ValueError(f"policy {policy.name!r} has no fleet step "
                         f"(supported: {_SUPPORTED_POLICIES})")
    d_choices = int(getattr(policy, "d", 0))
    ctx = make_ctx(cfg.topo)
    m, k = ctx.num_servers, ctx.num_tiers
    batch = cfg.max_arrivals
    true_k = cfg.true_rates.as_array()
    p_hot = float(cfg.p_hot)
    horizon, warmup = cfg.horizon, cfg.warmup
    use_pallas = kops._on_tpu() if fc.use_pallas is None else fc.use_pallas

    def init():
        return (jnp.zeros((m, k), jnp.int32), jnp.zeros((m,), jnp.int32),
                jnp.float32(0.0), jnp.float32(0.0), jnp.int32(0))

    def chunk(carry, t0, lam, est, seed):
        base_key = jax.random.PRNGKey(seed)

        def step(c, t):
            q, serving, mean_n, n_meas, compl = c
            s = bp.PandasState(q, serving)
            key_t = jax.random.fold_in(base_key, t)
            k_arr, k_algo = jax.random.split(key_t)
            types, active = _sample_arrivals(k_arr, ctx, lam, p_hot, batch)
            k_route, k_serve = jax.random.split(k_algo)
            if policy.name == "pandas_po2":
                s = _route_batch_po2(s, est, ctx, types, active, k_route,
                                     d_choices)
            else:
                s = _route_batch_pandas(s, est, ctx, types, active, fc,
                                        use_pallas)
            s, compl_t = bp.serve_and_schedule(s, k_serve, true_k)
            n = (jnp.sum(s.q) + jnp.sum(s.serving > 0)).astype(jnp.float32)
            in_w = (t >= warmup).astype(jnp.float32)
            n_meas2 = n_meas + in_w
            mean_n2 = mean_n + in_w * (n - mean_n) / jnp.maximum(n_meas2, 1.0)
            compl2 = compl + compl_t * (t >= warmup)
            new = (s.q, s.serving, mean_n2, n_meas2, compl2)
            live = t < horizon
            return tuple(jnp.where(live, a, b) for a, b in zip(new, c)), ()

        carry, _ = jax.lax.scan(step, carry, t0 + jnp.arange(fc.chunk),
                                unroll=fc.unroll)
        return carry

    return init, chunk


def _finalize(carry_np, lam_total) -> Dict[str, Any]:
    """Metrics dict (same keys as the dense path) from a final carry."""
    q, serving, mean_n, n_meas, compl = carry_np
    denom = np.float32(lam_total)  # static scenario: lam_scale == 1
    mean_delay = np.where(denom > 0, mean_n / denom, np.nan)
    return {
        "mean_n": mean_n,
        "mean_delay": mean_delay,
        "throughput": compl / np.maximum(n_meas, 1.0),
        "final_n": (q.sum(axis=(-2, -1))
                    + (serving > 0).sum(axis=-1)).astype(np.float32),
    }


# Keyed cache of jitted chunk closures: repeated fleet_simulate calls
# with the same (policy, cfg, fleet) settings — a seed study, the test
# suite's band runs — would otherwise retrace AND recompile every call,
# and the fleet chunk compile is ~8 s at M=10008 on one core.  The key
# is the dataclass reprs (all three are frozen value types), so a config
# change can never alias a stale program.
_CHUNK_CACHE: Dict[Tuple[str, str, str], Any] = {}


def _jitted_chunk(policy: PolicyLike, cfg, fc: FleetConfig):
    key = (repr(policy), repr(cfg), repr(fc))
    hit = _CHUNK_CACHE.get(key)
    if hit is None:
        init, chunk = _build_fleet_chunk(policy, cfg, fc)
        hit = (init, jax.jit(chunk, donate_argnums=0))
        _CHUNK_CACHE[key] = hit
    return hit


def fleet_simulate(policy: PolicyLike, cfg, lam_total: float, est,
                   seed: int = 0,
                   fleet: FleetLike = None) -> Dict[str, Any]:
    """Fleet-path analogue of `core.simulator.simulate` (static scenario,
    uniform placement).  Same metrics keys; scalars come back as floats."""
    if lam_total < 0:
        raise ValueError(f"lam_total must be >= 0, got {lam_total}")
    fc = as_fleet_config(fleet)
    init, fn = _jitted_chunk(policy, cfg, fc)
    carry = init()
    lam = jnp.float32(lam_total)
    est = jnp.asarray(est, jnp.float32)
    seed = jnp.asarray(seed, jnp.uint32)
    for ci in range(-(-cfg.horizon // fc.chunk)):
        carry = fn(carry, jnp.int32(ci * fc.chunk), lam, est, seed)
    out = _finalize(tuple(np.asarray(x) for x in carry), lam_total)
    return {k: float(v) for k, v in out.items()}


def fleet_sweep(policy: PolicyLike, cfg, lam_grid, est_stack, seeds,
                fleet: FleetLike = None) -> Dict[str, np.ndarray]:
    """Fleet-path analogue of `core.simulator.sweep`: (L, E, S) metrics.

    The (load x error x seed) grid is flattened and vmapped through the
    chunk function — one compile amortizes across the whole study."""
    lam_grid = np.asarray(lam_grid, np.float32)
    est_stack = np.asarray(est_stack, np.float32)
    seeds = np.asarray(seeds, np.uint32)
    if np.any(lam_grid < 0):
        raise ValueError(f"lam_grid must be >= 0, got {lam_grid}")
    fc = as_fleet_config(fleet)
    init, chunk = _build_fleet_chunk(policy, cfg, fc)
    nl, ne, ns = len(lam_grid), len(est_stack), len(seeds)
    n = nl * ne * ns
    lam_b = jnp.asarray(np.repeat(lam_grid, ne * ns))
    est_b = jnp.asarray(np.tile(np.repeat(est_stack, ns, axis=0), (nl, 1, 1)))
    seed_b = jnp.asarray(np.tile(seeds, nl * ne))
    fn = jax.jit(jax.vmap(chunk, in_axes=(0, None, 0, 0, 0)),
                 donate_argnums=0)
    carry = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape), init())
    for ci in range(-(-cfg.horizon // fc.chunk)):
        carry = fn(carry, jnp.int32(ci * fc.chunk), lam_b, est_b, seed_b)
    out = _finalize(tuple(np.asarray(x) for x in carry),
                    np.asarray(lam_b))
    return {k: np.asarray(v).reshape(nl, ne, ns) for k, v in out.items()}
