"""Unified observability layer: one subsystem, two projections.

1. **In-scan recorders** (`recorder.py`) — fixed-shape, `lax.scan`-safe
   tracks compiled into the simulator's slot step when a
   `TelemetryConfig` is passed (``telemetry=`` on
   `simulate`/`sweep`/`run_study`): downsampled time series, a FIFO-
   coupled task-sojourn histogram, and a queue-length histogram, from
   which p50/p95/p99 delay and the queue-length distribution flow out as
   metrics keys.  With ``telemetry=None`` nothing is compiled and sample
   paths stay bitwise (pure observation even when on: no random bits
   consumed).

2. **Host-side event tracing** (`events.py`) — a ring-buffered
   `EventRecorder` the serving engine, the data pipeline, the host
   replication lifecycle and the benches emit typed events into, with a
   Chrome trace-event JSON exporter viewable in Perfetto and a
   span/timer hook for kernel-vs-host time attribution.

See docs/observability.md for recorder configuration, the histogram
error bound, and the trace-event schema.
"""

from repro.telemetry.events import (CLOCK_UNIT_US, EventRecorder, load_trace,
                                    maybe_span, validate_chrome_trace)
from repro.telemetry.recorder import (OVERFLOW_WARN_FRAC,
                                      TELEMETRY_METRIC_KEYS, SimTelemetry,
                                      TelemetryConfig, TelemetryLike,
                                      TelState, as_telemetry_config,
                                      fcfs_sojourns, maybe_warn_overflow,
                                      percentiles_from_hist)

__all__ = [
    "CLOCK_UNIT_US", "EventRecorder", "load_trace", "maybe_span",
    "validate_chrome_trace", "OVERFLOW_WARN_FRAC", "TELEMETRY_METRIC_KEYS",
    "SimTelemetry", "TelemetryConfig", "TelemetryLike", "TelState",
    "as_telemetry_config", "fcfs_sojourns", "maybe_warn_overflow",
    "percentiles_from_hist",
]
