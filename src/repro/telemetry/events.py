"""Host-side structured event tracing with a Chrome trace-event exporter.

One `EventRecorder` per run: consumers (`serve/engine.py`,
`data/pipeline.py`, `replication/host.py`, the benches) emit typed
events — route decisions, admissions, replica reads, failovers,
migration starts/commits, failure windows, kernel-dispatch spans — into
a bounded ring buffer (a deque: the newest `capacity` events win, and
the eviction count is reported, never hidden).  `to_chrome()` serializes
the buffer as Chrome trace-event JSON, the format Perfetto
(https://ui.perfetto.dev) and `chrome://tracing` load directly.

Timestamps are microseconds (`ts`/`dur`), per the trace-event spec.
Emitters on a virtual clock (engine steps, the pipeline's virtual time)
pass explicit ``ts_us`` values — the convention throughout this repo is
ONE CLOCK UNIT = 1 ms, i.e. ``ts_us = clock * 1000`` — while wall-clock
spans (`span`, the kernel-dispatch timer in the benches) use a
`perf_counter` anchored at recorder construction.  Phase codes used:
``X`` complete (ts + dur), ``i`` instant, ``C`` counter, ``M`` metadata.
"""

from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

#: trace-event phases this recorder emits / the validator accepts
PHASES = ("X", "B", "E", "i", "I", "C", "M")

#: per-microsecond scale for emitters on a step/virtual clock (1 unit = 1 ms)
CLOCK_UNIT_US = 1000.0


class EventRecorder:
    """Ring-buffered trace-event sink shared by every host-side emitter."""

    def __init__(self, capacity: int = 65_536, pid: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.pid = pid
        self._events: deque = deque(maxlen=capacity)
        self.emitted = 0
        self._t0 = time.perf_counter()

    # -- clocks -------------------------------------------------------------
    def now_us(self) -> float:
        """Wall-clock microseconds since recorder construction."""
        return (time.perf_counter() - self._t0) * 1e6

    # -- emitters -----------------------------------------------------------
    def _push(self, ev: Dict[str, Any]) -> None:
        self.emitted += 1
        self._events.append(ev)

    def instant(self, name: str, cat: str = "event",
                ts_us: Optional[float] = None, tid: int = 0,
                **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": float(self.now_us() if ts_us is None else ts_us),
                    "pid": self.pid, "tid": int(tid), "args": dict(args)})

    def counter(self, name: str, value: float, cat: str = "counter",
                ts_us: Optional[float] = None, tid: int = 0) -> None:
        self._push({"name": name, "cat": cat, "ph": "C",
                    "ts": float(self.now_us() if ts_us is None else ts_us),
                    "pid": self.pid, "tid": int(tid),
                    "args": {"value": float(value)}})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 cat: str = "span", tid: int = 0, **args: Any) -> None:
        self._push({"name": name, "cat": cat, "ph": "X",
                    "ts": float(ts_us), "dur": float(max(dur_us, 0.0)),
                    "pid": self.pid, "tid": int(tid), "args": dict(args)})

    def metadata(self, name: str, /, tid: int = 0, **args: Any) -> None:
        """Perfetto naming events, e.g.
        ``metadata("thread_name", tid=3, name="replica3")`` (the event
        name is positional-only so ``name=`` lands in args)."""
        self._push({"name": name, "ph": "M", "ts": 0.0, "pid": self.pid,
                    "tid": int(tid), "args": dict(args)})

    @contextmanager
    def span(self, name: str, cat: str = "host", tid: int = 0, **args: Any):
        """Wall-clock span: wraps a host-side region (e.g. the Pallas
        kernel dispatch path in the benches) as one complete event."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, self.now_us() - t0, cat=cat, tid=tid,
                          **args)

    # -- introspection / export ---------------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound (emitted - retained)."""
        return self.emitted - len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"emitted": self.emitted, "dropped": self.dropped,
                          "capacity": self.capacity},
        }

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
            f.write("\n")
        return path


def maybe_span(tracer: Optional[EventRecorder], name: str,
               cat: str = "host", tid: int = 0, **args: Any):
    """`tracer.span(...)` or a no-op context when tracing is off — the
    zero-overhead guard every instrumented call site uses."""
    if tracer is None:
        return nullcontext()
    return tracer.span(name, cat=cat, tid=tid, **args)


def validate_chrome_trace(doc: Any) -> None:
    """Raise ValueError unless `doc` is a loadable Chrome trace-event
    object (the schema check the tests pin: Perfetto's JSON importer
    requires exactly these fields)."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with a 'traceEvents' list")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for key, types in (("name", str), ("ph", str),
                           ("ts", (int, float)), ("pid", int), ("tid", int)):
            if not isinstance(ev.get(key), types):
                raise ValueError(f"event {i} ({ev.get('name')!r}) is "
                                 f"missing/mistyped field {key!r}")
        if ev["ph"] not in PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"complete event {i} ({ev['name']!r}) "
                             f"has no numeric 'dur'")


def load_trace(path: Union[str, Path]) -> Dict[str, Any]:
    """Load + validate a saved Chrome trace JSON file."""
    with open(path) as f:
        doc = json.load(f)
    validate_chrome_trace(doc)
    return doc
