"""In-scan telemetry recorders: fixed-shape tracks inside the `lax.scan`.

The simulator's metrics are end-of-run scalar means, but the paper's
heavy-traffic claims are statements about *distributions* — of task delay
and of queue length.  This module records both inside the scan without
breaking any of its invariants:

  * every buffer is fixed-shape (scan carry), so `sweep()` still vmaps
    the whole (load x error x seed) grid over it;
  * recording consumes NO random bits — the arrival/routing/service
    streams keep their exact keys, so enabling telemetry cannot perturb
    a sample path (pure observation; pinned in tests/test_telemetry.py);
  * with ``telemetry=None`` the simulator compiles none of this
    (PR 6's fixed+static passthrough discipline).

Sojourn times without per-task identity: every policy stores anonymous
queue *counts*, so the recorder pairs the i-th admitted task with the
i-th completion — a FIFO coupling over a ring buffer of arrival slots.
The histogram MEAN is pairing-invariant (the multiset sum of sojourns
equals the sum over slots of tasks-in-system, whatever the pairing), so
it matches the simulator's Little's-law `mean_delay`; quantiles are
reported under the FIFO coupling, which is exact for FIFO and the
standard virtual-delay proxy for the others.  Admissions are inferred
from the policy state itself (``n_after - n_before + completions``), so
FIFO's dropped arrivals never enter the ring.

Percentile estimates come from a fixed-bin histogram: the reported
quantile is the UPPER EDGE of the bin containing it, so the estimate
exceeds the exact order statistic by at most one bin width
(``hist_max / hist_bins`` slots) — the error bound docs/observability.md
documents and the tests assert.  Sojourns beyond ``hist_max`` land in an
overflow bin; a quantile falling there reports ``inf`` (raise
``hist_max``) rather than a silently-clamped number.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Recorder shapes (all static: they fix the scan-carry buffers).

    stride        -- time-series downsample stride in slots (1 = dense)
    hist_bins     -- sojourn-histogram regular bins (+1 overflow bin)
    hist_max      -- sojourn (slots) where the overflow bin starts
    qhist_bins    -- queue-length-histogram regular bins (+1 overflow)
    qhist_max     -- queue length where the overflow bin starts
    ring_capacity -- FIFO arrival-slot ring size; admissions beyond a
                     full ring are dropped from pairing (and counted in
                     ``telemetry_dropped`` — no silent truncation)

    The defaults give a sojourn bin width of exactly 1 slot; sojourns are
    integer slot counts, so up to ``hist_max`` the percentile estimate is
    the exact order statistic plus one bin width.  Raise ``hist_max``
    (or widen bins) for heavy-traffic runs whose tails pass 256 slots.
    """

    stride: int = 16
    hist_bins: int = 256
    hist_max: float = 256.0
    qhist_bins: int = 128
    qhist_max: float = 512.0
    ring_capacity: int = 4096

    def __post_init__(self):
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")
        if self.hist_bins < 1 or self.qhist_bins < 1:
            raise ValueError("hist_bins/qhist_bins must be >= 1")
        if self.hist_max <= 0 or self.qhist_max <= 0:
            raise ValueError("hist_max/qhist_max must be > 0")
        if self.ring_capacity < 1:
            raise ValueError(
                f"ring_capacity must be >= 1, got {self.ring_capacity}")

    @property
    def bin_width(self) -> float:
        """Sojourn-histogram bin width (slots) == the percentile error
        bound."""
        return float(self.hist_max) / self.hist_bins

    @property
    def qbin_width(self) -> float:
        return float(self.qhist_max) / self.qhist_bins


TelemetryLike = Union[None, bool, TelemetryConfig]

#: metric keys `SimTelemetry.metrics` adds to the simulator output dict
TELEMETRY_METRIC_KEYS = (
    "delay_p50", "delay_p95", "delay_p99", "delay_hist", "delay_overflow_frac",
    "queue_len_hist", "series", "telemetry_dropped", "telemetry_unmatched",
)

#: overflow fraction past which the summary warns (sojourn tails beyond
#: ``hist_max`` clamp any quantile landing there to inf)
OVERFLOW_WARN_FRAC = 0.01


def as_telemetry_config(spec: TelemetryLike) -> TelemetryConfig:
    """None/False -> disabled is handled by the caller; True -> defaults."""
    if spec is True:
        return TelemetryConfig()
    if isinstance(spec, TelemetryConfig):
        return spec
    raise TypeError(f"telemetry must be None, True, or a TelemetryConfig; "
                    f"got {spec!r}")


class TelState(NamedTuple):
    """Recorder state threaded through the scan carry (fixed shapes)."""

    ring: jnp.ndarray       # (B,) int32 arrival slots, FIFO order
    head: jnp.ndarray       # () int32 index of oldest entry
    count: jnp.ndarray      # () int32 entries in the ring
    delay_hist: jnp.ndarray  # (H+1,) int32 sojourn counts (+overflow)
    qlen_hist: jnp.ndarray   # (Q+1,) int32 queue-length counts (+overflow)
    series: jnp.ndarray      # (T_s, n_tracks) f32 downsampled point samples
    dropped: jnp.ndarray     # () int32 admissions not ringed (ring full)
    unmatched: jnp.ndarray   # () int32 in-window completions not binned


class SimTelemetry:
    """Compiled recorder for one (config, horizon, policy-track) tuple."""

    BASE_TRACKS: Tuple[str, ...] = ("n_in_system", "admitted", "completions")

    def __init__(self, cfg: TelemetryConfig, horizon: int, warmup: int,
                 num_servers: int, max_arrivals: int,
                 extra_tracks: Sequence[str] = ()):
        need = max(int(max_arrivals), int(num_servers))
        if cfg.ring_capacity < need:
            raise ValueError(
                f"ring_capacity ({cfg.ring_capacity}) must be >= "
                f"max(max_arrivals, num_servers) = {need} so one slot's "
                f"pushes/pops hit distinct ring indices")
        extra = tuple(extra_tracks)
        clash = set(extra) & set(self.BASE_TRACKS)
        if clash:
            raise ValueError(f"telemetry track names collide with the "
                             f"base tracks: {sorted(clash)}")
        if len(set(extra)) != len(extra):
            raise ValueError(f"duplicate telemetry track names: {extra}")
        self.cfg = cfg
        self.horizon = int(horizon)
        self.warmup = int(warmup)
        self.max_arrivals = int(max_arrivals)
        self.num_servers = int(num_servers)
        self.extra_tracks = extra
        self.track_names: Tuple[str, ...] = self.BASE_TRACKS + extra
        self.n_samples = -(-self.horizon // cfg.stride)  # ceil division

    # -- scan-side ----------------------------------------------------------
    def init(self) -> TelState:
        i32, f32 = jnp.int32, jnp.float32
        c = self.cfg
        return TelState(
            ring=jnp.zeros(c.ring_capacity, i32),
            head=jnp.zeros((), i32),
            count=jnp.zeros((), i32),
            delay_hist=jnp.zeros(c.hist_bins + 1, i32),
            qlen_hist=jnp.zeros(c.qhist_bins + 1, i32),
            series=jnp.zeros((self.n_samples, len(self.track_names)), f32),
            dropped=jnp.zeros((), i32),
            unmatched=jnp.zeros((), i32),
        )

    def record(self, st: TelState, t, admitted, completions, n_now,
               extras: Dict[str, jnp.ndarray]) -> TelState:
        """One slot of observation.  `admitted`/`completions`/`n_now` are
        int32 scalars for slot `t` (admissions pushed before completions
        are popped, matching the simulator's arrivals-then-service phase
        order: a task admitted and completed in the same slot has
        sojourn 0).  `extras` must carry exactly the extra tracks this
        recorder was built with."""
        if set(extras) != set(self.extra_tracks):
            raise ValueError(
                f"telemetry extras {sorted(extras)} do not match the "
                f"recorder's tracks {sorted(self.extra_tracks)}")
        i32, f32 = jnp.int32, jnp.float32
        c = self.cfg
        B = c.ring_capacity
        t = t.astype(i32)
        in_w = (t >= self.warmup).astype(i32)
        a = jnp.clip(admitted.astype(i32), 0, self.max_arrivals)
        compl = jnp.clip(completions.astype(i32), 0, self.num_servers)

        # push admissions (FIFO tail), dropping what the ring cannot hold
        pushes = jnp.minimum(a, B - st.count)
        lane = jnp.arange(self.max_arrivals, dtype=i32)
        idx = (st.head + st.count + lane) % B
        put = lane < pushes
        ring = st.ring.at[idx].set(jnp.where(put, t, st.ring[idx]))
        count = st.count + pushes
        dropped = st.dropped + (a - pushes)

        # pop completions (FIFO head) and bin their sojourns
        pops = jnp.minimum(compl, count)
        lane_m = jnp.arange(self.num_servers, dtype=i32)
        idx_m = (st.head + lane_m) % B
        take = lane_m < pops
        soj = (t - ring[idx_m]).astype(f32)
        bins = jnp.clip((soj / c.bin_width).astype(i32), 0, c.hist_bins)
        weight = (take & (in_w > 0)).astype(i32)
        delay_hist = st.delay_hist.at[bins].add(weight)
        unmatched = st.unmatched + in_w * (compl - pops)
        head = (st.head + pops) % B
        count = count - pops

        # queue-length distribution over the measurement window
        qbin = jnp.clip((n_now.astype(f32) / c.qbin_width).astype(i32),
                        0, c.qhist_bins)
        qlen_hist = st.qlen_hist.at[qbin].add(in_w)

        # downsampled point samples: slot t lands at row t // stride
        vals = [n_now.astype(f32), a.astype(f32), compl.astype(f32)]
        vals += [jnp.asarray(extras[k], f32) for k in self.extra_tracks]
        row_idx = t // c.stride
        sample = (t % c.stride == 0)
        row = jnp.where(sample, jnp.stack(vals), st.series[row_idx])
        series = st.series.at[row_idx].set(row)

        return TelState(ring=ring, head=head, count=count,
                        delay_hist=delay_hist, qlen_hist=qlen_hist,
                        series=series, dropped=dropped, unmatched=unmatched)

    def metrics(self, st: TelState) -> Dict[str, jnp.ndarray]:
        """End-of-run telemetry metrics (in-graph, so `sweep` vmaps them)."""
        f32 = jnp.float32
        hist = st.delay_hist.astype(f32)
        w = jnp.float32(self.cfg.bin_width)
        return {
            "delay_p50": _hist_quantile(hist, w, 0.50),
            "delay_p95": _hist_quantile(hist, w, 0.95),
            "delay_p99": _hist_quantile(hist, w, 0.99),
            "delay_hist": hist,
            "delay_overflow_frac": hist[-1] / jnp.maximum(jnp.sum(hist), 1.0),
            "queue_len_hist": st.qlen_hist.astype(f32),
            "series": st.series,
            "telemetry_dropped": st.dropped.astype(f32),
            "telemetry_unmatched": st.unmatched.astype(f32),
        }

    def live_quantile(self, st: TelState, q: float) -> jnp.ndarray:
        """Running sojourn quantile over everything binned SO FAR — the
        in-scan signal SLO-conditioned policies read mid-run.  NaN until
        the first completion is binned (comparisons are False -> no
        breach) and inf while the quantile sits in the overflow bin (any
        finite target reads as breached — correct: the tail has already
        passed ``hist_max``)."""
        return _hist_quantile(st.delay_hist.astype(jnp.float32),
                              jnp.float32(self.cfg.bin_width), q)


def _hist_quantile(hist: jnp.ndarray, width, q: float) -> jnp.ndarray:
    """Upper edge of the bin holding quantile `q` (NaN on an empty
    histogram, inf when it falls in the overflow bin)."""
    c = jnp.cumsum(hist)
    total = c[-1]
    idx = jnp.argmax(c >= q * total)
    val = (idx.astype(jnp.float32) + 1.0) * width
    val = jnp.where(idx >= hist.shape[0] - 1, jnp.inf, val)
    return jnp.where(total > 0, val, jnp.nan)


# -- host-side reference helpers (numpy; used by tests, docs, studies) ------

def percentiles_from_hist(counts: np.ndarray, bin_width: float,
                          qs: Sequence[float]) -> np.ndarray:
    """Numpy mirror of the in-graph quantile: upper bin edge per q."""
    counts = np.asarray(counts, np.float64)
    c = np.cumsum(counts)
    total = c[-1]
    out = np.empty(len(qs))
    for i, q in enumerate(qs):
        if total <= 0:
            out[i] = np.nan
            continue
        idx = int(np.argmax(c >= q * total))
        out[i] = np.inf if idx >= len(counts) - 1 else (idx + 1) * bin_width
    return out


def maybe_warn_overflow(overflow_frac: float, cfg: TelemetryConfig) -> bool:
    """Warn (stdlib `warnings`) when more than `OVERFLOW_WARN_FRAC` of the
    binned sojourns landed in the overflow bin — at that point any
    quantile >= 1 - overflow_frac reports inf rather than a number, and
    the histogram mean is silently clamped.  Suggests a 4x ``hist_max``
    (same bin count: 4x coarser bins, still a documented error bound).
    Returns whether it warned, so drivers/tests can assert on it."""
    frac = float(overflow_frac)
    if not np.isfinite(frac) or frac <= OVERFLOW_WARN_FRAC:
        return False
    import warnings
    warnings.warn(
        f"{100.0 * frac:.1f}% of recorded sojourns exceeded "
        f"hist_max={cfg.hist_max:g} (overflow bin); percentiles at or above "
        f"q={1.0 - frac:.3f} report inf. Rerun with a larger histogram "
        f"range, e.g. TelemetryConfig(hist_max={4.0 * cfg.hist_max:g}, "
        f"hist_bins={cfg.hist_bins}).",
        RuntimeWarning, stacklevel=2)
    return True


def fcfs_sojourns(admitted: np.ndarray,
                  completions: np.ndarray) -> np.ndarray:
    """Exact sojourns under the same FIFO coupling the in-scan recorder
    uses, reconstructed from DENSE (stride=1) per-slot admission and
    completion counts: the i-th admission pairs with the i-th completion.
    Unpaired admissions (still in system at the end) are censored."""
    a = np.asarray(admitted).astype(np.int64)
    c = np.asarray(completions).astype(np.int64)
    arr = np.repeat(np.arange(len(a)), a)
    dep = np.repeat(np.arange(len(c)), c)
    n = min(len(arr), len(dep))
    return (dep[:n] - arr[:n]).astype(np.int64)
