"""Training loop: data pipeline + sharded train_step + checkpointing +
straggler-aware input scheduling.

The loop composes the substrates: the locality-aware DataPipeline feeds
global batches; the jitted train_step (launch/steps.py) runs them; the
Checkpointer commits atomically every `ckpt_every` steps; per-step host
timings feed the pipeline's EWMA estimator so a straggling data host sheds
load mid-run (the paper's robustness property, live in the input path).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.launch import mesh as mesh_lib, steps as steps_lib
from repro.models import params as params_lib
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 256
    global_batch: int = 8
    steps: int = 50
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, mesh,
                 plan: steps_lib.RuntimePlan,
                 pipeline: Optional[DataPipeline] = None):
        self.cfg, self.tcfg, self.mesh, self.plan = cfg, tcfg, mesh, plan
        self.pipeline = pipeline or DataPipeline(PipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))
        (self.step_fn, self._astate, self._abatch,
         (self.state_sh, self.batch_sh)) = steps_lib.build_train_step(
            cfg, mesh, plan, tcfg.global_batch, tcfg.seq_len)
        self.ckpt = (Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None)
        self.state: Optional[steps_lib.TrainState] = None
        self.history: List[Dict] = []

    # -- state ----------------------------------------------------------------
    def init_state(self) -> None:
        key = jax.random.PRNGKey(self.tcfg.seed)
        with self.mesh:
            params = jax.jit(
                lambda k: params_lib.init_params(self.cfg, k),
                out_shardings=self.state_sh.params)(key)
            opt = jax.jit(
                lambda p: adamw.init(self.plan.opt, p),
                out_shardings=self.state_sh.opt)(params)
        self.state = steps_lib.TrainState(params, opt, jnp.int32(0))

    def restore_or_init(self) -> int:
        if self.ckpt and self.ckpt.latest_step() is not None:
            template = jax.tree.map(
                lambda s: np.zeros(s.shape, s.dtype), self._astate)
            self.state = self.ckpt.restore(template,
                                           shardings=self.state_sh)
            return int(self.state.step)
        self.init_state()
        return 0

    # -- loop -----------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> List[Dict]:
        steps = steps or self.tcfg.steps
        if self.state is None:
            self.restore_or_init()
        start = int(self.state.step)
        for i in range(start, start + steps):
            t0 = time.monotonic()
            batch = next(self.pipeline)
            with self.mesh:
                self.state, metrics = self.step_fn(
                    self.state, jax.tree.map(jnp.asarray, batch))
            if (i + 1) % self.tcfg.log_every == 0 or i == start:
                rec = {"step": i + 1,
                       "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "wall_s": time.monotonic() - t0,
                       "data_locality": self.pipeline.locality_fractions}
                self.history.append(rec)
            if self.ckpt and (i + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(i + 1, self.state,
                               metadata={"pipeline":
                                         _np_to_list(
                                             self.pipeline.state_dict())})
        return self.history


def _np_to_list(v):
    """JSON-safe view of a pipeline state dict: numpy arrays and scalars
    become lists / plain Python numbers at every nesting level — the
    replication-lifecycle state is a dict of dicts, and json.dumps of the
    checkpoint manifest rejects any numpy type it meets."""
    if isinstance(v, dict):
        return {k: _np_to_list(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_np_to_list(x) for x in v]
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return v
