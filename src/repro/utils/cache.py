"""Opt-in JAX persistent compilation cache.

The fleet chunk program at M=10008 takes minutes to compile on one CPU
core; across bench runs and test sessions the program is byte-identical,
so the XLA compilation cache turns every run after the first into a disk
read.  Opt in by exporting

    REPRO_JAX_CACHE_DIR=/path/to/cache

before running ``benchmarks/run.py`` or the test suite (tests/conftest.py
calls `enable_persistent_cache()` at collection time).  Unset, this module
does nothing — CI machines with ephemeral disks and single-shot runs pay
no cache-write overhead.
"""

from __future__ import annotations

import os
from pathlib import Path

_ENV_VAR = "REPRO_JAX_CACHE_DIR"
_enabled_dir: str | None = None


def enable_persistent_cache() -> str | None:
    """Point JAX's compilation cache at ``$REPRO_JAX_CACHE_DIR``.

    Returns the cache directory if enabled (creating it if needed), else
    None.  Idempotent — safe to call from several entry points.
    """
    global _enabled_dir
    cache_dir = os.environ.get(_ENV_VAR)
    if not cache_dir:
        return None
    if _enabled_dir == cache_dir:
        return _enabled_dir
    Path(cache_dir).mkdir(parents=True, exist_ok=True)
    import jax

    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # cache everything, including sub-second compiles: the suite's many
    # small jit programs add up on one core
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    _enabled_dir = cache_dir
    return _enabled_dir
