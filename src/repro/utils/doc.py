"""Docstring introspection shared by the self-describing registries
(`core.policy`, `workloads.scenario`)."""

from __future__ import annotations

from typing import Any


def first_doc_line(obj: Any) -> str:
    """First period-terminated sentence (or line) of `obj`'s docstring,
    whitespace-collapsed; empty string when undocumented."""
    doc = (obj.__doc__ or "").strip()
    if not doc:
        return ""
    head = doc.split(". ", 1)[0].split(".\n", 1)[0]
    return " ".join(head.split()).rstrip(".") + "."
