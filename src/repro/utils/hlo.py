"""Post-SPMD HLO analyzer: per-device FLOPs / HBM bytes / collective traffic
with correct while-loop (layer-scan) trip-count multiplication.

Why not compiled.cost_analysis()?  XLA's HloCostAnalysis visits a while body
ONCE — a 48-layer scanned model under-counts ~48x (verified empirically).
The optimized HLO text, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on every while op, so this
module parses the per-device HLO module into computations, walks the call
graph from ENTRY, and accumulates:

  flops   — 2*M*N*K for every dot (incl. dots inside fusions), conv flops,
            + 1/elem for elementwise fusions (minor)
  bytes   — Σ (operands + result) buffer bytes per *top-level* op: fusions
            count their boundary buffers only, which is precisely the
            post-fusion HBM-traffic model a roofline wants
  collectives — ring-model per-device link traffic:
            all-gather/reduce-scatter/all-to-all: (n-1)/n * bytes
            all-reduce: 2 (n-1)/n * bytes ; collective-permute: bytes

compiled.as_text() is the per-device program, so all shapes here are
per-device shapes.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HEADER = re.compile(r"^(%?[\w\.\-_]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-_]+)")
_COND_BODY = re.compile(r"body=%?([\w\.\-_]+)")
_GROUPS = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "reshape", "after-all", "iota", "partition-id", "replica-id",
    "opt-barrier", "rng-bit-generator",
}

COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, float]:
    elems, total = 0, 0.0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dcn_bytes: float = 0.0
    artifact_bytes: float = 0.0  # CPU-backend bf16<->f32 upcast fusions


@dataclasses.dataclass
class HloReport:
    flops: float
    bytes: float
    coll_counts: Dict[str, int]
    coll_bytes: Dict[str, float]
    dcn_bytes: float = 0.0
    artifact_bytes: float = 0.0

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def collective_count(self) -> int:
        return sum(self.coll_counts.values())

    def summary(self) -> str:
        lines = [f"  flops/device:        {self.flops:.3e}",
                 f"  hbm bytes/device:    {self.bytes:.3e}",
                 f"  collective traffic:  {self.collective_bytes:.3e} B"]
        for k in sorted(self.coll_counts):
            lines.append(f"    {k:20s} x{self.coll_counts[k]:<6d} "
                         f"{self.coll_bytes[k]:.3e} B")
        return "\n".join(lines)


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_HEADER.match(line.replace("ENTRY ", ""))
            if m and ("->" in line):
                current = m.group(1).lstrip("%")
                comps[current] = []
            continue
        if line.startswith("}") or line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[current].append(Instr(m.group(1), m.group(2), m.group(3),
                                        m.group(4)))
    return comps


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    return default


def _crosses_boundary(rest: str, boundary: Optional[int]) -> bool:
    """True if any replica group spans device ids on both sides of
    `boundary` (pod edge) — best-effort DCN attribution."""
    if boundary is None:
        return False
    m = _GROUPS.search(rest)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        return any(i < boundary for i in ids) and any(i >= boundary
                                                      for i in ids)
    return False


def _dot_flops(instr: Instr, types: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.result_type)
    lhs_name = re.findall(r"%([\w\.\-_]+)", instr.rest)
    k = 1
    m = _CONTRACT.search(instr.rest)
    if m and lhs_name and lhs_name[0] in types:
        dims_str = _SHAPE.search(types[lhs_name[0]])
        if dims_str:
            dims = [int(d) for d in dims_str.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci:
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(instr: Instr, types: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.result_type)
    ops = re.findall(r"%([\w\.\-_]+)", instr.rest)
    if len(ops) >= 2 and ops[1] in types:
        ksh = _SHAPE.search(types[ops[1]])
        if ksh:
            kdims = [int(d) for d in ksh.group(2).split(",") if d]
            kelems = 1
            for d in kdims:
                kelems *= d
            out_feat = kdims[-1] if kdims else 1
            return 2.0 * out_elems * kelems / max(out_feat, 1)
    return 2.0 * out_elems


def analyze(text: str, default_group: int = 2,
            pod_boundary: Optional[int] = None) -> HloReport:
    comps = parse_computations(text)
    types_per_comp: Dict[str, Dict[str, str]] = {
        c: {i.name: i.result_type for i in instrs}
        for c, instrs in comps.items()}
    memo: Dict[str, CompStats] = {}

    def walk(comp: str) -> CompStats:
        if comp in memo:
            return memo[comp]
        memo[comp] = CompStats()  # break cycles defensively
        st = CompStats()
        types = types_per_comp.get(comp, {})
        for ins in comps.get(comp, []):
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                _, size = _shape_elems_bytes(ins.result_type)
                n = _group_size(ins.rest, default_group)
                frac = (n - 1) / max(n, 1)
                factor = {"all-gather": frac, "all-reduce": 2 * frac,
                          "reduce-scatter": frac, "all-to-all": frac,
                          "collective-permute": 1.0}[base]
                st.coll_counts[base] += 1
                st.coll_bytes[base] += size * factor
                if _crosses_boundary(ins.rest, pod_boundary):
                    st.dcn_bytes += size * factor
                st.bytes += size
                continue
            if op.endswith("-done"):
                continue
            if op == "while":
                body = _COND_BODY.search(ins.rest)
                trips = 1
                mt = _TRIP.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                if body and body.group(1) in comps:
                    sub = walk(body.group(1))
                    st.flops += trips * sub.flops
                    st.bytes += trips * sub.bytes
                    st.dcn_bytes += trips * sub.dcn_bytes
                    st.artifact_bytes += trips * sub.artifact_bytes
                    for k, v in sub.coll_counts.items():
                        st.coll_counts[k] += trips * v
                    for k, v in sub.coll_bytes.items():
                        st.coll_bytes[k] += trips * v
                continue
            if op == "fusion":
                fbytes, fart, fflops = _fusion_cost(ins, types, comps,
                                                    types_per_comp, walk)
                st.bytes += fbytes
                st.artifact_bytes += fart
                st.flops += fflops
                continue
            if op in ("call", "custom-call", "conditional"):
                for name in _CALLS.findall(ins.rest):
                    if name in comps:
                        sub = walk(name)
                        st.flops += sub.flops
                        st.bytes += sub.bytes
                        st.dcn_bytes += sub.dcn_bytes
                        st.artifact_bytes += sub.artifact_bytes
                        for k, v in sub.coll_counts.items():
                            st.coll_counts[k] += v
                        for k, v in sub.coll_bytes.items():
                            st.coll_bytes[k] += v
                if op == "custom-call":
                    _, rbytes = _shape_elems_bytes(ins.result_type)
                    st.bytes += rbytes + _operand_bytes(ins, types)
                continue
            # In-place update/slice ops: XLA aliases the big buffer, so HBM
            # traffic is ~2x the touched slice, not the buffer size.
            if op in ("dynamic-update-slice", "scatter"):
                upd = _update_operand_bytes(ins, types, op)
                st.bytes += 2.0 * upd
                continue
            if op in ("dynamic-slice", "gather"):
                _, rbytes = _shape_elems_bytes(ins.result_type)
                st.bytes += 2.0 * rbytes
                continue
            if op == "dot":
                st.flops += _dot_flops(ins, types)
            elif op == "convolution":
                st.flops += _conv_flops(ins, types)
            elif op not in SKIP_BYTES_OPS:
                elems, _ = _shape_elems_bytes(ins.result_type)
                st.flops += elems  # ~1 flop/element for standalone elementwise
            if op in SKIP_BYTES_OPS:
                continue
            _, rbytes = _shape_elems_bytes(ins.result_type)
            st.bytes += rbytes + _operand_bytes(ins, types)
        memo[comp] = st
        return st

    # inside analyze(): dot flops inside non-entry computations used as
    # fusion bodies are picked up via walk(); find the entry computation.
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-_]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    st = walk(entry)
    return HloReport(st.flops, st.bytes, dict(st.coll_counts),
                     dict(st.coll_bytes), st.dcn_bytes, st.artifact_bytes)


def _operand_bytes(ins: Instr, types: Dict[str, str]) -> float:
    total = 0.0
    arglist = ins.rest.split(")")[0]
    for name in re.findall(r"%([\w\.\-_]+)", arglist):
        if name in types:
            _, b = _shape_elems_bytes(types[name])
            total += b
    return total


def _update_operand_bytes(ins: Instr, types: Dict[str, str], op: str) -> float:
    """Bytes of the update operand: dynamic-update-slice(buf, update, idx...)
    and scatter(buf, indices, updates)."""
    arglist = ins.rest.split(")")[0]
    names = re.findall(r"%([\w\.\-_]+)", arglist)
    idx = 1 if op == "dynamic-update-slice" else 2
    if len(names) > idx and names[idx] in types:
        _, b = _shape_elems_bytes(types[names[idx]])
        return b
    return 0.0


_PURE_MOVE_OPS = {"parameter", "convert", "bitcast", "copy", "reshape",
                  "transpose", "tuple", "get-tuple-element", "broadcast"}
_SLICE_OPS = {"dynamic-slice", "gather", "slice"}
_UPDATE_OPS = {"dynamic-update-slice", "scatter"}
_MOVE_THROUGH = {"bitcast", "convert", "copy", "reshape", "transpose"}
_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _fusion_cost(ins: Instr, types: Dict[str, str], comps, types_per_comp,
                 walk):
    """Interior-aware fusion HBM-traffic model.

    - A parameter whose (transitive, through pure-move ops) consumers are all
      slice/gather ops is charged at the slice-result sizes: the fused kernel
      reads only those regions (this is how per-layer slices of stacked scan
      buffers avoid being billed as full-buffer reads every iteration).
    - A DUS/scatter-rooted fusion writes only the update region: charge 2x
      the update operand, skip the aliased buffer.
    - Pure convert fusions (bf16<->f32 moves, no compute) are CPU-backend
      dot-upcast artifacts with no TPU equivalent: charged to the artifact
      bucket, excluded from the roofline memory term but reported.
    """
    _, rbytes = _shape_elems_bytes(ins.result_type)
    called = _CALLS.search(ins.rest)
    name = called.group(1) if called else None
    if name not in comps:
        return rbytes + _operand_bytes(ins, types), 0.0, rbytes / 2

    fcomp = comps[name]
    ftypes = types_per_comp[name]
    sub = walk(name)
    sub_flops = sub.flops

    ops_set = {i.op for i in fcomp}
    if ops_set <= _PURE_MOVE_OPS | {"constant"}:
        return 0.0, rbytes + _operand_bytes(ins, types), 0.0

    # parameter index -> interior name
    params_by_idx: Dict[int, str] = {}
    for fi in fcomp:
        if fi.op == "parameter":
            m = _PARAM_IDX.search(fi.op + "(" + fi.rest)
            m2 = re.search(r"^(\d+)\)", fi.rest)
            idx = int(m2.group(1)) if m2 else len(params_by_idx)
            params_by_idx[idx] = fi.name

    # direct consumers of each interior value
    direct: Dict[str, List[Instr]] = {}
    for fi in fcomp:
        for ref in re.findall(r"%([\w\.\-_]+)", fi.rest.split(")")[0]):
            direct.setdefault(ref, []).append(fi)

    def terminal_consumers(vname: str, depth: int = 0) -> List[Instr]:
        if depth > 12:
            return []
        out: List[Instr] = []
        for c in direct.get(vname, []):
            if c.op in _MOVE_THROUGH:
                out.extend(terminal_consumers(c.name, depth + 1))
            else:
                out.append(c)
        return out

    total = 0.0
    root = fcomp[-1] if fcomp else None
    dus_root = root is not None and (
        root.op in _UPDATE_OPS
        or (root.op == "convert" and any(i.op in _UPDATE_OPS for i in fcomp)))
    dus_buffer_vals = set()
    if dus_root:
        for fi in fcomp:
            if fi.op in _UPDATE_OPS:
                refs = re.findall(r"%([\w\.\-_]+)", fi.rest.split(")")[0])
                if refs:
                    dus_buffer_vals.add(refs[0])
                idx = 1 if fi.op == "dynamic-update-slice" else 2
                if len(refs) > idx and refs[idx] in ftypes:
                    total += 2.0 * _shape_elems_bytes(ftypes[refs[idx]])[1]

    arglist = ins.rest.split(")")[0]
    outer_args = re.findall(r"%([\w\.\-_]+)", arglist)
    for idx, outer in enumerate(outer_args):
        pname = params_by_idx.get(idx)
        if pname is None:
            continue
        term = terminal_consumers(pname)
        term_ops = {c.op for c in term}
        full = _shape_elems_bytes(types.get(outer, ftypes.get(pname, "")))[1]
        if dus_root and (pname in dus_buffer_vals or not term):
            # the aliased in-place buffer (or feeds only the DUS chain)
            if all(c.op in _UPDATE_OPS for c in term):
                continue
        if term and term_ops <= _SLICE_OPS:
            sliced = sum(_shape_elems_bytes(c.result_type)[1] for c in term)
            total += min(sliced, full)
        else:
            total += full
    if not dus_root:
        total += rbytes
    return total, 0.0, sub_flops
