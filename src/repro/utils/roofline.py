"""Three-term roofline model for TPU v5e (target hardware; see EXPERIMENTS.md).

    compute term    = FLOPs/device / PEAK_FLOPS
    memory term     = HBM bytes/device / HBM_BW
    collective term = link traffic/device / ICI_BW  (DCN hops budgeted
                      separately at DCN_BW when a "pod" axis is present)

The dominant term is the projected step-time lower bound; the reported
roofline fraction is MODEL_FLOPS-time / dominant-term-time, i.e. how close
the compiled program is to the best achievable given its own bottleneck.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e, per chip.
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (conservative single-link budget)
DCN_BW = 12.5e9          # bytes/s per host cross-pod (100 Gb/s NIC budget)
HBM_PER_CHIP = 16e9      # capacity


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    ici_bytes_per_device: float
    dcn_bytes_per_device: float = 0.0
    model_flops_per_device: float = 0.0
    # Analytic minimum HBM traffic for the algorithm (params once, cache
    # once, activation stream) — the memory-side analogue of MODEL_FLOPS.
    model_bytes_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.ici_bytes_per_device / ICI_BW \
            + self.dcn_bytes_per_device / DCN_BW

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO FLOPs — remat/redundancy waste detector."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def ideal_s(self) -> float:
        """Best achievable step time: the algorithm's inherent work at peak
        (useful FLOPs at peak MXU, or minimal HBM traffic at full bandwidth,
        whichever binds)."""
        return max(self.model_flops_per_device / PEAK_FLOPS,
                   self.model_bytes_per_device / HBM_BW)

    @property
    def roofline_fraction(self) -> float:
        """ideal_s / bound_s: 1.0 = the compiled program does no work beyond
        the algorithm's inherent compute/traffic; lower = waste (remat,
        redundancy, layout copies, collectives) in the dominant term."""
        if self.bound_s <= 0:
            return 0.0
        return min(self.ideal_s / self.bound_s, 1.0)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "ici_bytes_per_device": self.ici_bytes_per_device,
            "dcn_bytes_per_device": self.dcn_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "model_bytes_per_device": self.model_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound_s": self.bound_s,
            "ideal_s": self.ideal_s,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(kind: str, n_active_params: float, tokens: float,
                extra_attn_flops: float = 0.0) -> float:
    """Global useful FLOPs: 6*N*D for a train step (fwd+bwd), 2*N*D for
    forward-only (prefill/decode), plus explicit attention term."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens + extra_attn_flops


def attention_flops(kind: str, cfg, seq_len: int, batch: int,
                    decode: bool = False) -> float:
    """Softmax-attention FLOPs (QK^T + PV), windowing-aware."""
    if cfg.num_heads == 0:
        return 0.0
    specs = [sl for st in cfg.stages for _ in range(st.repeats)
             for sl in st.block if sl.kind == "attn"]
    total = 0.0
    d = cfg.num_heads * cfg.head_dim
    for sl in specs:
        if decode:
            ctx_len = min(sl.window, seq_len) if sl.window else seq_len
            per_layer = 4.0 * batch * 1 * ctx_len * d
        else:
            if sl.window and sl.window < seq_len:
                per_layer = 4.0 * batch * seq_len * sl.window * d
            else:
                per_layer = 4.0 * batch * seq_len * seq_len * d / 2  # causal
        total += per_layer
    mult = 3.0 if kind == "train" else 1.0  # bwd ~ 2x fwd
    return total * mult
