"""Scenario subsystem: declarative time-varying workloads and fault
injection, consumed by the simulator (compiled `Schedule`), the serving
engine / data pipeline / benches (`HostPlayback`), and the drift study.
See `repro.workloads.scenario` for the model and `repro.workloads.library`
for the built-in scenarios.
"""

from repro.workloads.scenario import (  # noqa: F401
    HostPlayback,
    Scenario,
    ScenarioConfig,
    ScenarioLike,
    Schedule,
    Segment,
    SlotKnobs,
    arrival_steps,
    available_scenarios,
    compile_schedule,
    host_playback,
    make_scenario,
    mean_lam_mult_over,
    register_scenario,
    slot_knobs,
)
