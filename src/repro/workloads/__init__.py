"""Scenario subsystem: declarative time-varying workloads, fault
injection, and trace-driven replay.

Consumed by the simulator (compiled `Schedule`), the serving engine /
data pipeline / benches (`HostPlayback`), and the drift study.  See
`repro.workloads.scenario` for the model, `repro.workloads.library` for
the built-in synthetic scenarios, and `repro.workloads.trace` for
recorded-trace replay (trace schema, JSONL/CSV loader, change-point
compiler, synthetic generator, and the export hook that re-records live
runs as replayable traces).
"""

from repro.workloads.scenario import (  # noqa: F401
    HostPlayback,
    Scenario,
    ScenarioConfig,
    ScenarioLike,
    Schedule,
    Segment,
    SlotKnobs,
    arrival_steps,
    available_scenarios,
    compile_schedule,
    first_doc_line,
    host_playback,
    make_scenario,
    mean_lam_mult_over,
    register_scenario,
    scenario_descriptions,
    slot_knobs,
)
from repro.workloads.trace import (  # noqa: F401
    Incident,
    Trace,
    bundled_traces,
    load_bundled,
    load_trace,
    save_trace,
    synthesize_trace,
    trace_from_arrivals,
    trace_to_scenario,
)
from repro.workloads.ingest import (  # noqa: F401
    ALIBABA_BATCH_TASK_COLUMNS,
    ALIBABA_CONTAINER_COLUMNS,
    GOOGLE_V2_TASK_EVENT_COLUMNS,
    load_alibaba_cluster_csv,
    load_google_cluster_csv,
    save_alibaba_cluster_csv,
    save_google_cluster_csv,
)
