"""Ingest adapters: map external cluster-trace formats onto the `Trace`
schema (ROADMAP follow-on to trace-driven replay).

The first adapter covers the Google cluster-usage **v2** ``task_events``
table (Reiss & Wilkes, "Google cluster-usage traces: format + schema",
2011-2014 releases): headerless CSV shards whose rows are per-task
scheduling events with microsecond timestamps.  The adapter bins SUBMIT
events into uniform wall-clock intervals — exactly the per-interval
arrival counts our `Trace` carries — and, optionally, derives per-rack
arrival-weight annotations from the ``machine_id`` column (machines are
hashed onto racks, so key skew in the recorded placement becomes the
`rack_weights` knob the simulator replays).

The second adapter covers the Alibaba **cluster-trace-v2018** release:
``batch_task`` rows (second-granularity ``start_time`` stamps, one row
per task with an instance count) supply the arrival counts, and the
``container`` table's ``machine_id`` column supplies the per-rack weight
annotations on the same interval grid — two files because Alibaba splits
the workload across tables where Google uses one.  Both adapters share
the machine -> rack hashing and the deterministic-exporter round-trip
discipline described below.

Everything downstream is free: ``trace_to_scenario`` compiles the result
into the same piecewise schedule every synthetic scenario uses, so a
recorded Google trace replays through the simulator, both Pallas kernels,
the serving engine and the data pipeline with zero new branching.

A deterministic exporter (`save_google_cluster_csv`) writes a trace back
out in the same column layout (one synthetic SUBMIT row per counted
arrival, evenly spaced inside its interval), which is what makes the
round-trip property testable: export -> ingest reproduces the original
per-interval counts bit-for-bit *given the interval count* — an event
stream cannot represent trailing empty intervals, so round-tripping a
trace that ends in zero-arrival intervals needs ``num_intervals=``
passed explicitly at load time (the loader's default covers only up to
the last event).
"""

from __future__ import annotations

import csv
import hashlib
from functools import lru_cache
from pathlib import Path
from typing import Optional, Sequence, Union

import numpy as np

from repro.workloads.trace import Trace

# Google cluster-usage v2 task_events column order (no header row in the
# published shards).
GOOGLE_V2_TASK_EVENT_COLUMNS = (
    "time", "missing_info", "job_id", "task_index", "machine_id",
    "event_type", "user", "scheduling_class", "priority",
    "cpu_request", "memory_request", "disk_request", "different_machines",
)
_TIME, _MACHINE, _EVENT = 0, 4, 5
GOOGLE_V2_SUBMIT = 0  # event_type of a task submission
GOOGLE_V2_TIME_UNIT = 1e-6  # timestamps are microseconds


def _rack_of_machine(machine: str, num_racks: int) -> int:
    """Stable machine -> rack assignment (the trace does not publish the
    physical topology, so machines are hashed onto racks)."""
    digest = hashlib.blake2s(machine.encode(), digest_size=4).digest()
    return int.from_bytes(digest, "big") % num_racks


def load_google_cluster_csv(path: Union[str, Path], *,
                            interval: float = 300.0,
                            name: Optional[str] = None,
                            event_types: Sequence[int] = (GOOGLE_V2_SUBMIT,),
                            time_unit: float = GOOGLE_V2_TIME_UNIT,
                            num_intervals: Optional[int] = None,
                            num_racks: Optional[int] = None) -> Trace:
    """Read a Google cluster-usage v2 ``task_events`` CSV shard into a
    `Trace` of per-interval arrival counts.

    interval      -- seconds per trace interval (default 5 minutes)
    event_types   -- which event codes count as arrivals (default SUBMIT)
    time_unit     -- seconds per timestamp unit (v2 uses microseconds)
    num_intervals -- force the interval count (default: cover the last
                     event — pass it explicitly to keep trailing
                     zero-arrival intervals, which no event stream can
                     encode); events past the end are rejected
    num_racks     -- when set, annotate each interval with per-rack
                     arrival weights derived from the machine_id column
                     (machines hashed onto `num_racks` racks; intervals
                     with no machine-attributed events fall back to
                     uniform weights)

    Rows shorter than the event-type column, and rows whose timestamp or
    event code does not parse, are rejected with their line number — a
    mis-delimited shard should fail loudly, not bin garbage.
    """
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"no trace file at {path}")
    if interval <= 0:
        raise ValueError(f"interval must be > 0, got {interval}")
    if time_unit <= 0:
        raise ValueError(f"time_unit must be > 0, got {time_unit}")
    wanted = {int(e) for e in event_types}
    times: list = []
    machines: list = []
    with open(path, newline="") as f:
        for ln, row in enumerate(csv.reader(f), 1):
            if not row:
                continue
            if ln == 1 and not row[_TIME].strip().lstrip("-").isdigit():
                continue  # tolerate a header row on hand-built shards
            if len(row) <= _EVENT:
                raise ValueError(
                    f"{path}:{ln}: row has {len(row)} columns, need at "
                    f"least {_EVENT + 1} (google v2 task_events layout)")
            try:
                t = int(row[_TIME])
                ev = int(row[_EVENT])
            except ValueError:
                raise ValueError(f"{path}:{ln}: unparseable time/event "
                                 f"{row[_TIME]!r}/{row[_EVENT]!r}") from None
            if ev not in wanted:
                continue
            if t < 0:
                raise ValueError(f"{path}:{ln}: negative timestamp {t}")
            times.append(t * time_unit)
            machines.append(row[_MACHINE].strip()
                            if len(row) > _MACHINE else "")
    if not times:
        raise ValueError(f"{path}: no events with type in {sorted(wanted)}")
    times_arr = np.asarray(times, np.float64)
    n = num_intervals if num_intervals is not None \
        else int(np.floor(times_arr.max() / interval)) + 1
    if n < 1:
        raise ValueError(f"num_intervals must be >= 1, got {n}")
    horizon = n * interval
    if times_arr.max() >= horizon:
        raise ValueError(f"{path}: event at {times_arr.max():.0f}s falls "
                         f"outside the {n} x {interval:.0f}s horizon")
    bins = np.minimum((times_arr / interval).astype(np.int64), n - 1)
    arrivals = np.bincount(bins, minlength=n).astype(np.float64)

    rack_weights = None
    if num_racks is not None:
        if num_racks < 1:
            raise ValueError(f"num_racks must be >= 1, got {num_racks}")
        rack_weights = np.zeros((n, num_racks), np.float64)
        for b, machine in zip(bins, machines):
            if machine:
                rack_weights[b, _rack_of_machine(machine, num_racks)] += 1.0
        empty = rack_weights.sum(axis=1) == 0
        rack_weights[empty] = 1.0  # uniform where placement is unknown
        rack_weights /= rack_weights.sum(axis=1, keepdims=True)

    return Trace(name=name or path.stem, interval=float(interval),
                 arrivals=arrivals, rack_weights=rack_weights)


def save_google_cluster_csv(trace: Trace, path: Union[str, Path], *,
                            time_unit: float = GOOGLE_V2_TIME_UNIT) -> Path:
    """Write a trace as a Google cluster-usage v2 ``task_events`` shard:
    one SUBMIT row per counted arrival, spaced evenly inside its interval.

    When the trace carries `rack_weights` (N, R), each row's machine_id is
    drawn from a per-rack machine pool (largest-remainder apportionment of
    the interval's weights over its rows), so
    ``load_google_cluster_csv(..., num_racks=R)`` recovers the annotation.
    The export is deterministic — the round-trip test relies on it.
    Trailing zero-arrival intervals produce no rows (an event stream has
    no way to mark them); reload with ``num_intervals=trace.num_intervals``
    to preserve them.
    """
    path = Path(path)
    num_racks = (None if trace.rack_weights is None
                 else int(trace.rack_weights.shape[1]))
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        job = 0
        for i, count in enumerate(np.asarray(trace.arrivals)):
            count = int(round(float(count)))
            if count <= 0:
                continue
            t0 = i * trace.interval
            step = trace.interval / count
            if num_racks is None:
                racks = [None] * count
            else:
                weights = np.asarray(trace.rack_weights[i], np.float64)
                frac = weights / weights.sum() * count
                quota = np.floor(frac).astype(int)
                for j in np.argsort(-(frac - quota))[: count - quota.sum()]:
                    quota[j] += 1  # largest-remainder top-up to `count`
                racks = [r for r, q in enumerate(quota) for _ in range(q)]
            for j in range(count):
                t = int(round((t0 + j * step) / time_unit))
                rack = racks[j]
                machine = "" if rack is None else \
                    _machine_in_rack(rack, num_racks)
                job += 1
                w.writerow([t, 0, job, 0, machine, GOOGLE_V2_SUBMIT,
                            "user", 0, 0, "", "", "", ""])
    return path


@lru_cache(maxsize=4096)
def _machine_in_rack(rack: int, num_racks: int) -> str:
    """A machine id that `_rack_of_machine` maps back onto `rack`, found by
    deterministic search over candidate names (a handful of hash probes)."""
    i = 0
    while True:
        cand = f"m{rack}-{i}"
        if _rack_of_machine(cand, num_racks) == rack:
            return cand
        i += 1


# ---------------------------------------------------------------------------
# Alibaba cluster-trace-v2018
# ---------------------------------------------------------------------------

# Alibaba cluster-trace-v2018 column orders (headerless CSVs).
ALIBABA_BATCH_TASK_COLUMNS = (
    "task_name", "instance_num", "job_name", "task_type", "status",
    "start_time", "end_time", "plan_cpu", "plan_mem",
)
_AB_INSTANCES, _AB_STATUS, _AB_START = 1, 4, 5
ALIBABA_CONTAINER_COLUMNS = (
    "container_id", "machine_id", "time_stamp", "app_du", "status",
    "cpu_request", "cpu_limit", "mem_size",
)
_AC_MACHINE, _AC_TIME = 1, 2


def _read_rows(path: Path, min_cols: int, time_col: int, what: str):
    """Headerless-CSV row iterator shared by the Alibaba tables: yields
    (line_number, row), tolerating a header row on hand-built shards
    (probed on the *time* column — the id columns are non-numeric in
    genuine rows too) and rejecting short rows loudly (a mis-delimited
    shard must not bin garbage)."""
    with open(path, newline="") as f:
        for ln, row in enumerate(csv.reader(f), 1):
            if not row:
                continue
            if ln == 1 and len(row) > time_col and row[time_col].strip() \
                    and not _is_number(row[time_col]):
                continue  # header row
            if len(row) < min_cols:
                raise ValueError(
                    f"{path}:{ln}: row has {len(row)} columns, need at "
                    f"least {min_cols} ({what} layout)")
            yield ln, row


def _is_number(s: str) -> bool:
    try:
        float(s)
        return True
    except ValueError:
        return False


def load_alibaba_cluster_csv(batch_task_path: Union[str, Path], *,
                             container_path: Optional[Union[str, Path]]
                             = None,
                             interval: float = 300.0,
                             name: Optional[str] = None,
                             use_instances: bool = False,
                             num_intervals: Optional[int] = None,
                             num_racks: Optional[int] = None) -> Trace:
    """Read Alibaba cluster-trace-v2018 shards into a `Trace`.

    batch_task_path -- ``batch_task`` CSV: each row's ``start_time``
                       (seconds) is one arrival (or ``instance_num``
                       arrivals with ``use_instances=True``); rows whose
                       start_time is empty or 0 (tasks that never
                       started) are skipped
    container_path  -- optional ``container`` table: its ``machine_id``
                       column, binned by ``time_stamp`` onto the same
                       interval grid, yields per-rack arrival weights
                       (requires ``num_racks``; intervals with no
                       container events fall back to uniform)
    num_intervals   -- as in `load_google_cluster_csv`: pass explicitly
                       to keep trailing zero-arrival intervals

    The two tables are one recorded cluster: the horizon covers the last
    event of either, and events past a forced ``num_intervals`` horizon
    are rejected.
    """
    batch_task_path = Path(batch_task_path)
    if not batch_task_path.exists():
        raise FileNotFoundError(f"no trace file at {batch_task_path}")
    if interval <= 0:
        raise ValueError(f"interval must be > 0, got {interval}")
    if container_path is not None and num_racks is None:
        raise ValueError("container_path needs num_racks to derive "
                         "rack weights")
    times: list = []
    weights_n: list = []
    for ln, row in _read_rows(batch_task_path, _AB_START + 1, _AB_START,
                              "alibaba batch_task"):
        raw = row[_AB_START].strip()
        if raw in ("", "0"):
            continue  # task never started
        try:
            t = float(raw)
            n = int(float(row[_AB_INSTANCES])) if use_instances and \
                row[_AB_INSTANCES].strip() else 1
        except ValueError:
            raise ValueError(
                f"{batch_task_path}:{ln}: unparseable start_time/"
                f"instance_num {row[_AB_START]!r}/"
                f"{row[_AB_INSTANCES]!r}") from None
        if t < 0:
            raise ValueError(f"{batch_task_path}:{ln}: negative "
                             f"start_time {t}")
        times.append(t)
        weights_n.append(max(n, 1))
    if not times:
        raise ValueError(f"{batch_task_path}: no started batch tasks")
    times_arr = np.asarray(times, np.float64)
    counts_arr = np.asarray(weights_n, np.int64)

    c_times: list = []
    c_machines: list = []
    if container_path is not None:
        container_path = Path(container_path)
        if not container_path.exists():
            raise FileNotFoundError(f"no trace file at {container_path}")
        for ln, row in _read_rows(container_path, _AC_TIME + 1, _AC_TIME,
                                  "alibaba container"):
            raw = row[_AC_TIME].strip()
            if not raw:
                continue
            try:
                t = float(raw)
            except ValueError:
                raise ValueError(f"{container_path}:{ln}: unparseable "
                                 f"time_stamp {row[_AC_TIME]!r}") from None
            if t < 0:
                raise ValueError(f"{container_path}:{ln}: negative "
                                 f"time_stamp {t}")
            machine = row[_AC_MACHINE].strip()
            if machine:
                c_times.append(t)
                c_machines.append(machine)

    t_max = max([times_arr.max()] + (c_times or []))
    n = num_intervals if num_intervals is not None \
        else int(np.floor(t_max / interval)) + 1
    if n < 1:
        raise ValueError(f"num_intervals must be >= 1, got {n}")
    horizon = n * interval
    if t_max >= horizon:
        raise ValueError(f"{batch_task_path}: event at {t_max:.0f}s falls "
                         f"outside the {n} x {interval:.0f}s horizon")
    bins = np.minimum((times_arr / interval).astype(np.int64), n - 1)
    arrivals = np.zeros(n, np.float64)
    np.add.at(arrivals, bins, counts_arr.astype(np.float64))

    rack_weights = None
    if container_path is not None:
        if num_racks < 1:
            raise ValueError(f"num_racks must be >= 1, got {num_racks}")
        rack_weights = np.zeros((n, num_racks), np.float64)
        for t, machine in zip(c_times, c_machines):
            b = min(int(t / interval), n - 1)
            rack_weights[b, _rack_of_machine(machine, num_racks)] += 1.0
        empty = rack_weights.sum(axis=1) == 0
        rack_weights[empty] = 1.0  # uniform where placement is unknown
        rack_weights /= rack_weights.sum(axis=1, keepdims=True)

    return Trace(name=name or batch_task_path.stem,
                 interval=float(interval), arrivals=arrivals,
                 rack_weights=rack_weights)


def save_alibaba_cluster_csv(trace: Trace,
                             batch_task_path: Union[str, Path], *,
                             container_path: Optional[Union[str, Path]]
                             = None) -> Path:
    """Write a trace as Alibaba cluster-trace-v2018 shards: one
    ``batch_task`` row per counted arrival (``instance_num = 1``, evenly
    spaced inside its interval) and — when the trace carries
    `rack_weights` and a ``container_path`` is given — one container row
    per arrival whose machine_id is drawn from a per-rack pool
    (largest-remainder apportionment, mirroring the Google exporter), so
    ``load_alibaba_cluster_csv(..., container_path=..., num_racks=R)``
    recovers the annotation.  Deterministic; trailing zero-arrival
    intervals need ``num_intervals=`` at reload, as with Google.

    The single event that would land exactly on ``start_time == 0`` (the
    loader skips never-started tasks) is shifted to half its interval
    sub-step instead — still inside interval 0 at any interval length.
    """
    batch_task_path = Path(batch_task_path)
    if trace.rack_weights is not None and container_path is None:
        raise ValueError("trace carries rack_weights: pass container_path "
                         "to preserve them (or strip the weights)")
    num_racks = (None if trace.rack_weights is None
                 else int(trace.rack_weights.shape[1]))
    rows_c = []
    with open(batch_task_path, "w", newline="") as f:
        w = csv.writer(f)
        task = 0
        for i, count in enumerate(np.asarray(trace.arrivals)):
            count = int(round(float(count)))
            if count <= 0:
                continue
            t0 = i * trace.interval
            step = trace.interval / count
            if num_racks is None:
                racks = [None] * count
            else:
                weights = np.asarray(trace.rack_weights[i], np.float64)
                frac = weights / weights.sum() * count
                quota = np.floor(frac).astype(int)
                for j in np.argsort(-(frac - quota))[: count - quota.sum()]:
                    quota[j] += 1
                racks = [r for r, q in enumerate(quota) for _ in range(q)]
            for j in range(count):
                t = t0 + j * step
                if t <= 0.0:
                    t = 0.5 * step  # 0 would read back as never-started
                task += 1
                w.writerow([f"task_{task}", 1, f"j_{task}", 1, "Terminated",
                            f"{t:.6f}", f"{t + step:.6f}", 100, 0.5])
                if racks[j] is not None:
                    rows_c.append((f"c_{task}",
                                   _machine_in_rack(racks[j], num_racks),
                                   f"{t:.6f}", "du_1", "started",
                                   4, 4, 1.0))
    if container_path is not None:
        with open(container_path, "w", newline="") as f:
            w = csv.writer(f)
            for row in rows_c:
                w.writerow(row)
    return batch_task_path
