"""Built-in scenarios, registered by name (loaded lazily by the registry).

Each builder returns a declarative `Scenario`; options are plain Python
numbers so any scenario is constructible from a config string or CLI flag.
Arrival-modulating builders keep the time-average ``lam_mult`` at 1.0 (the
MMPP normalizes itself), so a load expressed as a fraction of the static
fluid capacity offers the same long-run traffic under every scenario — the
delay differences between scenarios then measure burstiness and drift, not
a hidden change of load.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.workloads.scenario import Scenario, Segment, register_scenario


@register_scenario("static")
def static() -> Scenario:
    """The identity scenario: every knob multiplied by 1.0 for the whole
    run.  Compiled and played back, it reproduces the pre-scenario sample
    paths bitwise (common random numbers preserved)."""
    return Scenario("static", (Segment(start=0.0),))


@register_scenario("diurnal")
def diurnal(amplitude: float = 0.35, cycles: float = 1.0,
            segments: int = 24) -> Scenario:
    """Sinusoidal day/night load: lam_mult = 1 + amplitude*sin(2*pi*cycles*u),
    discretized to `segments` piecewise-constant spans (mean exactly ~1 by
    symmetry of the midpoint rule over whole cycles)."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    if segments < 2:
        raise ValueError(f"need >= 2 segments, got {segments}")
    mults = [1.0 + amplitude * math.sin(2.0 * math.pi * cycles
                                        * (i + 0.5) / segments)
             for i in range(segments)]
    # Explicit unit-mean normalization: for whole cycles the midpoint mean
    # is ~1 already, but fractional `cycles` would otherwise smuggle extra
    # offered load into the comparison.
    mean = sum(mults) / segments
    segs = tuple(Segment(start=i / segments, lam_mult=m / mean)
                 for i, m in enumerate(mults))
    return Scenario("diurnal", segs)


@register_scenario("flash_crowd")
def flash_crowd(peak: float = 1.8, start: float = 0.45,
                width: float = 0.15) -> Scenario:
    """A sudden arrival surge: lam_mult jumps to `peak` during
    [start, start+width), compensated to keep the time-average at 1.0 so
    the long-run offered load matches the static scenario."""
    if peak <= 1.0:
        raise ValueError(f"peak must be > 1, got {peak}")
    if not 0.0 < start < start + width < 1.0:
        raise ValueError(f"surge window [{start}, {start + width}) must sit "
                         f"strictly inside (0, 1)")
    # base * (1 - width) + peak * base * width == 1
    base = 1.0 / (1.0 - width + peak * width)
    return Scenario("flash_crowd", (
        Segment(start=0.0, lam_mult=base),
        Segment(start=start, lam_mult=peak * base),
        Segment(start=start + width, lam_mult=base),
    ))


@register_scenario("mmpp")
def mmpp(lam_lo: float = 0.6, lam_hi: float = 1.6,
         mean_dwell: float = 0.08, seed: int = 0,
         max_segments: int = 48) -> Scenario:
    """2-state Markov-modulated Poisson arrivals: the rate multiplier
    alternates between `lam_lo` and `lam_hi` with exponential dwell times
    (mean `mean_dwell` of the run), sampled deterministically from `seed`
    and normalized to unit time-average."""
    if not 0.0 < lam_lo < lam_hi:
        # lam_lo == 0 (interrupted Poisson) would divide by zero in the
        # unit-mean normalization whenever the sampled path never leaves
        # the low state; approximate it with a small positive rate instead.
        raise ValueError(f"need 0 < lam_lo < lam_hi, got {lam_lo}, {lam_hi}")
    if mean_dwell <= 0.0:
        raise ValueError(f"mean_dwell must be > 0, got {mean_dwell}")
    rng = np.random.default_rng(seed)
    starts, mults = [0.0], [lam_lo]
    t = float(rng.exponential(mean_dwell))
    while t < 1.0 and len(starts) < max_segments:
        starts.append(t)
        mults.append(lam_hi if mults[-1] == lam_lo else lam_lo)
        t += float(rng.exponential(mean_dwell))
    spans = np.diff(np.array(starts + [1.0]))
    mean = float(np.dot(spans, np.array(mults)))
    segs = tuple(Segment(start=s, lam_mult=m / mean)
                 for s, m in zip(starts, mults))
    return Scenario("mmpp", segs)


@register_scenario("hot_shift")
def hot_shift(phases: int = 4, p_hot: Optional[float] = None) -> Scenario:
    """Hotspot migration: the hot rack advances one rack per phase (rack ids
    wrap mod num_racks at compile time), optionally overriding the hot
    fraction — the locality-drift case the affinity-scheduling line
    stresses (a scheduler warmed on rack 0 must follow the hotspot)."""
    if phases < 2:
        raise ValueError(f"need >= 2 phases, got {phases}")
    segs = tuple(Segment(start=k / phases, hot_rack=k, p_hot=p_hot)
                 for k in range(phases))
    return Scenario("hot_shift", segs)


@register_scenario("stragglers")
def stragglers(servers: Sequence[int] = (0, 1), factor: float = 0.25,
               start: float = 0.25, width: float = 0.5) -> Scenario:
    """Per-server straggler window: `servers` run at `factor` x their true
    rates (all tiers) during [start, start+width) — thermal throttling or a
    noisy neighbor.  Rate estimates that froze before the window are wrong
    inside it; the blind EWMA estimator re-learns."""
    if not 0.0 < factor < 1.0:
        raise ValueError(f"factor must be in (0, 1), got {factor}")
    if not 0.0 < start < start + width < 1.0:
        raise ValueError(f"straggler window [{start}, {start + width}) must "
                         f"sit strictly inside (0, 1)")
    slow = {int(s): factor for s in servers}
    return Scenario("stragglers", (
        Segment(start=0.0),
        Segment(start=start, slow_servers=slow),
        Segment(start=start + width),
    ))


@register_scenario("server_loss")
def server_loss(servers: Sequence[int] = (0, 1), start: float = 0.35,
                width: float = 0.3) -> Scenario:
    """Server failure window: `servers` are DEAD (zero service rate, all
    hosted replicas wiped) during [start, start+width) and rejoin empty
    afterwards — the availability / data-loss event the paper's 3x
    replication exists to survive.  A replication controller must re-create
    the lost replicas from the survivors, paying migration bandwidth."""
    if not servers:
        raise ValueError("server_loss needs at least one server id")
    if not 0.0 < start < start + width < 1.0:
        raise ValueError(f"failure window [{start}, {start + width}) must "
                         f"sit strictly inside (0, 1)")
    down = tuple(int(s) for s in servers)
    return Scenario("server_loss", (
        Segment(start=0.0),
        Segment(start=start, down_servers=down),
        Segment(start=start + width),
    ))


@register_scenario("rack_loss")
def rack_loss(racks: Sequence[int] = (0,), start: float = 0.35,
              width: float = 0.25) -> Scenario:
    """Rack failure window: every server in `racks` is DEAD (replicas
    wiped) during [start, start+width) — the correlated-failure case that
    motivates spreading replicas across racks.  Rack ids wrap mod the rack
    count and resolve through the consumer's rack_of map at compile time."""
    if not racks:
        raise ValueError("rack_loss needs at least one rack id")
    if not 0.0 < start < start + width < 1.0:
        raise ValueError(f"failure window [{start}, {start + width}) must "
                         f"sit strictly inside (0, 1)")
    down = tuple(int(r) for r in racks)
    return Scenario("rack_loss", (
        Segment(start=0.0),
        Segment(start=start, down_racks=down),
        Segment(start=start + width),
    ))


@register_scenario("rack_congestion")
def rack_congestion(beta_mult: float = 0.6, gamma_mult: float = 0.5,
                    start: float = 0.4, width: float = 0.4) -> Scenario:
    """Network fault: rack-switch / DCN congestion sags the TRUE rack-local
    and remote rates (beta, gamma) during [start, start+width) while local
    service (alpha) is unaffected — exactly the "network" error mode of the
    robustness study, but injected into reality instead of the estimate."""
    if not (0.0 < beta_mult <= 1.0 and 0.0 < gamma_mult <= 1.0):
        raise ValueError(f"tier multipliers must be in (0, 1], got "
                         f"{beta_mult}, {gamma_mult}")
    if not 0.0 < start < start + width < 1.0:
        raise ValueError(f"congestion window [{start}, {start + width}) must "
                         f"sit strictly inside (0, 1)")
    return Scenario("rack_congestion", (
        Segment(start=0.0),
        Segment(start=start, tier_mult=(1.0, beta_mult, gamma_mult)),
        Segment(start=start + width),
    ))
