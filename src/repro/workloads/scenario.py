"""Declarative time-varying workload scenarios (the Scenario subsystem).

The paper's case for Balanced-PANDAS rests on "the change of traffic over
time in addition to estimation errors of processing rates", yet a single
static configuration — constant-rate Poisson arrivals, a frozen hot rack,
true rates that never move — can only probe the estimation-error half.  A
`Scenario` closes the gap: it is a declarative, piecewise-constant schedule
over *normalized* run time ``[0, 1)`` of every workload knob the system
exposes:

  * arrival-rate modulation (``lam_mult``): diurnal ramps, flash crowds,
    2-state MMPP bursts;
  * locality drift (``p_hot``, ``hot_rack``, ``rack_weights``): the hot
    rack migrating, the hot fraction ramping, or a full per-rack
    arrival-weight vector (the K-tier generalization);
  * fault injection into the *true* service rates: per-server straggler
    windows (``slow_servers``) and network congestion that sags whole tiers
    (``tier_mult`` on beta / gamma).

One scenario object feeds every layer through two projections:

  * `compile_schedule` — dense, fixed-shape JAX arrays (`Schedule`) gathered
    per slot by `slot_knobs(schedule, t)` inside the simulator's
    `lax.scan`; shapes do not depend on ``t`` or on any batch dimension, so
    `sweep()` still vmaps the whole load x error x seed grid into one XLA
    program, and the simulator contains zero per-scenario branching.
  * `host_playback` — the same segments as numpy arrays (`HostPlayback`)
    for the host-side consumers: the serving engine (time-varying replica
    slowdowns), `bench_serving` (arrival-time modulation), and the data
    pipeline (straggler hosts on the virtual clock).

Scenarios are registered by name with `@register_scenario` (mirroring the
`@register_policy` registry in `core/policy.py`) so every driver —
`sweep()`, `run_study()`, `drift_study()`, `bench_serving`, the data
pipeline — selects them by string; `scenario_descriptions()` exposes a
one-line description per entry (surfaced by ``benchmarks/run.py --help``).
The ``"static"`` scenario is the identity: compiled, it multiplies every
knob by 1.0, and the simulator reproduces the pre-scenario sample paths
bitwise (common random numbers preserved; pinned by
tests/test_workloads.py).  Synthetic scenarios live in
`repro.workloads.library`; *recorded* ones come from
`repro.workloads.trace`, which compiles real cluster traces (per-interval
arrival counts + incident windows) into this same representation
(``scenario="trace"``).
"""

from __future__ import annotations

import dataclasses
import numbers
from typing import (Any, Callable, Dict, Mapping, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax.numpy as jnp
import numpy as np

from repro.utils.doc import first_doc_line

# ---------------------------------------------------------------------------
# Declarative pieces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """One piecewise-constant span of a scenario, starting at fraction
    ``start`` of the run and lasting until the next segment (or the end).

    lam_mult     -- arrival-rate multiplier applied to the configured load
    p_hot        -- absolute hot-traffic fraction; None keeps the config's
    hot_rack     -- rack receiving the hot traffic (mod num_racks at compile)
    rack_weights -- per-rack arrival weights for the skewed traffic: hot
                    tasks draw their rack from this vector instead of the
                    single ``hot_rack`` (resized to the topology's rack
                    count at compile: truncated or cycled).  None keeps
                    the classic one-hot hot_rack behaviour — and the
                    bitwise static sample path.
    tier_mult    -- per-tier multipliers on the TRUE rates: network faults
                    (rack-switch congestion sags the non-local tiers).
                    Three values are the classic (local, rack, remote)
                    spelling — on a deeper topology the remote multiplier
                    extends to every tier past the rack; a K-length tuple
                    addresses each tier exactly.
    slow_servers -- {server_id: rate_mult} per-server TRUE-rate multipliers
                    (straggler windows; ids taken mod fleet size at compile)
    down_servers -- server ids DEAD during this segment: rate 0, replicas
                    wiped (ids taken mod fleet size at compile).  Death is a
                    separate track from slow_servers because a dead server
                    loses its data — stragglers only serve it slowly.
    down_racks   -- rack ids whose every server is dead during this segment
                    (ids taken mod rack count at compile; resolved through
                    the topology's ``rack_of`` map)
    users_mult   -- multiplier on the closed-loop user population
                    (`repro.control`'s ``closed_loop`` load generator) —
                    the closed-loop analogue of ``lam_mult``.  Ignored by
                    open-loop runs, so the default 1.0 keeps every
                    pre-control schedule bitwise (the track is only
                    materialized when some segment moves it).
    """

    start: float
    lam_mult: float = 1.0
    p_hot: Optional[float] = None
    hot_rack: int = 0
    tier_mult: Tuple[float, ...] = (1.0, 1.0, 1.0)
    slow_servers: Mapping[int, float] = dataclasses.field(default_factory=dict)
    rack_weights: Optional[Tuple[float, ...]] = None
    down_servers: Tuple[int, ...] = ()
    down_racks: Tuple[int, ...] = ()
    users_mult: float = 1.0

    def __post_init__(self):
        if not 0.0 <= self.start < 1.0:
            raise ValueError(f"segment start must be in [0, 1), got {self.start}")
        if self.lam_mult < 0.0:
            raise ValueError(f"lam_mult must be >= 0, got {self.lam_mult}")
        if self.p_hot is not None and not 0.0 <= self.p_hot <= 1.0:
            raise ValueError(f"p_hot must be in [0, 1], got {self.p_hot}")
        if self.hot_rack < 0:
            raise ValueError(f"hot_rack must be >= 0, got {self.hot_rack}")
        if len(self.tier_mult) < 2 or any(m <= 0.0 for m in self.tier_mult):
            raise ValueError(f"tier_mult must be >= 2 positive values, "
                             f"got {self.tier_mult}")
        if any(v <= 0.0 for v in self.slow_servers.values()):
            raise ValueError(f"slow_servers multipliers must be > 0, "
                             f"got {dict(self.slow_servers)}")
        if self.rack_weights is not None:
            w = tuple(float(x) for x in self.rack_weights)
            if not w or any(x < 0.0 for x in w) or sum(w) <= 0.0:
                raise ValueError(f"rack_weights must be non-negative with a "
                                 f"positive sum, got {self.rack_weights}")
            object.__setattr__(self, "rack_weights", w)
        for field in ("down_servers", "down_racks"):
            ids = getattr(self, field)
            if any(not isinstance(i, numbers.Integral) or i < 0 for i in ids):
                raise ValueError(f"{field} must be non-negative server/rack "
                                 f"ids, got {ids}")
            object.__setattr__(self, field, tuple(int(i) for i in ids))
        if self.users_mult < 0.0:
            raise ValueError(f"users_mult must be >= 0, got {self.users_mult}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, ordered tuple of `Segment`s covering [0, 1)."""

    name: str
    segments: Tuple[Segment, ...]

    def __post_init__(self):
        if not self.segments:
            raise ValueError("scenario needs at least one segment")
        starts = [s.start for s in self.segments]
        if starts[0] != 0.0:
            raise ValueError(f"first segment must start at 0.0, got {starts[0]}")
        if any(b <= a for a, b in zip(starts, starts[1:])):
            raise ValueError(f"segment starts must strictly increase: {starts}")

    @property
    def mean_lam_mult(self) -> float:
        """Time-average arrival multiplier over [0, 1) — the factor relating
        the configured base load to the effective offered load."""
        starts = [s.start for s in self.segments] + [1.0]
        return float(sum(s.lam_mult * (b - a) for s, a, b in
                         zip(self.segments, starts, starts[1:])))


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Name + builder options, e.g. ``ScenarioConfig("stragglers",
    {"factor": 0.2})`` — the scenario analogue of `PolicyConfig`."""

    name: str
    options: Mapping[str, Any] = dataclasses.field(default_factory=dict)


ScenarioLike = Union[str, ScenarioConfig, Scenario, None]


# ---------------------------------------------------------------------------
# Registry (mirrors core/policy.py)
# ---------------------------------------------------------------------------

_SCENARIOS: Dict[str, Callable[..., Scenario]] = {}
_BUILTIN_MODULES = ("repro.workloads.library", "repro.workloads.trace")
_builtins_loaded = False


def _load_builtins() -> None:
    global _builtins_loaded
    if _builtins_loaded:
        return
    import importlib
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)
    _builtins_loaded = True


def register_scenario(name: str):
    """Decorator: register ``builder(**options) -> Scenario`` under `name`."""
    def deco(builder: Callable[..., Scenario]):
        if name in _SCENARIOS:
            raise ValueError(f"duplicate scenario registration: {name!r}")
        _SCENARIOS[name] = builder
        builder.scenario_name = name  # type: ignore[attr-defined]
        return builder
    return deco


def available_scenarios() -> Tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_SCENARIOS))


def scenario_descriptions() -> Dict[str, str]:
    """``{name: one-line description}`` for every registered scenario,
    taken from the first sentence of each builder's docstring — the
    self-describing registry surface behind ``benchmarks/run.py --help``."""
    _load_builtins()
    return {name: first_doc_line(builder)
            for name, builder in sorted(_SCENARIOS.items())}


def make_scenario(spec: ScenarioLike, **options) -> Scenario:
    """Resolve a name / ScenarioConfig / Scenario instance; None -> static."""
    if spec is None:
        spec = "static"
    if isinstance(spec, Scenario):
        if options:
            raise ValueError("options only apply when building by name")
        return spec
    if isinstance(spec, ScenarioConfig):
        if options:
            raise ValueError("options only apply when building by name")
        spec, options = spec.name, dict(spec.options)
    _load_builtins()
    try:
        builder = _SCENARIOS[spec]
    except KeyError:
        raise ValueError(f"unknown scenario {spec!r}; "
                         f"registered: {available_scenarios()}") from None
    return builder(**options)


# ---------------------------------------------------------------------------
# Dense materialization shared by both projections
# ---------------------------------------------------------------------------


def _expand_tier_mult(tm: Sequence[float], num_tiers: int) -> Tuple[float, ...]:
    """Map a segment's tier_mult onto K tiers: exact when lengths match;
    the classic 3-tuple extends its remote multiplier to every tier past
    the rack (DCN congestion hits everything beyond the rack switch) and
    drops the rack entry on a 2-tier fleet."""
    tm = tuple(float(x) for x in tm)
    if len(tm) == num_tiers:
        return tm
    if len(tm) == 3:
        if num_tiers > 3:
            return tm[:2] + (tm[2],) * (num_tiers - 2)
        if num_tiers == 2:
            return (tm[0], tm[2])
    raise ValueError(f"tier_mult {tm} does not fit a {num_tiers}-tier "
                     f"topology (pass 3 or exactly {num_tiers} values)")


def _resize_weights(w: Sequence[float], num_racks: int) -> Tuple[float, ...]:
    """Fit a segment's rack_weights to the compiled rack count: truncate a
    longer vector, cycle a shorter one (mirroring hot_rack's mod wrap)."""
    w = tuple(float(x) for x in w)
    out = tuple(w[i % len(w)] for i in range(num_racks))
    if sum(out) <= 0.0:
        raise ValueError(f"rack_weights {w} are all zero over the first "
                         f"{num_racks} racks")
    return out


def _dense_segments(scn: Scenario, num_workers: int, num_racks: int,
                    base_p_hot: float, num_tiers: int = 3,
                    materialize_weights: bool = True, rack_of=None):
    """Numpy per-segment arrays:
    (starts, lam, p_hot, hot_rack, tier, server, rack_weights, alive).

    starts are fractions in [0, 1); tier is (S, K); server is (S, M);
    rack_weights is (S, R) — or None when no segment opts into per-rack
    weights (the bitwise-pinned classic hot_rack path) or the caller
    does not consume the locality knobs (`materialize_weights=False`,
    the host projection — weights must not be resized/validated against
    a rack count the host side does not have).  alive is (S, M) bool —
    or None when no segment declares failures (a compile-time fact both
    projections branch on in Python, keeping the failure-free paths
    bitwise identical to the pre-replication code).  ``down_racks``
    resolve through ``rack_of`` (server -> rack map); scenarios that use
    them require the caller to supply it.
    """
    s_count = len(scn.segments)
    starts = np.array([s.start for s in scn.segments], np.float64)
    lam = np.array([s.lam_mult for s in scn.segments], np.float32)
    p_hot = np.array([base_p_hot if s.p_hot is None else s.p_hot
                      for s in scn.segments], np.float32)
    hot = np.array([s.hot_rack % max(num_racks, 1) for s in scn.segments],
                   np.int32)
    tier = np.array([_expand_tier_mult(s.tier_mult, num_tiers)
                     for s in scn.segments], np.float32)
    server = np.ones((s_count, num_workers), np.float32)
    for i, seg in enumerate(scn.segments):
        for sid, mult in seg.slow_servers.items():
            server[i, sid % num_workers] = mult
    if not materialize_weights or \
            all(s.rack_weights is None for s in scn.segments):
        weights = None
    else:
        # segments without explicit weights keep their hot_rack as one-hot
        weights = np.zeros((s_count, max(num_racks, 1)), np.float32)
        for i, seg in enumerate(scn.segments):
            if seg.rack_weights is None:
                weights[i, hot[i]] = 1.0
            else:
                weights[i] = _resize_weights(seg.rack_weights,
                                             max(num_racks, 1))
    if all(not s.down_servers and not s.down_racks for s in scn.segments):
        alive = None
    else:
        alive = np.ones((s_count, num_workers), bool)
        for i, seg in enumerate(scn.segments):
            for sid in seg.down_servers:
                alive[i, sid % num_workers] = False
            if seg.down_racks:
                if rack_of is None:
                    raise ValueError(
                        "scenario uses down_racks but this consumer did not "
                        "supply a server->rack map; pass rack_of= (e.g. the "
                        "topology's rack_of) to resolve rack failures")
                rk = np.asarray(rack_of)
                if rk.shape != (num_workers,):
                    raise ValueError(f"rack_of must have shape "
                                     f"({num_workers},), got {rk.shape}")
                n_racks = int(rk.max()) + 1
                for rid in seg.down_racks:
                    alive[i, rk == rid % n_racks] = False
            if not alive[i].any():
                raise ValueError(
                    f"segment {i} of scenario {scn.name!r} kills every "
                    f"server — at least one must survive")
    return starts, lam, p_hot, hot, tier, server, weights, alive


# ---------------------------------------------------------------------------
# JAX projection: fixed-shape schedule + per-slot gather
# ---------------------------------------------------------------------------


class Schedule(NamedTuple):
    """Compiled scenario: per-segment arrays gathered by slot index inside
    `lax.scan`.  All shapes are static per scenario (S segments, M servers,
    K tiers), so vmapping the simulator over any grid leaves them
    untouched.  ``rack_weights`` is None unless some segment opts into
    per-rack arrival weights — a compile-time (Python) fact, so the
    classic hot_rack sampling path stays branch-free and bitwise pinned."""

    knots: jnp.ndarray      # (S,) int32 first slot of each segment
    lam_mult: jnp.ndarray   # (S,) f32 arrival-rate multiplier
    p_hot: jnp.ndarray      # (S,) f32 absolute hot fraction
    hot_rack: jnp.ndarray   # (S,) int32 rack receiving hot traffic
    rate_mult: jnp.ndarray  # (S, M, K) f32 TRUE-rate multiplier per server/tier
    rack_weights: Optional[jnp.ndarray] = None  # (S, R) f32 arrival weights
    alive: Optional[jnp.ndarray] = None  # (S, M) f32 1=alive, 0=dead; None
    #                                      when no segment declares failures
    users_mult: Optional[jnp.ndarray] = None  # (S,) f32 closed-loop user
    #                                      population multiplier; None when
    #                                      every segment keeps the default


class SlotKnobs(NamedTuple):
    """The scenario knobs in force during one slot."""

    lam_mult: jnp.ndarray   # () f32
    p_hot: jnp.ndarray      # () f32
    hot_rack: jnp.ndarray   # () int32
    rate_mult: jnp.ndarray  # (M, K) f32
    rack_weights: Optional[jnp.ndarray] = None  # (R,) f32 or None
    alive: Optional[jnp.ndarray] = None  # (M,) f32 or None
    users_mult: Optional[jnp.ndarray] = None  # () f32 or None


def _users_track(scn: Scenario) -> Optional[np.ndarray]:
    """(S,) closed-loop user-population multipliers, or None when every
    segment keeps the default 1.0 (the compile-time fact both projections
    branch on — open-loop schedules carry no users track at all)."""
    if all(s.users_mult == 1.0 for s in scn.segments):
        return None
    return np.array([s.users_mult for s in scn.segments], np.float32)


def compile_schedule(scn: Scenario, topo, horizon: int,
                     base_p_hot: float) -> Schedule:
    """Compile a scenario against a `Topology` and a slot horizon.  The
    topology fixes both the rack count (hot_rack wrap, rack_weights width)
    and the tier count K of the rate-multiplier track."""
    starts, lam, p_hot, hot, tier, server, weights, alive = _dense_segments(
        scn, topo.num_servers, topo.num_racks, base_p_hot,
        num_tiers=topo.num_tiers, rack_of=np.asarray(topo.rack_of))
    knots = np.floor(starts * horizon).astype(np.int32)
    knots[0] = 0
    rate = server[:, :, None] * tier[:, None, :]  # (S, M, K)
    users = _users_track(scn)
    return Schedule(
        knots=jnp.asarray(knots),
        lam_mult=jnp.asarray(lam),
        p_hot=jnp.asarray(p_hot),
        hot_rack=jnp.asarray(hot),
        rate_mult=jnp.asarray(rate),
        rack_weights=None if weights is None else jnp.asarray(weights),
        alive=None if alive is None else jnp.asarray(alive, jnp.float32),
        users_mult=None if users is None else jnp.asarray(users),
    )


def slot_knobs(sched: Schedule, t: jnp.ndarray) -> SlotKnobs:
    """Gather the segment in force at slot `t` (trace-safe, fixed shapes).

    With duplicate knots (segments shorter than one slot at small horizons)
    the LAST matching segment wins — `side="right"` lands after the run of
    duplicates.
    """
    i = jnp.searchsorted(sched.knots, t.astype(jnp.int32), side="right") - 1
    return SlotKnobs(lam_mult=sched.lam_mult[i], p_hot=sched.p_hot[i],
                     hot_rack=sched.hot_rack[i], rate_mult=sched.rate_mult[i],
                     rack_weights=None if sched.rack_weights is None
                     else sched.rack_weights[i],
                     alive=None if sched.alive is None else sched.alive[i],
                     users_mult=None if sched.users_mult is None
                     else sched.users_mult[i])


def mean_lam_mult_over(sched: Schedule, start_slot: int,
                       horizon: int) -> float:
    """Exact time-average of lam_mult over slots [start_slot, horizon) —
    the Little's-law denominator correction for the measurement window.

    Computed from segment spans clipped to the window (O(S), not
    O(window)), so a window that starts or ends mid-segment weighs that
    truncated segment by exactly the slots it contributes.  Zero-length or
    inverted windows raise instead of silently returning NaN, and a
    negative ``start_slot`` raises instead of wrapping onto the final
    segment (both were possible before these guards; pinned by
    tests/test_workloads.py)."""
    if not 0 <= start_slot < horizon:
        raise ValueError(f"need 0 <= start_slot < horizon for a non-empty "
                         f"window, got [{start_slot}, {horizon})")
    knots = np.asarray(sched.knots, np.int64)
    lam = np.asarray(sched.lam_mult, np.float64)
    # Each segment runs [knot, next knot); the last extends to `horizon`
    # (truncated there even if the scenario was compiled for a longer run).
    ends = np.append(knots[1:], max(horizon, int(knots[-1]) + 1))
    spans = (np.minimum(ends, horizon)
             - np.maximum(knots, start_slot)).clip(min=0)
    return float(np.dot(lam, spans) / spans.sum())


# ---------------------------------------------------------------------------
# Host projection: numpy playback for engine / pipeline / benches
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HostPlayback:
    """Host-side scenario playback over continuous (or step) time.

    Time wraps modulo `horizon`, so one playback cycle repeats — natural for
    diurnal patterns and harmless for one-shot windows as long as the run
    fits one horizon.  All consumers (serving engine, data pipeline,
    bench_serving) read the same compiled segments through this object, so
    there is no per-scenario branching on the host paths either.
    """

    horizon: float
    starts: np.ndarray       # (S,) segment start fractions
    lam_mult: np.ndarray     # (S,)
    tier_mult: np.ndarray    # (S, K)
    server_mult: np.ndarray  # (S, M)
    alive: Optional[np.ndarray] = None  # (S, M) bool; None = no failures
    users_mult: Optional[np.ndarray] = None  # (S,); None = no users track

    def _seg(self, t: float) -> int:
        u = (float(t) % self.horizon) / self.horizon
        return int(np.searchsorted(self.starts, u, side="right")) - 1

    def alive_at(self, t: float, worker: int) -> bool:
        """Whether `worker` is up at time `t` (always True for scenarios
        without a failure track)."""
        if self.alive is None:
            return True
        return bool(self.alive[self._seg(t), worker])

    def alive_mask_at(self, t: float) -> np.ndarray:
        """(M,) bool liveness mask at time `t`."""
        if self.alive is None:
            return np.ones(self.server_mult.shape[1], bool)
        return self.alive[self._seg(t)]

    def lam_mult_at(self, t: float) -> float:
        return float(self.lam_mult[self._seg(t)])

    def users_mult_at(self, t: float) -> float:
        """Closed-loop user-population multiplier at time `t` (1.0 for
        scenarios without a users track)."""
        if self.users_mult is None:
            return 1.0
        return float(self.users_mult[self._seg(t)])

    def rate_mult_at(self, t: float, worker: int,
                     tier: Optional[int] = None) -> float:
        """TRUE-rate multiplier for `worker` at time `t` (x tier sag when the
        locality tier of the work is known)."""
        s = self._seg(t)
        mult = float(self.server_mult[s, worker])
        if tier is not None and 0 <= tier < self.tier_mult.shape[1]:
            mult *= float(self.tier_mult[s, tier])
        return mult

    def slowdown(self, t: float, worker: int,
                 tier: Optional[int] = None) -> float:
        """Observed service-time inflation factor (1 / rate multiplier)."""
        return 1.0 / max(self.rate_mult_at(t, worker, tier), 1e-6)


def host_playback(scn: Scenario, num_workers: int, horizon: float,
                  num_tiers: int = 3, rack_of=None) -> HostPlayback:
    """Project a scenario to host-side numpy playback over `num_workers`
    with `num_tiers` locality tiers (the fleet Topology's ``num_tiers``).

    Host consumers (engine, pipeline, benches) place work by rendezvous
    hashing, so only the arrival-rate and fault tracks are materialized —
    the locality knobs (p_hot / hot_rack / rack_weights) are simulator-only.
    ``rack_of`` (server -> rack map, e.g. ``ClusterSpec.rack_of``) is only
    needed when the scenario uses ``down_racks``.
    """
    if not (isinstance(horizon, numbers.Real) and horizon > 0):
        raise ValueError(f"playback horizon must be > 0, got {horizon}")
    starts, lam, _p_hot, _hot, tier, server, _w, alive = _dense_segments(
        scn, num_workers, num_racks=1, base_p_hot=0.5, num_tiers=num_tiers,
        materialize_weights=False, rack_of=rack_of)
    return HostPlayback(horizon=float(horizon), starts=starts, lam_mult=lam,
                        tier_mult=tier, server_mult=server, alive=alive,
                        users_mult=_users_track(scn))


def arrival_steps(playback: HostPlayback, n_requests: int,
                  base_per_step: float) -> np.ndarray:
    """Deterministic arrival step for each of `n_requests` under the
    playback's time-varying intensity ``base_per_step * lam_mult(t)``.

    Fractional-accumulator thinning: walk steps, accumulate intensity, emit
    one arrival per accumulated unit.  Used by bench_serving to drive
    request submission times from the same scenario that drives slowdowns.
    """
    if base_per_step <= 0:
        raise ValueError(f"base_per_step must be > 0, got {base_per_step}")
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    if n_requests == 0:
        return np.empty(0, np.int64)
    if float(playback.lam_mult.max()) <= 0.0:
        raise ValueError("scenario has lam_mult == 0 everywhere: no "
                         "arrivals would ever be emitted")
    steps = np.empty(n_requests, np.int64)
    acc, t, emitted = 0.0, 0, 0
    # Generous bound: enough steps to emit everything at the mean intensity,
    # plus slack cycles.  Guards against degenerate playbacks where only
    # zero-rate segments land on integer steps (e.g. horizon ~ 1).
    max_steps = int(10 * (n_requests / base_per_step + playback.horizon)) + 100
    while emitted < n_requests:
        if t > max_steps:
            raise RuntimeError(
                f"arrival_steps emitted only {emitted}/{n_requests} after "
                f"{t} steps — scenario intensity too low on this playback")
        acc += base_per_step * playback.lam_mult_at(t)
        while acc >= 1.0 and emitted < n_requests:
            steps[emitted] = t
            emitted += 1
            acc -= 1.0
        t += 1
    return steps
