"""Trace-driven replay: compile recorded cluster traces into `Scenario`s.

The drift study (PR 2) stresses every policy with *synthetic* drift —
diurnal ramps, flash crowds, MMPP bursts.  Production comparisons (the
affinity-scheduling line, the Hadoop scheduling surveys) instead ground
themselves in *recorded* traffic: per-interval arrival counts from a real
cluster, annotated with key-skew shifts and incident windows.  This module
closes that gap without adding a single branch to the simulator's hot
path: a recorded trace is validated, resampled onto the normalized run
clock ``[0, 1)``, and compiled into the exact same piecewise
`Segment`/`Scenario` representation every other scenario uses — so
``simulate(..., scenario="trace")``, `sweep`, `drift_study`,
`HostPlayback` (serving engine + data pipeline) and
``bench_serving.bench_scenarios`` all replay it through the seam PR 2
built.

The pieces:

  * **Schema** — `Trace` (per-interval arrival counts plus optional
    per-interval key-skew annotations ``p_hot`` / ``hot_rack``) and
    `Incident` (a straggler or rack-congestion window over a span of
    intervals).  Intervals are uniform in wall time (``interval``
    seconds each); the compiler maps interval ``i`` of ``N`` onto the
    run fraction ``[i/N, (i+1)/N)``.
  * **Loader / saver** — JSONL (full schema, incident records included)
    and CSV (arrival + skew columns only) via `load_trace` / `save_trace`;
    round-trips are lossless, so an exported trace replays bit-for-bit.
  * **Compiler** — `trace_to_scenario`: unit-mean arrival normalization
    (a load expressed as a fraction of static fluid capacity offers the
    same long-run traffic under every replayed trace) and change-point
    merging (adjacent intervals whose knobs agree within a tolerance
    collapse into one segment; the tolerance doubles until the segment
    count fits ``max_segments``, so a 10k-interval trace compiles to a
    bounded, `lax.scan`-friendly schedule).  Merging averages arrivals
    over equal-length intervals, so the time-average — and therefore the
    offered load — is preserved *exactly*, not approximately.
  * **Generator** — `synthesize_trace` builds deterministic reference
    traces ("diurnal_week", "flash_day"); the copies checked in under
    ``workloads/traces/`` are its exact output (pinned by
    tests/test_trace.py) and load by name through `load_bundled`.
  * **Export hook** — `trace_from_arrivals` bins recorded arrival steps
    (e.g. `ServingEngine.arrival_log`) back into a `Trace`, so any
    benchmark run can be re-recorded and replayed deterministically.

A constant trace (no skew annotations, no incidents) compiles to the same
single-segment schedule as the ``"static"`` scenario, so its simulator
sample paths are bitwise identical — pinned by tests/test_trace.py.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import math
import numbers
from pathlib import Path
from typing import (Dict, List, Mapping, Optional, Sequence, Tuple, Union)

import numpy as np

from repro.workloads.scenario import Scenario, Segment, register_scenario

TRACE_VERSION = 1
INCIDENT_KINDS = ("straggler", "rack_congestion")


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Incident:
    """One incident window over a span of trace intervals.

    kind      -- "straggler" (per-server slowdown) or "rack_congestion"
                 (tier-wide sag of the rack-local / remote rates)
    start/end -- interval span [start, end), end exclusive
    servers   -- straggler only: affected server ids (mod fleet at compile)
    factor    -- straggler only: TRUE-rate multiplier in (0, 1)
    tier_mult -- congestion only: (local, rack, remote) TRUE-rate multipliers
    """

    kind: str
    start: int
    end: int
    servers: Tuple[int, ...] = ()
    factor: float = 0.25
    tier_mult: Tuple[float, float, float] = (1.0, 1.0, 1.0)

    def __post_init__(self):
        if self.kind not in INCIDENT_KINDS:
            raise ValueError(f"unknown incident kind {self.kind!r}; "
                             f"expected one of {INCIDENT_KINDS}")
        if not 0 <= self.start < self.end:
            raise ValueError(f"incident needs 0 <= start < end, got "
                             f"[{self.start}, {self.end})")
        if self.kind == "straggler":
            if not self.servers:
                raise ValueError("straggler incident needs `servers`")
            if not 0.0 < self.factor < 1.0:
                raise ValueError(f"straggler factor must be in (0, 1), "
                                 f"got {self.factor}")
        if self.kind == "rack_congestion":
            if len(self.tier_mult) != 3 or any(m <= 0.0
                                               for m in self.tier_mult):
                raise ValueError(f"tier_mult must be 3 positive values, "
                                 f"got {self.tier_mult}")
        object.__setattr__(self, "servers",
                           tuple(int(s) for s in self.servers))
        object.__setattr__(self, "tier_mult",
                           tuple(float(m) for m in self.tier_mult))


@dataclasses.dataclass(frozen=True, eq=False)
class Trace:
    """A recorded cluster trace: uniform intervals of ``interval`` wall
    seconds, each carrying an arrival count and optional key-skew
    annotations, plus incident windows.

    arrivals     -- (N,) per-interval arrival counts (>= 0; any real scale —
                    the compiler normalizes to unit mean)
    p_hot        -- optional (N,) hot-traffic fraction per interval; keep the
                    values quantized to a few levels (every distinct value
                    starts a new segment that merging must preserve)
    hot_rack     -- optional (N,) rack receiving the hot traffic
    rack_weights -- optional (N, R) per-rack arrival weights (the
                    many-rack generalization of hot_rack: the skewed
                    traffic draws its rack from this vector); quantize to
                    a few distinct rows, like p_hot
    """

    name: str
    interval: float
    arrivals: np.ndarray
    p_hot: Optional[np.ndarray] = None
    hot_rack: Optional[np.ndarray] = None
    incidents: Tuple[Incident, ...] = ()
    rack_weights: Optional[np.ndarray] = None

    def __post_init__(self):
        arr = np.asarray(self.arrivals, np.float64)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError(f"arrivals must be a non-empty 1-d array, "
                             f"got shape {arr.shape}")
        if not np.isfinite(arr).all() or (arr < 0).any():
            raise ValueError("arrivals must be finite and >= 0")
        if not (isinstance(self.interval, numbers.Real) and self.interval > 0):
            raise ValueError(f"interval must be > 0, got {self.interval}")
        object.__setattr__(self, "arrivals", arr)
        n = arr.size
        if self.p_hot is not None:
            ph = np.asarray(self.p_hot, np.float64)
            if ph.shape != (n,):
                raise ValueError(f"p_hot must have shape ({n},), "
                                 f"got {ph.shape}")
            if ((ph < 0) | (ph > 1)).any() or not np.isfinite(ph).all():
                raise ValueError("p_hot values must be in [0, 1]")
            object.__setattr__(self, "p_hot", ph)
        if self.hot_rack is not None:
            hr = np.asarray(self.hot_rack, np.int64)
            if hr.shape != (n,):
                raise ValueError(f"hot_rack must have shape ({n},), "
                                 f"got {hr.shape}")
            if (hr < 0).any():
                raise ValueError("hot_rack ids must be >= 0")
            object.__setattr__(self, "hot_rack", hr)
        if self.rack_weights is not None:
            rw = np.asarray(self.rack_weights, np.float64)
            if rw.ndim != 2 or rw.shape[0] != n or rw.shape[1] < 1:
                raise ValueError(f"rack_weights must have shape ({n}, R), "
                                 f"got {rw.shape}")
            if not np.isfinite(rw).all() or (rw < 0).any() or \
                    (rw.sum(axis=1) <= 0).any():
                raise ValueError("rack_weights rows must be non-negative "
                                 "with positive sums")
            object.__setattr__(self, "rack_weights", rw)
        for inc in self.incidents:
            if inc.end > n:
                raise ValueError(f"incident [{inc.start}, {inc.end}) runs "
                                 f"past the trace ({n} intervals)")
        object.__setattr__(self, "incidents", tuple(self.incidents))

    @property
    def num_intervals(self) -> int:
        return int(self.arrivals.size)

    @property
    def duration(self) -> float:
        """Wall-clock span of the whole trace, seconds."""
        return float(self.interval * self.num_intervals)

    def __eq__(self, other) -> bool:
        if not isinstance(other, Trace):
            return NotImplemented

        def arr_eq(a, b):
            return (a is None) == (b is None) and (
                a is None or np.array_equal(a, b))
        return (self.name == other.name
                and self.interval == other.interval
                and arr_eq(self.arrivals, other.arrivals)
                and arr_eq(self.p_hot, other.p_hot)
                and arr_eq(self.hot_rack, other.hot_rack)
                and arr_eq(self.rack_weights, other.rack_weights)
                and self.incidents == other.incidents)


# ---------------------------------------------------------------------------
# Loader / saver (JSONL + CSV)
# ---------------------------------------------------------------------------


def save_trace(trace: Trace, path: Union[str, Path]) -> Path:
    """Write a trace to `path`: JSONL for ``.jsonl``/``.json`` (full
    schema), CSV for ``.csv`` (interval columns only — incident windows
    have no CSV representation and raise)."""
    path = Path(path)
    if path.suffix == ".csv":
        if trace.incidents:
            raise ValueError("CSV traces cannot carry incident records; "
                             "save as .jsonl instead")
        if trace.rack_weights is not None:
            raise ValueError("CSV traces cannot carry rack_weights vectors; "
                             "save as .jsonl instead")
        cols = ["arrivals"]
        if trace.p_hot is not None:
            cols.append("p_hot")
        if trace.hot_rack is not None:
            cols.append("hot_rack")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["# name", trace.name, "interval", trace.interval])
            w.writerow(cols)
            for i in range(trace.num_intervals):
                row: List[object] = [_num(trace.arrivals[i])]
                if trace.p_hot is not None:
                    row.append(_num(trace.p_hot[i]))
                if trace.hot_rack is not None:
                    row.append(int(trace.hot_rack[i]))
                w.writerow(row)
        return path
    with open(path, "w") as f:
        head = {"record": "header", "version": TRACE_VERSION,
                "name": trace.name, "interval": trace.interval}
        f.write(json.dumps(head) + "\n")
        for i in range(trace.num_intervals):
            rec: Dict[str, object] = {"record": "interval",
                                      "arrivals": _num(trace.arrivals[i])}
            if trace.p_hot is not None:
                rec["p_hot"] = _num(trace.p_hot[i])
            if trace.hot_rack is not None:
                rec["hot_rack"] = int(trace.hot_rack[i])
            if trace.rack_weights is not None:
                rec["rack_weights"] = [_num(w) for w in trace.rack_weights[i]]
            f.write(json.dumps(rec) + "\n")
        for inc in trace.incidents:
            rec = {"record": "incident", "kind": inc.kind,
                   "start": inc.start, "end": inc.end}
            if inc.kind == "straggler":
                rec["servers"] = list(inc.servers)
                rec["factor"] = inc.factor
            else:
                rec["tier_mult"] = list(inc.tier_mult)
            f.write(json.dumps(rec) + "\n")
    return path


def _num(x: float) -> Union[int, float]:
    """Integral floats serialize as ints (arrival counts stay readable and
    round-trip exactly)."""
    f = float(x)
    return int(f) if f.is_integer() and abs(f) < 2**53 else f


def load_trace(path: Union[str, Path]) -> Trace:
    """Read a JSONL or CSV trace written by `save_trace` (or by hand)."""
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"no trace file at {path}")
    if path.suffix == ".csv":
        return _load_csv(path)
    return _load_jsonl(path)


def _load_jsonl(path: Path) -> Trace:
    name, interval = path.stem, 1.0
    arrivals: List[float] = []
    p_hot: List[float] = []
    hot_rack: List[int] = []
    rack_weights: List[List[float]] = []
    incidents: List[Incident] = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: invalid JSON: {e}") from None
            kind = rec.get("record")
            if kind == "header":
                if rec.get("version", TRACE_VERSION) > TRACE_VERSION:
                    raise ValueError(f"{path}: trace version "
                                     f"{rec['version']} is newer than "
                                     f"supported ({TRACE_VERSION})")
                name = rec.get("name", name)
                interval = float(rec.get("interval", interval))
            elif kind == "interval":
                arrivals.append(float(rec["arrivals"]))
                if "p_hot" in rec:
                    p_hot.append(float(rec["p_hot"]))
                if "hot_rack" in rec:
                    hot_rack.append(int(rec["hot_rack"]))
                if "rack_weights" in rec:
                    rack_weights.append(
                        [float(w) for w in rec["rack_weights"]])
            elif kind == "incident":
                incidents.append(Incident(
                    kind=rec["kind"], start=int(rec["start"]),
                    end=int(rec["end"]),
                    servers=tuple(rec.get("servers", ())),
                    factor=float(rec.get("factor", 0.25)),
                    tier_mult=tuple(rec.get("tier_mult", (1.0, 1.0, 1.0)))))
            else:
                raise ValueError(f"{path}:{ln}: unknown record type "
                                 f"{kind!r}")
    if p_hot and len(p_hot) != len(arrivals):
        raise ValueError(f"{path}: p_hot must be annotated on all intervals "
                         f"or none ({len(p_hot)}/{len(arrivals)} annotated)")
    if hot_rack and len(hot_rack) != len(arrivals):
        raise ValueError(f"{path}: hot_rack must be annotated on all "
                         f"intervals or none "
                         f"({len(hot_rack)}/{len(arrivals)} annotated)")
    if rack_weights and len(rack_weights) != len(arrivals):
        raise ValueError(f"{path}: rack_weights must be annotated on all "
                         f"intervals or none "
                         f"({len(rack_weights)}/{len(arrivals)} annotated)")
    return Trace(name=name, interval=interval,
                 arrivals=np.asarray(arrivals, np.float64),
                 p_hot=np.asarray(p_hot, np.float64) if p_hot else None,
                 hot_rack=np.asarray(hot_rack, np.int64) if hot_rack else None,
                 incidents=tuple(incidents),
                 rack_weights=(np.asarray(rack_weights, np.float64)
                               if rack_weights else None))


def _load_csv(path: Path) -> Trace:
    name, interval = path.stem, 1.0
    with open(path, newline="") as f:
        rows = [r for r in csv.reader(f) if r]
    if rows and rows[0] and rows[0][0].startswith("#"):
        meta = rows.pop(0)
        kv = dict(zip(meta[::2], meta[1::2]))
        name = kv.get("# name", name)
        interval = float(kv.get("interval", interval))
    if not rows:
        raise ValueError(f"{path}: empty CSV trace")
    cols = [c.strip() for c in rows.pop(0)]
    if "arrivals" not in cols:
        raise ValueError(f"{path}: CSV trace needs an `arrivals` column, "
                         f"got {cols}")
    data = {c: [] for c in cols}
    for r in rows:
        for c, v in zip(cols, r):
            data[c].append(v)
    return Trace(
        name=name, interval=interval,
        arrivals=np.asarray(data["arrivals"], np.float64),
        p_hot=(np.asarray(data["p_hot"], np.float64)
               if "p_hot" in data else None),
        hot_rack=(np.asarray(data["hot_rack"], np.int64)
                  if "hot_rack" in data else None))


# ---------------------------------------------------------------------------
# Compiler: Trace -> Scenario (unit-mean + change-point merging)
# ---------------------------------------------------------------------------


def _interval_knobs(trace: Trace):
    """Per-interval aux knobs (everything except the arrival track):
    (p_hot, hot_rack, tier_mult, slow_servers-items) tuples.  Intervals
    with identical aux knobs form the runs inside which arrival merging
    is allowed — aux changes are exact change-points that survive any
    merge tolerance."""
    n = trace.num_intervals
    tier = np.ones((n, 3), np.float64)
    slow: List[Dict[int, float]] = [{} for _ in range(n)]
    for inc in trace.incidents:
        for i in range(inc.start, inc.end):
            if inc.kind == "straggler":
                for s in inc.servers:
                    slow[i][s] = slow[i].get(s, 1.0) * inc.factor
            else:
                tier[i] *= inc.tier_mult
    keys = []
    for i in range(n):
        keys.append((
            None if trace.p_hot is None else float(trace.p_hot[i]),
            0 if trace.hot_rack is None else int(trace.hot_rack[i]),
            tuple(float(m) for m in tier[i]),
            tuple(sorted(slow[i].items())),
            None if trace.rack_weights is None
            else tuple(float(w) for w in trace.rack_weights[i]),
        ))
    return keys


def _segment_runs(lam: np.ndarray, keys: Sequence, tol: float) -> List[int]:
    """Greedy change-point segmentation: one pass over intervals, breaking
    wherever the aux knobs change or the arrival band (max - min of the
    open segment) would exceed `tol`.  Returns segment start indices."""
    starts = [0]
    lo = hi = lam[0]
    for i in range(1, len(lam)):
        lo, hi = min(lo, lam[i]), max(hi, lam[i])
        if keys[i] != keys[i - 1] or hi - lo > tol:
            starts.append(i)
            lo = hi = lam[i]
    return starts


def trace_to_scenario(trace: Trace, max_segments: int = 64,
                      tol: float = 0.05, normalize: bool = True) -> Scenario:
    """Compile a trace into a piecewise-constant `Scenario` on [0, 1).

    normalize    -- divide arrivals by their mean so the compiled
                    ``lam_mult`` track has unit time-average (same long-run
                    offered load as every built-in scenario); pass False to
                    replay the raw counts as absolute multipliers.
    tol          -- initial arrival-band tolerance for merging, in units of
                    the (normalized) multiplier; adjacent intervals whose
                    arrivals stay within one band collapse into a segment.
    max_segments -- bound on the compiled segment count: the tolerance
                    doubles until the schedule fits.  Aux change-points
                    (skew annotations, incident boundaries) are never
                    merged away, so a trace whose aux knobs change more
                    than `max_segments` times cannot be compiled — quantize
                    the annotations instead.

    Merging replaces each segment's arrivals with their plain mean over
    equal-length intervals, so the trace's time-average arrival rate is
    preserved exactly at any tolerance.
    """
    if max_segments < 1:
        raise ValueError(f"max_segments must be >= 1, got {max_segments}")
    if tol <= 0:
        raise ValueError(f"tol must be > 0, got {tol}")
    lam = trace.arrivals
    if normalize:
        mean = float(lam.mean())
        if mean <= 0:
            raise ValueError(f"trace {trace.name!r} has zero mean arrivals; "
                             "nothing to normalize")
        lam = lam / mean
    keys = _interval_knobs(trace)
    aux_runs = 1 + sum(keys[i] != keys[i - 1] for i in range(1, len(keys)))
    if aux_runs > max_segments:
        raise ValueError(
            f"trace {trace.name!r} has {aux_runs} annotation/incident "
            f"change-points but max_segments={max_segments}; quantize the "
            f"p_hot/hot_rack annotations or raise max_segments")
    # Widen the arrival band until the schedule fits, then binary-refine
    # back toward the tightest feasible tolerance — the compiled schedule
    # uses as much of the segment budget as the trace's structure needs.
    starts = _segment_runs(lam, keys, tol)
    if len(starts) > max_segments:
        lo, hi = tol, tol
        while len(starts) > max_segments:
            lo, hi = hi, hi * 2.0
            starts = _segment_runs(lam, keys, hi)
        for _ in range(16):
            mid = 0.5 * (lo + hi)
            mid_starts = _segment_runs(lam, keys, mid)
            if len(mid_starts) <= max_segments:
                hi, starts = mid, mid_starts
            else:
                lo = mid
    n = trace.num_intervals
    bounds = starts + [n]
    segments = []
    for a, b in zip(bounds, bounds[1:]):
        p_hot, hot_rack, tier, slow, weights = keys[a]
        segments.append(Segment(
            start=a / n,
            lam_mult=float(lam[a:b].mean()),
            p_hot=p_hot,
            hot_rack=hot_rack,
            tier_mult=tier,
            slow_servers=dict(slow),
            rack_weights=weights))
    return Scenario(f"trace:{trace.name}", tuple(segments))


# ---------------------------------------------------------------------------
# Synthetic reference traces + the bundled copies
# ---------------------------------------------------------------------------

_TRACE_DIR = Path(__file__).parent / "traces"
_BUNDLED_FILES = {"diurnal_week": "diurnal_week.jsonl",
                  "flash_day": "flash_day.csv"}


def synthesize_trace(kind: str = "diurnal_week", seed: int = 0) -> Trace:
    """Deterministic reference traces (the bundled files are this
    function's exact output for seed 0; pinned by tests/test_trace.py).

    "diurnal_week" -- 7 days of 10-minute intervals (1008): sinusoidal
        day/night load with a weekend dip, business-hours key skew
        (``p_hot`` stepping 0.45 -> 0.62), and a 6-hour straggler
        incident on day 3.
    "flash_day"    -- one day of 5-minute intervals (288): flat load with
        Poisson noise and a 2.6x flash crowd during 14:00-15:00.  No
        annotations or incidents, so it round-trips through CSV.
    """
    if kind not in _BUNDLED_FILES:
        raise ValueError(f"unknown synthetic trace kind {kind!r}; "
                         f"expected one of {tuple(_BUNDLED_FILES)}")
    rng = np.random.default_rng(np.random.SeedSequence(
        [seed, list(_BUNDLED_FILES).index(kind)]))
    if kind == "diurnal_week":
        per_day = 144  # 10-minute intervals
        n = 7 * per_day
        u = (np.arange(n) % per_day) / per_day  # time of day in [0, 1)
        day = np.arange(n) // per_day
        base = 120.0 * (1.0 + 0.35 * np.sin(2.0 * np.pi * (u - 0.25)))
        base = base * np.where(day >= 5, 0.72, 1.0)  # weekend dip
        arrivals = rng.poisson(base).astype(np.float64)
        # business-hours key skew, quantized to two levels so the compiled
        # schedule stays bounded (3 aux runs per day)
        p_hot = np.where((u >= 0.375) & (u < 0.75), 0.62, 0.45)
        incidents = (Incident("straggler",
                              start=3 * per_day + 60, end=3 * per_day + 96,
                              servers=(4, 5), factor=0.3),)
        return Trace("diurnal_week", interval=600.0, arrivals=arrivals,
                     p_hot=p_hot, incidents=incidents)
    n = 288  # flash_day: 5-minute intervals
    base = np.full(n, 95.0)
    base[168:180] *= 2.6  # flash crowd 14:00-15:00
    arrivals = rng.poisson(base).astype(np.float64)
    return Trace("flash_day", interval=300.0, arrivals=arrivals)


def bundled_traces() -> Tuple[str, ...]:
    """Names of the example traces checked in under ``workloads/traces/``."""
    return tuple(sorted(_BUNDLED_FILES))


def load_bundled(name: str) -> Trace:
    """Load one of the bundled example traces by name."""
    try:
        fname = _BUNDLED_FILES[name]
    except KeyError:
        raise ValueError(f"unknown bundled trace {name!r}; "
                         f"available: {bundled_traces()}") from None
    return load_trace(_TRACE_DIR / fname)


@register_scenario("trace")
def trace_scenario(path: Optional[Union[str, Path]] = None,
                   name: Optional[str] = None, max_segments: int = 64,
                   tol: float = 0.05, normalize: bool = True) -> Scenario:
    """Replay a recorded cluster trace (JSONL/CSV of per-interval arrival
    counts, key-skew annotations, and incident windows), compiled to the
    same piecewise schedule as every synthetic scenario; `path` loads a
    trace file, `name` one of the bundled examples (default
    "diurnal_week")."""
    if path is not None and name is not None:
        raise ValueError("pass either path= or name=, not both")
    tr = load_trace(path) if path is not None \
        else load_bundled(name or "diurnal_week")
    return trace_to_scenario(tr, max_segments=max_segments, tol=tol,
                             normalize=normalize)


# ---------------------------------------------------------------------------
# Export hook: re-record a run as a trace
# ---------------------------------------------------------------------------


def trace_from_arrivals(steps: Sequence[float], num_intervals: int,
                        name: str = "recorded", horizon: Optional[float] = None,
                        interval: Optional[float] = None) -> Trace:
    """Bin recorded arrival times (engine steps, slots, seconds — any
    monotone clock) into a per-interval `Trace`, the inverse of
    `arrival_steps`: export a live run, `save_trace` it, and the same
    traffic replays deterministically via ``scenario="trace"``.

    horizon  -- clock span covered by the trace; default: just past the
                last arrival.
    interval -- wall seconds per bin recorded as metadata; default:
                horizon / num_intervals (one clock unit == one second).
    """
    if num_intervals < 1:
        raise ValueError(f"num_intervals must be >= 1, got {num_intervals}")
    steps = np.asarray(steps, np.float64)
    if steps.size and ((steps < 0).any() or not np.isfinite(steps).all()):
        raise ValueError("arrival steps must be finite and >= 0")
    if horizon is None:
        horizon = float(steps.max()) + 1.0 if steps.size else float(num_intervals)
    if horizon <= 0:
        raise ValueError(f"horizon must be > 0, got {horizon}")
    if steps.size and steps.max() >= horizon:
        raise ValueError(f"arrivals at step {steps.max()} fall outside "
                         f"horizon {horizon}")
    counts, _ = np.histogram(steps, bins=num_intervals, range=(0.0, horizon))
    return Trace(name=name,
                 interval=float(interval if interval is not None
                                else horizon / num_intervals),
                 arrivals=counts.astype(np.float64))
