"""Test-suite bootstrap.

Two jobs:

1. Opt-in persistent XLA compilation cache (`REPRO_JAX_CACHE_DIR=...`):
   the suite jit-compiles hundreds of small programs plus a handful of
   expensive fleet-scale ones; on a warm cache a full run saves minutes
   of single-core compile time.  Unset, nothing changes.

2. When the real `hypothesis` package is unavailable (minimal containers
   where nothing can be pip-installed), install a tiny deterministic
   stand-in so the suite still collects and the property tests still run —
   each `@given` test executes a fixed number of seeded pseudo-random
   examples instead of hypothesis's managed search.  The stub covers
   exactly the strategy surface this repo uses (`integers`, `floats`,
   `lists`, `sampled_from`); with hypothesis installed (see
   pyproject.toml) it is never touched.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

from repro.utils.cache import enable_persistent_cache

enable_persistent_cache()

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # sample(rng) -> value

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def lists(elements, min_size=0, max_size=10, unique=False):
        def sample(r):
            n = r.randint(min_size, max_size)
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 10_000:
                tries += 1
                v = elements.sample(r)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out
        return _Strategy(sample)

    # Cap examples: the stub has no shrinking/database, so keep the fallback
    # suite fast; the declared max_examples applies under real hypothesis.
    _STUB_CAP = 20

    def given(*strategies, **kw_strategies):
        def deco(fn):
            n = min(getattr(fn, "_stub_max_examples", 10), _STUB_CAP)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # crc32, not hash(): str hashing is salted per process and
                # would break run-to-run reproducibility of the examples.
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random((base ^ (i * 0x9E3779B9))
                                        & 0xFFFFFFFF)
                    drawn = [s.sample(rng) for s in strategies]
                    kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kw)

            # Hide the wrapped signature from pytest, which would otherwise
            # resolve the strategy-filled parameters as fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._stub_max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.lists = lists
    st_mod.sampled_from = sampled_from

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
