"""Behavioural tests for the four scheduling algorithms (paper §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (balanced_pandas as bp, fifo, jsq_maxweight as mw,
                        priority, locality as loc)

TOPO = loc.Topology(12, 4)  # 3 racks of 4 — small for tests
RACK_OF = jnp.asarray(TOPO.rack_of, jnp.int32)
TRUE3 = jnp.array([0.5, 0.45, 0.25], jnp.float32)
EST = jnp.tile(TRUE3[None, :], (12, 1))


def _arrivals(key, lam=3.0, n=8, p_hot=0.0):
    traffic = loc.Traffic(lam_total=lam, p_hot=p_hot, max_arrivals=n)
    k1, k2 = jax.random.split(key)
    num = jnp.minimum(jax.random.poisson(k1, lam), n)
    active = jnp.arange(n) < num
    types = loc.sample_task_types(k2, TOPO, traffic, n)
    return types, active


# ---------------------------------------------------------------- PANDAS ---

def test_pandas_routes_to_min_weighted_workload():
    s = bp.init_state(TOPO)
    # Uniform base workload (W=4 everywhere) so the rate division
    # differentiates tiers; overload server 0 so it is never picked.
    # Queue matrix columns: 0 local, 1 rack-local, 2 remote.
    s = s._replace(q=s.q.at[:, 0].set(2).at[0, 0].set(10))
    task = jnp.array([0, 1, 2], jnp.int32)
    s2 = bp.route_one(s, jax.random.PRNGKey(0), task, jnp.bool_(True), EST,
                      RACK_OF)
    # Scores: server 0: (10/.5)/.5=40; locals 1,2: (2/.5)/.5=8;
    # rack-local 3: 4/.45=8.9; remotes: 4/.25=16 -> join 1 or 2 (local).
    assert int(s2.q[0, 0]) == 10
    assert int(s2.q[1, 0] + s2.q[2, 0]) == 5  # 2+2 base + 1 arrival


def test_pandas_remote_routing_when_locals_swamped():
    s = bp.init_state(TOPO)
    # All rack-0/1 servers (locals + rack-locals) swamped; remotes empty.
    s = s._replace(q=s.q.at[:8, 0].set(100))
    task = jnp.array([0, 1, 4], jnp.int32)  # locals in racks 0 and 1
    s2 = bp.route_one(s, jax.random.PRNGKey(0), task, jnp.bool_(True), EST,
                      RACK_OF)
    assert int(jnp.sum(s2.q[8:, 2])) == 1  # went remote to rack 2


def test_pandas_scheduling_priority_order():
    s = bp.init_state(TOPO)
    s = s._replace(q=s.q.at[3, 1].set(1).at[3, 2].set(1))
    types = jnp.zeros((1, 3), jnp.int32)
    active = jnp.zeros((1,), bool)
    s2, _ = bp.slot_step(s, jax.random.PRNGKey(0), types, active, EST, TRUE3,
                         RACK_OF)
    # Idle server 3 must pick the rack-local task first.
    assert int(s2.serving[3]) == loc.RACK_LOCAL
    assert int(s2.q[3, 1]) == 0 and int(s2.q[3, 2]) == 1


def test_pandas_conservation_and_nonnegativity():
    step = jax.jit(lambda s, k, ty, ac: bp.slot_step(s, k, ty, ac, EST, TRUE3,
                                                     RACK_OF))
    s = bp.init_state(TOPO)
    arrived = completed = 0
    for t in range(200):
        key = jax.random.PRNGKey(t)
        types, active = _arrivals(jax.random.fold_in(key, 1))
        s, compl = step(s, jax.random.fold_in(key, 2), types, active)
        arrived += int(jnp.sum(active))
        completed += int(compl)
        assert (np.asarray(s.q) >= 0).all()
    assert int(bp.num_in_system(s)) == arrived - completed


def test_pandas_workload_includes_in_service_residual():
    s = bp.init_state(TOPO)
    s = s._replace(serving=s.serving.at[0].set(loc.LOCAL),
                   q=s.q.at[0, 0].set(2))
    w = bp.workload(s, EST)
    assert float(w[0]) == pytest.approx(3 / 0.5)  # (2 queued + 1 serving)/alpha
    assert float(w[1]) == 0.0


# ------------------------------------------------------ scale invariance ---

@pytest.mark.parametrize("algo", [bp, mw])
def test_uniform_rate_scaling_is_decision_invariant(algo):
    """Beyond-paper analytical result: scaling all estimates by c changes no
    decision, hence the whole sample path (see balanced_pandas docstring)."""
    step = jax.jit(algo.slot_step)  # one compile, shared by both rollouts

    def rollout(est):
        s = algo.init_state(TOPO)
        ns = []
        for t in range(60):
            key = jax.random.PRNGKey(t)
            types, active = _arrivals(jax.random.fold_in(key, 1), lam=4.0)
            s, _ = step(s, jax.random.fold_in(key, 2), types, active, est,
                        TRUE3, RACK_OF)
            ns.append(int(algo.num_in_system(s)))
        return ns

    assert rollout(EST) == rollout(EST * 0.7)


# ------------------------------------------------------------------ JSQ-MW -

def test_jsq_routing_joins_shortest_local_queue():
    from repro.core import claiming
    q = jnp.zeros((12,), jnp.int32).at[0].set(5).at[1].set(3).at[2].set(7)
    task = jnp.array([0, 1, 2], jnp.int32)
    q2 = claiming.jsq_route_one(q, jax.random.PRNGKey(0), task, jnp.bool_(True))
    assert int(q2[1]) == 4  # joined the shortest (3 < 5 < 7)
    assert int(q2[0]) == 5 and int(q2[2]) == 7


def test_jsq_mw_slot_conserves_tasks():
    s = mw.init_state(TOPO)
    s = s._replace(q=s.q.at[0].set(5).at[1].set(3).at[2].set(7))
    types = jnp.array([[0, 1, 2]], jnp.int32)
    active = jnp.ones((1,), bool)
    s2, _ = mw.slot_step(s, jax.random.PRNGKey(0), types, active, EST, TRUE3,
                         RACK_OF)
    total_before = 5 + 3 + 7 + 1
    started = int(jnp.sum(s2.serving_tier > 0))
    assert int(jnp.sum(s2.q)) == total_before - started


def test_maxweight_claim_prefers_weighted_longest():
    s = mw.init_state(TOPO)
    # Queue 0 long but remote to server 8 (rack 2); queue 9 short but local-ish
    # (same rack as 8). Weighted: gamma*20=5 vs beta*12=5.4 -> picks 9.
    s = s._replace(q=s.q.at[0].set(20).at[9].set(12))
    sid = jnp.arange(12)
    score = loc.pair_rate(jnp.int32(8), sid, RACK_OF, TRUE3) * s.q
    assert int(jnp.argmax(score)) == 9


def test_jsq_mw_conservation():
    step = jax.jit(lambda s, k, ty, ac: mw.slot_step(s, k, ty, ac, EST, TRUE3,
                                                     RACK_OF))
    s = mw.init_state(TOPO)
    arrived = completed = 0
    for t in range(200):
        key = jax.random.PRNGKey(1000 + t)
        types, active = _arrivals(jax.random.fold_in(key, 1))
        s, compl = step(s, jax.random.fold_in(key, 2), types, active)
        arrived += int(jnp.sum(active))
        completed += int(compl)
        assert (np.asarray(s.q) >= 0).all()
    assert int(mw.num_in_system(s)) == arrived - completed


# ---------------------------------------------------------------- Priority -

def test_priority_serves_own_queue_first():
    s = priority.init_state(TOPO)
    s = s._replace(q=s.q.at[3].set(1).at[7].set(50))
    types = jnp.zeros((1, 3), jnp.int32)
    active = jnp.zeros((1,), bool)
    s2, _ = priority.slot_step(s, jax.random.PRNGKey(0), types, active, EST,
                               TRUE3, RACK_OF)
    # Server 3 serves its own (local) task at rate alpha despite queue 7
    # being much longer.
    assert int(s2.serving_tier[3]) == loc.LOCAL
    assert int(s2.q[3]) == 0


# -------------------------------------------------------------------- FIFO -

def test_fifo_order_and_drops():
    s = fifo.init_state(TOPO, cap=4)
    types = jnp.tile(jnp.array([[0, 1, 2]], jnp.int32), (6, 1))
    active = jnp.ones((6,), bool)
    # 12 idle servers would drain everything pushed; to test drops push with
    # no servers available: pre-mark all servers busy, with near-zero true
    # rates so none of them completes (and frees up) within the slot.
    s = s._replace(serving_tier=jnp.full((12,), loc.REMOTE, jnp.int32))
    s2, _ = fifo.slot_step(s, jax.random.PRNGKey(0), types, active, EST,
                           jnp.full((3,), 1e-9, jnp.float32), RACK_OF)
    assert int(s2.count) == 4
    assert int(s2.drops) == 2


def test_fifo_conservation():
    step = jax.jit(lambda s, k, ty, ac: fifo.slot_step(s, k, ty, ac, EST,
                                                       TRUE3, RACK_OF))
    s = fifo.init_state(TOPO, cap=512)
    arrived = completed = dropped = 0
    for t in range(150):
        key = jax.random.PRNGKey(2000 + t)
        types, active = _arrivals(jax.random.fold_in(key, 1))
        s, compl = step(s, jax.random.fold_in(key, 2), types, active)
        arrived += int(jnp.sum(active))
        completed += int(compl)
    dropped = int(s.drops)
    assert int(fifo.num_in_system(s)) == arrived - completed - dropped


# ------------------------------------------------------------ claim safety -

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_claim_loop_never_overdraws(seed):
    """Property: after the claim loop, queues stay >= 0 and the number of
    newly started services equals the number of claimed tasks."""
    key = jax.random.PRNGKey(seed)
    q0 = jax.random.randint(jax.random.fold_in(key, 0), (12,), 0, 3)
    busy = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (12,))
    st0 = jnp.where(busy, loc.LOCAL, 0).astype(jnp.int32)
    from repro.core import claiming
    sid = jnp.arange(12)

    def score_fn(m, qv):
        return loc.pair_rate(m, sid, RACK_OF, TRUE3) * qv.astype(jnp.float32)

    def tier_fn(m, n):
        return claiming.pair_tier(m, n, RACK_OF)

    q1, sr1 = claiming.claim_loop(q0.astype(jnp.int32), st0,
                                  jax.random.fold_in(key, 2), score_fn,
                                  tier_fn)
    assert (np.asarray(q1) >= 0).all()
    started = int(jnp.sum((sr1 > 0) & ~busy))
    claimed = int(jnp.sum(q0) - jnp.sum(q1))
    assert started == claimed
    n_idle = int(jnp.sum(~busy))
    assert claimed == min(n_idle, int(jnp.sum(q0)))
