"""Control-plane subsystem (`repro.control`): registry surface, the
bitwise-off discipline, conservation invariants (property-tested), both
projections (lax.scan simulator + host serving engine), and the
sojourn-histogram satellites.

The load-bearing guarantee mirrors the scenario/placement/telemetry
subsystems: ``control=None`` compiles NOTHING — every metric of every
registered policy is bitwise identical to the pre-control simulator —
and the one documented exception to telemetry purity (``slo_pandas``,
``uses_signals``) degrades to bitwise Balanced-PANDAS whenever the
signals it conditions on are absent.
"""

import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.control import (ClosedLoopClients, ControlConfig, ControlPlane,
                           available_controllers, controller_descriptions,
                           make_controller, register_controller,
                           resolve_control, scale_priority)
from repro.control.plane import AdmissionController
from repro.core import locality as loc, simulator as sim
from repro.core.policy import available_policies, get_policy_cls
from repro.launch.elastic import Autoscaler

TOPO = loc.Topology(12, 4)  # K=3: 3 racks of 4
CFG = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), max_arrivals=16,
                    horizon=400, warmup=100)
CAP = loc.capacity_hot_rack(CFG.topo, CFG.true_rates, CFG.p_hot)
EST = sim.make_estimates(CFG, "network", 0.0, -1)


# -- registry ---------------------------------------------------------------

def test_builtin_controllers_registered():
    assert set(available_controllers()) == {
        "open_loop", "closed_loop", "token_bucket", "queue_threshold",
        "autoscale"}
    desc = controller_descriptions()
    assert set(desc) == set(available_controllers())
    for name, line in desc.items():
        assert line.startswith("[") and "]" in line, (name, line)
        assert "\n" not in line


def test_registry_rejects_bad_registrations():
    with pytest.raises(ValueError, match="duplicate"):
        register_controller(type("Dup", (AdmissionController,),
                                 {"name": "token_bucket"}))
    with pytest.raises(ValueError, match="kind"):
        register_controller(type("BadKind", (AdmissionController,),
                                 {"name": "bad_kind_ctl", "kind": "nope"}))
    with pytest.raises(ValueError, match="registered"):
        make_controller("no_such_controller")


def test_resolve_control_seam():
    assert resolve_control(None) is None
    one = resolve_control("token_bucket")
    assert isinstance(one, ControlPlane) and one.admission is not None
    assert resolve_control(one) is one
    # JSON-friendly mapping + options reach the controller
    m = resolve_control({"name": "token_bucket",
                         "options": {"rate": 2.5, "defer": True}})
    assert m.admission.rate == 2.5 and m.admission.defers
    both = resolve_control([ControlConfig("queue_threshold"), "autoscale"])
    assert both.admission is not None and both.autoscale is not None
    assert both.describe() == "queue_threshold+autoscale"
    with pytest.raises(ValueError, match="duplicate"):
        resolve_control(["token_bucket", "queue_threshold"])
    with pytest.raises(TypeError):
        resolve_control(42)


def test_scale_priority_round_robins_racks():
    rank = scale_priority(TOPO)
    rack = np.asarray(TOPO.rack_of)
    assert sorted(rank) == list(range(12))
    # any prefix of the keep-order spans racks as evenly as possible
    for keep in (3, 6, 9):
        kept = rack[rank < keep]
        counts = np.bincount(kept, minlength=3)
        assert counts.max() - counts.min() <= 1, (keep, counts)


# -- bitwise-off discipline -------------------------------------------------

@pytest.mark.parametrize("policy", available_policies())
def test_control_none_is_bitwise_off(policy):
    """``control=None`` must compile to the exact pre-control program:
    no carry slots, no RNG consumption, no ctl_* keys — for every
    registered policy (K=3 pin; the K-generic seam is the same code)."""
    off = sim.simulate(policy, CFG, 3.0, EST, seed=0)
    on = sim.simulate(policy, CFG, 3.0, EST, seed=0, control=None)
    assert set(off) == set(on)
    for k, v in off.items():
        assert np.array_equal(np.asarray(v), np.asarray(on[k])), (policy, k)
    assert not any(k.startswith("ctl_") for k in off)


def test_slo_pandas_without_telemetry_is_balanced_pandas():
    """No telemetry -> no signals -> slo_pandas IS balanced_pandas,
    bitwise (the documented degradation, not an approximation)."""
    a = sim.simulate("balanced_pandas", CFG, 0.9 * CAP, EST, seed=0)
    b = sim.simulate("slo_pandas", CFG, 0.9 * CAP, EST, seed=0)
    assert a == b


def test_slo_pandas_engages_under_breach():
    """With telemetry on and an easily-breached target the SLO bias must
    actually move the sample path (otherwise the policy is dead code)."""
    from repro.core.policy import PolicyConfig
    base = sim.simulate("balanced_pandas", CFG, 0.99 * CAP, EST, seed=0,
                        telemetry=True)
    slo = sim.simulate(PolicyConfig("slo_pandas", {"slo_target": 2.0}),
                       CFG, 0.99 * CAP, EST, seed=0, telemetry=True)
    assert any(not np.array_equal(np.asarray(base[k]), np.asarray(slo[k]))
               for k in ("mean_n", "throughput", "final_n"))


# -- admission: conservation + effect ---------------------------------------

def test_token_bucket_sheds_and_conserves():
    res = sim.simulate("balanced_pandas", CFG, 1.5 * CAP, EST, seed=0,
                       control={"name": "token_bucket",
                                "options": {"rate": 0.8 * CAP,
                                            "burst": 2.0 * CAP}})
    assert res["ctl_shed"] > 0
    assert res["ctl_offered"] == res["ctl_admitted"] + res["ctl_shed"]
    assert 0.0 < res["ctl_shed_rate"] < 1.0
    assert "ctl_backlog" not in res  # non-deferring bucket


def test_token_bucket_defer_conserves_with_backlog():
    # warmup=0: the backlog level is LIVE state while the counters are
    # window-gated, so the conservation identity is exact only over the
    # full horizon (with a warmup, backlog carried into the window shows
    # up as admitted-but-never-offered releases).
    cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), max_arrivals=16,
                        horizon=400, warmup=0)
    res = sim.simulate("balanced_pandas", cfg, 1.5 * CAP, EST, seed=0,
                       control={"name": "token_bucket",
                                "options": {"rate": 0.8 * CAP,
                                            "burst": 2.0 * CAP,
                                            "defer": True,
                                            "backlog_cap": 64.0}})
    # offered == admitted + shed + still-deferred
    assert res["ctl_offered"] == pytest.approx(
        res["ctl_admitted"] + res["ctl_shed"] + res["ctl_backlog"])
    assert 0.0 <= res["ctl_backlog"] <= 64.0


def test_queue_threshold_bounds_the_system():
    thr = 20
    res = sim.simulate("balanced_pandas", CFG, 1.5 * CAP, EST, seed=0,
                       control={"name": "queue_threshold",
                                "options": {"threshold": thr}})
    assert res["final_n"] <= thr
    assert res["ctl_shed"] > 0


def test_mean_delay_uses_measured_admitted_rate():
    """Little's law under admission: the denominator must be what
    actually entered the system, not the configured offered rate."""
    res = sim.simulate("balanced_pandas", CFG, 1.5 * CAP, EST, seed=0,
                       control={"name": "queue_threshold",
                                "options": {"threshold": 20}})
    n_meas = CFG.horizon - CFG.warmup
    lam_adm = res["ctl_admitted"] / n_meas
    assert res["mean_delay"] == pytest.approx(res["mean_n"] / lam_adm,
                                              rel=1e-5)


# -- closed loop: conservation property -------------------------------------

@settings(max_examples=6, deadline=None)
@given(users=st.integers(min_value=1, max_value=40),
       think_time=st.floats(min_value=1.0, max_value=16.0),
       seed=st.integers(min_value=0, max_value=3))
def test_closed_loop_conservation(users, think_time, seed):
    """N-users closed loop: at most ``users`` requests exist anywhere
    (in system + thinking), and with warmup=0 the window accounting is
    exact: admitted == offered and admitted - completed == final_n."""
    cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), max_arrivals=48,
                        horizon=200, warmup=0)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    res = sim.simulate("balanced_pandas", cfg, 1.0, est, seed=seed,
                       control={"name": "closed_loop",
                                "options": {"users": users,
                                            "think_time": think_time}})
    assert res["ctl_offered"] == res["ctl_admitted"]  # no admission arm
    assert res["ctl_shed"] == 0
    completed = round(res["throughput"] * cfg.horizon)
    assert res["ctl_admitted"] - completed == res["final_n"]
    assert res["final_n"] <= users


# -- autoscale: sim projection ----------------------------------------------

def test_sim_autoscale_masks_and_reports():
    res = sim.simulate("balanced_pandas", CFG, 0.3 * CAP, EST, seed=0,
                       control="autoscale")
    m = TOPO.num_servers
    assert res["ctl_active_min"] >= TOPO.num_racks  # rack floor
    assert res["ctl_active_min"] <= res["ctl_active_mean"] <= m
    assert res["ctl_active_mean"] < m  # low load actually descales
    # throughput survives descale: the load is far under even the floor
    assert res["throughput"] == pytest.approx(0.3 * CAP, rel=0.15)


def test_autoscale_requires_mask_support():
    with pytest.raises(ValueError, match="server_mask"):
        sim.simulate("fifo", CFG, 1.0, EST, seed=0, control="autoscale")


def test_crn_survives_engagement():
    """Control hooks draw no RNG: two runs differing only in an
    admission arm that never rejects share the arrival stream, so their
    offered counts match slot-for-slot (same CRN)."""
    loose = sim.simulate("balanced_pandas", CFG, 1.5 * CAP, EST, seed=0,
                         control={"name": "queue_threshold",
                                  "options": {"threshold": 10_000}})
    tight = sim.simulate("balanced_pandas", CFG, 1.5 * CAP, EST, seed=0,
                         control={"name": "queue_threshold",
                                  "options": {"threshold": 15}})
    assert loose["ctl_offered"] == tight["ctl_offered"]
    assert loose["ctl_shed"] == 0 and tight["ctl_shed"] > 0


# -- host projection: Autoscaler hysteresis ---------------------------------

def test_autoscaler_hysteresis_and_cooldown():
    a = Autoscaler(min_servers=2, max_servers=8, p95_high=100.0,
                   p95_low=10.0, up_after=2, down_after=3, cooldown=5,
                   step_frac=0.25)
    assert a.current == 8
    # shrink: three consecutive lows (step = ceil(8 * .25) = 2)
    assert a.observe(0, 5.0) is None
    assert a.observe(1, 5.0) is None
    assert a.observe(2, 5.0) == 6
    # cooldown swallows readings (even breaches)
    assert a.observe(3, 500.0) is None
    assert a.observe(6, 500.0) is None
    # after cooldown: two highs grow by ceil(6 * .25) = 2
    assert a.observe(7, 500.0) is None
    assert a.observe(8, 500.0) == 8  # clamped to max
    # NaN (no data) resets streaks
    b = Autoscaler(min_servers=1, max_servers=4, p95_high=50.0,
                   p95_low=5.0, up_after=2, down_after=2, cooldown=0)
    assert b.observe(0, 60.0) is None
    assert b.observe(1, float("nan")) is None
    assert b.observe(2, 60.0) is None  # streak restarted
    # mid-band readings also reset
    assert b.observe(3, 20.0) is None
    assert b.observe(4, 60.0) is None
    with pytest.raises(ValueError):
        Autoscaler(min_servers=5, max_servers=4)


def test_closed_loop_clients_conserve_users():
    c = ClosedLoopClients(users=5, think_time=3.0, seed=1)
    submitted = completed = 0
    for step in range(50):
        n = c.poll(step, completed)
        submitted += n
        assert c.in_flight == submitted - completed <= 5
        # complete one outstanding request every other step
        if step % 2 and completed < submitted:
            completed += 1
    # deterministic per seed
    c2 = ClosedLoopClients(users=5, think_time=3.0, seed=1)
    completed = 0
    replay = []
    for step in range(10):
        replay.append(c2.poll(step, 0))
    c3 = ClosedLoopClients(users=5, think_time=3.0, seed=1)
    assert replay == [c3.poll(s, 0) for s in range(10)]


# -- host projection: serving engine ----------------------------------------

@pytest.fixture(scope="module")
def engine_bits():
    import jax
    from repro.configs import registry
    from repro.models import params as P
    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, prm


def _mk_reqs(cfg, n, seed=0):
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=2, prefix_id=i % 3) for i in range(n)]


def test_engine_admission_sheds_before_routing(engine_bits):
    from repro.serve.engine import EngineConfig, ServingEngine
    cfg, prm = engine_bits
    eng = ServingEngine(cfg, prm, EngineConfig(
        num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
        max_len=64, prefill_buckets=(16,),
        control={"name": "queue_threshold", "options": {"threshold": 3}}))
    out = eng.run_until_drained(_mk_reqs(cfg, 12), max_steps=300)
    m = eng.control.metrics()
    shed = [r for r in out if r.finish_time == -1.0]
    fin = [r for r in out if r.finish_time > 0]
    assert m["ctl_shed"] == len(shed) > 0
    assert m["ctl_admitted"] == len(fin) == eng.completed
    assert eng.in_system == 0
    # shed requests never touched a queue: every router queue drained
    assert eng.queue_depths.sum() == 0


def test_engine_sojourn_histogram_cross_check(engine_bits):
    """The engine's sojourn histogram must agree with exact per-request
    sojourns pushed through the telemetry estimator: identical binning
    gives identical percentiles (upper bin edges), and overflow
    accounting matches."""
    from repro.serve.engine import EngineConfig, ServingEngine
    from repro.telemetry import percentiles_from_hist
    cfg, prm = engine_bits

    exact = []

    class Probe(ServingEngine):
        def _note_finished(self, finished):
            exact.extend(self.steps - r._submit_step for r in finished)
            super()._note_finished(finished)

    eng = Probe(cfg, prm, EngineConfig(
        num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
        max_len=64, prefill_buckets=(16,),
        sojourn_hist_bins=64, sojourn_hist_max=64.0))
    eng.run_until_drained(_mk_reqs(cfg, 10), max_steps=300)
    assert len(exact) == 10 and int(eng.sojourn_hist.sum()) == 10
    width = 64.0 / 64
    ref = np.zeros(65, np.int64)
    for s in exact:
        ref[min(int(s / width), 64)] += 1
    np.testing.assert_array_equal(eng.sojourn_hist, ref)
    qs = (0.5, 0.95, 0.99)
    np.testing.assert_array_equal(
        eng.sojourn_percentiles(qs), percentiles_from_hist(ref, width, qs))
    # upper-bin-edge property: estimator >= exact order statistic
    for q, est_q in zip(qs, eng.sojourn_percentiles(qs)):
        assert est_q >= np.quantile(exact, q) - 1e-9
    assert eng.sojourn_overflow_frac == np.mean(np.asarray(exact) >= 64.0)


def test_engine_autoscale_parks_and_drains(engine_bits):
    from repro.serve.engine import EngineConfig, ServingEngine
    cfg, prm = engine_bits
    eng = ServingEngine(cfg, prm, EngineConfig(
        num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
        max_len=64, prefill_buckets=(16,),
        control={"name": "autoscale",
                 "options": {"p95_high": 1e9, "p95_low": 1e8,
                             "down_after": 2, "cooldown": 2,
                             "min_servers": 1, "step_frac": 0.5}}))
    reqs = _mk_reqs(cfg, 10)
    out = eng.run_until_drained(reqs, max_steps=300)
    assert all(r.finish_time > 0 for r in out)  # parked replicas drained
    m = eng.control.metrics()
    assert m["ctl_active"] < 4 and eng._parked.sum() > 0
    assert eng.router.active_mask.sum() == m["ctl_active"]


# -- satellites: recorder overflow accounting -------------------------------

def test_recorder_reports_overflow_frac():
    from repro.telemetry import TelemetryConfig
    tcfg = TelemetryConfig(hist_bins=8, hist_max=4.0)  # absurdly small
    res = sim.simulate("balanced_pandas", CFG, 0.9 * CAP, EST, seed=0,
                       telemetry=tcfg)
    assert 0.0 < res["delay_overflow_frac"] <= 1.0
    wide = sim.simulate("balanced_pandas", CFG, 0.9 * CAP, EST, seed=0,
                        telemetry=True)
    assert wide["delay_overflow_frac"] <= res["delay_overflow_frac"]


def test_maybe_warn_overflow():
    from repro.telemetry import (OVERFLOW_WARN_FRAC, TelemetryConfig,
                                 maybe_warn_overflow)
    tcfg = TelemetryConfig(hist_bins=8, hist_max=4.0)
    with pytest.warns(RuntimeWarning, match="hist_max=16"):
        assert maybe_warn_overflow(0.5, tcfg)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert not maybe_warn_overflow(OVERFLOW_WARN_FRAC / 2, tcfg)
        assert not maybe_warn_overflow(float("nan"), tcfg)
