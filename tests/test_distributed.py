"""Distributed execution on an 8-device host-platform mesh (subprocess, so
the forced device count never leaks into other tests).

Covers: real sharded train steps (loss decreases, params sharded as
planned), sharded serve step, checkpoint save on one mesh -> restore on a
DIFFERENT mesh (the elastic-restart path), and dry-run cell lowering at
test scale.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs_and_learns():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry, runtime
        from repro.launch import mesh as mesh_lib
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = registry.get_smoke_config("granite_moe_1b")
        mesh = mesh_lib.make_test_mesh((4, 2), ("data", "model"))
        plan = runtime.plan_for(cfg, "train_4k", "train",
                                dp_axes=("data",))
        tr = Trainer(cfg, TrainerConfig(seq_len=64, global_batch=8,
                                        steps=8, log_every=1), mesh, plan)
        hist = tr.run()
        losses = [h["loss"] for h in hist]
        # params are actually sharded over the mesh
        emb = tr.state.params["embed"]
        assert len(emb.sharding.device_set) > 1, emb.sharding
        print("LOSSES", losses[0], losses[-1])
        assert losses[-1] < losses[0]
    """)
    assert "LOSSES" in out


def test_sharded_serve_step_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry, runtime
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.models import params as P, transformer as T

        cfg = registry.get_smoke_config("chatglm3_6b")
        mesh = mesh_lib.make_test_mesh((2, 4), ("data", "model"))
        plan = runtime.plan_for(cfg, "decode_32k", "decode",
                                dp_axes=("data",))
        fn, (ap, ac, ab), (p_sh, c_sh, b_sh) = steps_lib.build_serve_step(
            cfg, mesh, plan, batch=4, max_len=32)
        prm = P.init_params(cfg, jax.random.PRNGKey(0))
        caches = T.init_caches(cfg, 4, 32)
        batch = {"tokens": jnp.ones((4, 1), jnp.int32),
                 "lengths": jnp.zeros((4,), jnp.int32)}
        with mesh:
            tok, logits, caches2 = fn(prm, caches, batch)
        # single-device reference
        lg_ref, _ = T.decode_step(prm, cfg, batch["tokens"],
                                  batch["lengths"],
                                  T.init_caches(cfg, 4, 32))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(lg_ref[:, 0]),
                                   atol=2e-3, rtol=2e-3)
        print("SERVE OK")
    """)


def test_checkpoint_restore_across_mesh_change(tmp_path):
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry, runtime
        from repro.launch import mesh as mesh_lib, steps as steps_lib
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = registry.get_smoke_config("mamba2_13b")
        plan = runtime.plan_for(cfg, "train_4k", "train", dp_axes=("data",))

        mesh1 = mesh_lib.make_test_mesh((4, 2), ("data", "model"))
        tr1 = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=8, steps=4,
                                         ckpt_dir=r"{tmp_path}",
                                         ckpt_every=4, log_every=2),
                      mesh1, plan)
        tr1.run()

        # the "post-failure" mesh: half the data axis
        mesh2 = mesh_lib.make_test_mesh((2, 2), ("data", "model"))
        tr2 = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=8, steps=2,
                                         ckpt_dir=r"{tmp_path}",
                                         log_every=1), mesh2, plan)
        start = tr2.restore_or_init()
        assert start == 4, start
        hist = tr2.run()
        assert all(np.isfinite(h["loss"]) for h in hist)
        print("ELASTIC RESTORE OK")
    """)


def test_dryrun_cell_at_test_scale():
    """lower+compile a production-shaped cell on the 8-device mesh via the
    same code path dryrun uses (mesh shapes reduced)."""
    run_py("""
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import registry, runtime
        from repro.launch import steps as steps_lib
        from repro.utils import hlo as hlo_lib

        cfg = registry.get_smoke_config("mixtral_8x22b")
        dev = np.asarray(jax.devices()[:8]).reshape(2, 4)
        mesh = Mesh(dev, ("data", "model"))
        plan = runtime.plan_for(cfg, "train_4k", "train", dp_axes=("data",))
        fn, astate, abatch, _ = steps_lib.build_train_step(
            cfg, mesh, plan, global_batch=8, seq_len=64)
        with mesh:
            lowered = fn.lower(astate, abatch)
            compiled = lowered.compile()
        rep = hlo_lib.analyze(compiled.as_text())
        assert rep.flops > 0 and rep.bytes > 0
        assert rep.collective_count > 0  # sharded program must communicate
        print("DRYRUN-8DEV OK", int(rep.collective_count))
    """)
