"""Fleet fast path (sharding.sim): exactness, fidelity bands, gating.

What is pinned here and why:

* the fused route kernel, the segment-min route, and the dense (B, M)
  oracle agree **bitwise** on fuzzed topologies of every depth — the
  three implementations are one semantics contract
  (kernels/ref.fleet_route), including cross-tier score ties, which a
  naive per-level combine gets wrong;
* the dense simulator path is **bitwise-pinned** for all six policies:
  the fleet dispatch seam must not perturb sub-threshold runs at all;
* the fleet path's delay stays inside a band of the dense simulator at
  a mid-size fleet — the fast path is an approximation of the
  sequential in-slot dynamics (snapshot routing + retry passes +
  water-fill pool), and this band is the licensed error;
* chunked/donated execution is an implementation detail: results are
  bitwise-identical across chunk sizes, including ragged tails;
* the compiled chunk's HLO stays under a dispatch budget at M=2400 —
  slots/sec at fleet scale is dispatch-bound, so op-count growth is the
  leading indicator of a throughput regression (see docs/scaling.md).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import balanced_pandas as bp
from repro.core import locality as loc, simulator as sim
from repro.core.policy import PolicyConfig, available_policies
from repro.kernels import ops as kops, ref
from repro.sharding.sim import (
    FLEET_AUTO_THRESHOLD, FleetConfig, _build_fleet_chunk,
    _private_route_segmin, fleet_simulate, fleet_supported, fleet_sweep,
    make_ctx,
)

# fuzz topologies: (topology, rates) covering depth 0 (K=2), 1, and 2
TOPOS = (
    (loc.Topology(24), loc.Rates(0.5, 0.25)),
    (loc.Topology(24, 4), loc.Rates()),
    (loc.Topology(36, (3, 6)), loc.Rates(0.5, 0.45, 0.35, 0.25)),
)


def _fuzz_state(rng, m, k, batch=17):
    q = jnp.asarray(rng.integers(0, 60, (m, k)), jnp.int32)
    serving = jnp.asarray(rng.integers(0, 8, (m,)), jnp.int32)
    # half the batch piles onto servers 0..5 so group minima collide
    hot = np.stack([np.sort(rng.choice(6, 3, replace=False))
                    for _ in range(batch // 2)])
    cold = np.stack([np.sort(rng.choice(m, 3, replace=False))
                     for _ in range(batch - batch // 2)])
    locs = jnp.asarray(np.concatenate([hot, cold]), jnp.int32)
    return q, serving, locs


@pytest.mark.parametrize("topo,rates", TOPOS,
                         ids=["depth0", "depth1", "depth2"])
def test_fleet_route_kernel_matches_oracle(topo, rates):
    rng = np.random.default_rng(0)
    m = topo.num_servers
    ctx = make_ctx(topo)
    est = loc.per_server_rates(rates.as_array(), m)
    for _ in range(10):
        q, serving, locs = _fuzz_state(rng, m, est.shape[1])
        sk, tk, vk = kops.fleet_route(q, serving, est, ctx.anc, locs)
        sr, tr, vr = ref.fleet_route(q, serving, est, ctx.anc, locs)
        np.testing.assert_array_equal(sk, sr)
        np.testing.assert_array_equal(tk, tr)
        np.testing.assert_array_equal(vk, vr)


@pytest.mark.parametrize("topo,rates", TOPOS,
                         ids=["depth0", "depth1", "depth2"])
def test_segmin_route_matches_oracle(topo, rates):
    rng = np.random.default_rng(1)
    m = topo.num_servers
    ctx = make_ctx(topo)
    est = loc.per_server_rates(rates.as_array(), m)
    for _ in range(10):
        q, serving, locs = _fuzz_state(rng, m, est.shape[1])
        w = bp.workload(bp.PandasState(q=q, serving=serving), est)
        si, ti, vi = _private_route_segmin(w, est, ctx, locs)
        sr, tr, vr = ref.fleet_route(q, serving, est, ctx.anc, locs)
        np.testing.assert_array_equal(si, sr)
        np.testing.assert_array_equal(ti, tr)
        np.testing.assert_array_equal(vi, vr)


@pytest.mark.parametrize("topo,rates", TOPOS,
                         ids=["depth0", "depth1", "depth2"])
def test_kernel_and_segmin_paths_bitwise_in_loop(topo, rates):
    """Full fleet runs with use_pallas on/off are bitwise identical.

    This is strictly stronger than the single-call fuzz: the evolving
    queue state reaches cross-tier score ties (two different servers
    whose f32 scores at different tiers coincide exactly) that random
    states almost never hit; both paths must break them the way the
    dense (B, M) argmin does — lowest server index.
    """
    m = topo.num_servers
    cap = loc.capacity_hot_rack(topo, rates, 0.5)
    lam = 0.75 * cap
    est = loc.per_server_rates(rates.as_array(), m)
    cfg = sim.SimConfig(topo=topo, true_rates=rates, horizon=300,
                        warmup=100, p_hot=0.5,
                        max_arrivals=max(8, int(2.2 * lam)))
    a = fleet_simulate("balanced_pandas", cfg, lam, est, seed=3,
                       fleet=FleetConfig(use_pallas=False))
    b = fleet_simulate("balanced_pandas", cfg, lam, est, seed=3,
                       fleet=FleetConfig(use_pallas=True))
    assert a == b


# ---------------------------------------------------------------------------
# dense path: bitwise pins (fleet dispatch must not perturb it at all)

_PIN_CFG = sim.SimConfig(topo=loc.Topology(24, 6), true_rates=loc.Rates(),
                         p_hot=0.5, max_arrivals=24, horizon=1200,
                         warmup=300)
_PIN_CAP = loc.capacity_hot_rack(_PIN_CFG.topo, _PIN_CFG.true_rates, 0.5)

# recorded from the dense path; exact f32 values, not approximations
_DENSE_PINS = {
    "balanced_pandas": {"final_n": 15.0,
                        "mean_delay": 3.4911115169525146,
                        "mean_n": 27.928892135620117,
                        "throughput": 7.965555667877197},
    "blind_pandas": {"est_alpha_mean": 0.4999604821205139, "final_n": 17.0,
                     "mean_delay": 3.4968056678771973,
                     "mean_n": 27.974445343017578,
                     "throughput": 7.9633331298828125},
    "fifo": {"drops": 0.0, "final_n": 595.0,
             "mean_delay": 62.2972412109375, "mean_n": 498.3779296875,
             "throughput": 7.548888683319092},
    "jsq_maxweight": {"final_n": 18.0, "mean_delay": 3.21610951423645,
                      "mean_n": 25.7288761138916,
                      "throughput": 7.965555667877197},
    "pandas_po2": {"final_n": 18.0, "mean_delay": 3.7629172801971436,
                   "mean_n": 30.10333824157715,
                   "throughput": 7.967777729034424},
    "priority": {"final_n": 21.0, "mean_delay": 3.612638235092163,
                 "mean_n": 28.901105880737305,
                 "throughput": 7.965555667877197},
    # signal-free slo_pandas IS balanced_pandas (bitwise, by construction)
    "slo_pandas": {"final_n": 15.0,
                   "mean_delay": 3.4911115169525146,
                   "mean_n": 27.928892135620117,
                   "throughput": 7.965555667877197},
}


@pytest.mark.parametrize("name", sorted(_DENSE_PINS))
def test_dense_path_bitwise_pinned(name):
    assert set(available_policies()) == set(_DENSE_PINS)
    est = sim.make_estimates(_PIN_CFG, "network", 0.0, -1)
    pol = PolicyConfig(name, {"prior": _PIN_CFG.true_rates.values}) \
        if name == "blind_pandas" else name
    out = sim.simulate(pol, _PIN_CFG, 0.8 * _PIN_CAP, est, seed=0)
    assert out == _DENSE_PINS[name]


# ---------------------------------------------------------------------------
# fidelity: fleet path vs the dense simulator at a mid-size fleet

_BAND_TOPO = loc.Topology(240, 6)
_BAND_RATES = loc.Rates()
_BAND_CAP = loc.capacity_hot_rack(_BAND_TOPO, _BAND_RATES, 0.5)
_BAND_LAM = 0.8 * _BAND_CAP
# the dense arm MUST get max_arrivals ~ 2*lam or arrivals truncate and
# the comparison is void (throughput pins below lam)
_BAND_CFG = sim.SimConfig(topo=_BAND_TOPO, true_rates=_BAND_RATES,
                          horizon=2000, warmup=600, p_hot=0.5,
                          max_arrivals=int(2.05 * _BAND_LAM))
_BAND_EST = loc.per_server_rates(_BAND_RATES.as_array(), 240)


def test_fleet_delay_band_vs_dense_balanced_pandas():
    dense = sim.simulate("balanced_pandas", _BAND_CFG, _BAND_LAM, _BAND_EST,
                         seed=0, fleet=False)
    fleet = fleet_simulate("balanced_pandas", _BAND_CFG, _BAND_LAM,
                           _BAND_EST, seed=0)
    # all offered load is served on both paths
    assert dense["throughput"] == pytest.approx(_BAND_LAM, rel=0.02)
    assert fleet["throughput"] == pytest.approx(dense["throughput"],
                                                rel=0.02)
    # delay band: snapshot routing + 2 retry passes + water-fill pool
    # tracks the sequential dynamics to within 15% at this size
    # (measured -2%; rounds=1 sits at +26% and must stay out of band)
    assert fleet["mean_delay"] == pytest.approx(dense["mean_delay"],
                                                rel=0.15)


def test_fleet_delay_band_vs_dense_pandas_po2():
    dense = sim.simulate("pandas_po2", _BAND_CFG, _BAND_LAM, _BAND_EST,
                         seed=0, fleet=False)
    fleet = fleet_simulate("pandas_po2", _BAND_CFG, _BAND_LAM, _BAND_EST,
                           seed=0)
    assert dense["throughput"] == pytest.approx(_BAND_LAM, rel=0.02)
    assert fleet["throughput"] == pytest.approx(dense["throughput"],
                                                rel=0.02)
    # batch-sampled power-of-d candidates vs sequential draws: same
    # distribution, different stream; measured +6% at this size
    assert fleet["mean_delay"] == pytest.approx(dense["mean_delay"],
                                                rel=0.15)


def test_fleet_rounds_monotone_fidelity():
    """More retry passes must not leave the band (and 1 pass is the
    documented loose end: overflow spills to the remote pool)."""
    f2 = fleet_simulate("balanced_pandas", _BAND_CFG, _BAND_LAM, _BAND_EST,
                        seed=0, fleet=FleetConfig(rounds=3))
    assert f2["throughput"] == pytest.approx(_BAND_LAM, rel=0.02)


# ---------------------------------------------------------------------------
# chunked/donated execution is bitwise-invariant

def test_chunk_size_invariance_bitwise():
    topo, rates = loc.Topology(36, (3, 6)), loc.Rates(0.5, 0.45, 0.35, 0.25)
    cap = loc.capacity_hot_rack(topo, rates, 0.5)
    lam = 0.75 * cap
    est = loc.per_server_rates(rates.as_array(), 36)
    # horizon 300 is a ragged multiple of both chunk sizes
    cfg = sim.SimConfig(topo=topo, true_rates=rates, horizon=300,
                        warmup=100, p_hot=0.5,
                        max_arrivals=max(8, int(2.2 * lam)))
    outs = [fleet_simulate("balanced_pandas", cfg, lam, est, seed=5,
                           fleet=FleetConfig(chunk=c, unroll=u))
            for c, u in ((32, 1), (128, 4), (512, 2))]
    assert outs[0] == outs[1] == outs[2]


def test_fleet_sweep_matches_simulate_bitwise():
    topo, rates = loc.Topology(24, 4), loc.Rates()
    cap = loc.capacity_hot_rack(topo, rates, 0.5)
    est = loc.per_server_rates(rates.as_array(), 24)
    cfg = sim.SimConfig(topo=topo, true_rates=rates, horizon=200, warmup=50,
                        p_hot=0.5, max_arrivals=16)
    lam_grid = np.array([0.6, 0.75], np.float32) * cap
    ests = np.stack([np.asarray(est)] * 2)
    ests[1, :, 1:] *= 0.9  # second error arm
    seeds = np.arange(2)
    out = fleet_sweep("balanced_pandas", cfg, lam_grid, ests, seeds)
    assert out["mean_delay"].shape == (2, 2, 2)
    assert np.isfinite(out["mean_delay"]).all()
    single = fleet_simulate("balanced_pandas", cfg, float(lam_grid[1]),
                            ests[0], seed=1)
    for key, val in single.items():
        assert float(out[key][1, 0, 1]) == val


# ---------------------------------------------------------------------------
# gating: who gets the fast path, and that refusal is loud

def _small_cfg(m=24):
    return sim.SimConfig(topo=loc.Topology(m, 6), true_rates=loc.Rates(),
                         p_hot=0.5, max_arrivals=16, horizon=100, warmup=20)


def test_fleet_supported_reasons():
    cfg = _small_cfg()
    assert fleet_supported("balanced_pandas", cfg, None, None, None,
                           None) is None
    assert fleet_supported("pandas_po2", cfg, None, None, None, None) is None
    for bad, kw in [("fifo", {}),
                    ("balanced_pandas", {"scenario": "server_loss"}),
                    ("balanced_pandas", {"telemetry": True})]:
        reason = fleet_supported(
            bad, cfg, kw.get("scenario"), kw.get("placement"),
            kw.get("replication"), kw.get("telemetry"))
        assert reason is not None and isinstance(reason, str)


def test_auto_gate_threshold():
    # below threshold: auto keeps the dense path even though supported
    assert not sim._fleet_engaged(None, "balanced_pandas", _small_cfg(24),
                                  None, None, None, None)
    assert FLEET_AUTO_THRESHOLD == 1024
    assert sim._fleet_engaged(None, "balanced_pandas", _small_cfg(1026),
                              None, None, None, None)
    # fleet=False pins dense at any size
    assert not sim._fleet_engaged(False, "balanced_pandas",
                                  _small_cfg(1026), None, None, None, None)


def test_forced_fleet_on_unsupported_raises():
    with pytest.raises(ValueError, match="unsupported"):
        sim.simulate("fifo", _small_cfg(), 5.0,
                     sim.make_estimates(_small_cfg(), "network", 0.0, -1),
                     seed=0, fleet=True)


def test_forced_fleet_dispatches_below_threshold():
    cfg = _small_cfg()
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, 0.5)
    est = loc.per_server_rates(cfg.true_rates.as_array(), 24)
    via_sim = sim.simulate("balanced_pandas", cfg, 0.7 * cap, est, seed=2,
                           fleet=FleetConfig())
    direct = fleet_simulate("balanced_pandas", cfg, 0.7 * cap, est, seed=2)
    assert via_sim == direct


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        FleetConfig(chunk=0)
    with pytest.raises(ValueError):
        FleetConfig(rounds=0)
    with pytest.raises(ValueError):
        FleetConfig(fill_iters=4)


# ---------------------------------------------------------------------------
# dispatch budget: op count of the compiled chunk at M=2400

def test_hlo_dispatch_budget_m2400():
    """The fleet path is dispatch-bound on CPU (and would be on any
    host-driven accelerator): wall clock tracks the number of compiled
    ops per slot, not FLOPs.  Pin a generous ceiling on the chunk
    program's total instruction count so an accidental O(M)-dense
    scatter or an unrolled Python loop shows up as a test failure, not
    as a silent 5x slots/sec regression.  Measured ~18.7k instructions
    (chunk=128, unroll=4) when pinned.
    """
    from repro.utils import hlo

    topo = loc.Topology(2400, 6)
    rates = loc.Rates()
    cap = loc.capacity_hot_rack(topo, rates, 0.5)
    lam = 0.8 * cap
    cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                        max_arrivals=int(2.05 * lam), horizon=512,
                        warmup=128)
    est = loc.per_server_rates(rates.as_array(), 2400).astype(np.float32)
    init, chunk = _build_fleet_chunk("balanced_pandas", cfg, FleetConfig())
    args = (init(), np.int32(0), np.float32(lam), est, np.uint32(0))
    text = jax.jit(chunk).lower(*args).compile().as_text()
    comps = hlo.parse_computations(text)
    total = sum(len(instrs) for instrs in comps.values())
    assert 0 < total < 40_000, f"chunk program has {total} HLO instructions"
