"""HLO analyzer: trip-count multiplication, dot FLOPs, in-place modeling.

These compile tiny single-device programs and check the walker against
hand-computed truths (the roofline table's integrity rests on this).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import hlo


def _report(fn, *specs):
    compiled = jax.jit(fn).lower(*specs).compile()
    return hlo.analyze(compiled.as_text())


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, wi):
            return c @ wi, ()
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((9, 128, 128), jnp.float32)
    rep = _report(f, x, w)
    expected = 2 * 64 * 128 * 128 * 9
    assert rep.flops == pytest.approx(expected, rel=0.05)


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, ()
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, w)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
    rep = _report(f, x, w)
    expected = 2 * 32 * 64 * 64 * 12  # 3 x 4 nested trips
    assert rep.flops == pytest.approx(expected, rel=0.05)


def test_plain_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    rep = _report(f, a, b)
    assert rep.flops == pytest.approx(2 * 256 * 512 * 128, rel=0.02)
    min_bytes = 4 * (256 * 512 + 512 * 128 + 256 * 128)
    assert min_bytes * 0.9 <= rep.bytes <= min_bytes * 3


def test_inplace_cache_update_not_billed_full_buffer():
    """A one-token dynamic-update-slice into a DONATED buffer must cost
    O(token), not O(buffer) — the deferred-commit design depends on this.
    (Without donation XLA copies the buffer, and the walker correctly bills
    the copy — checked too.)"""
    def f(cache, tok):
        return jax.lax.dynamic_update_slice(cache, tok, (0, 5, 0))

    cache = jax.ShapeDtypeStruct((8, 4096, 64), jnp.float32)
    tok = jax.ShapeDtypeStruct((8, 1, 64), jnp.float32)
    buffer_bytes = 8 * 4096 * 64 * 4

    donated = jax.jit(f, donate_argnums=(0,)).lower(cache, tok).compile()
    rep = hlo.analyze(donated.as_text())
    assert rep.bytes < buffer_bytes * 0.1, rep.bytes

    copied = jax.jit(f).lower(cache, tok).compile()
    rep2 = hlo.analyze(copied.as_text())
    assert rep2.bytes >= buffer_bytes  # the defensive copy is real traffic


def test_sliced_scan_buffer_not_billed_per_iteration():
    """Reading one (1, d) slice per scan step from a stacked (L, d) buffer
    must bill ~L*d total, not L*(L*d)."""
    def f(x, big):
        def body(c, i):
            sl = jax.lax.dynamic_slice(big, (i, 0), (1, 512))
            return c + sl[0], ()
        y, _ = jax.lax.scan(body, x, jnp.arange(64))
        return y

    x = jax.ShapeDtypeStruct((512,), jnp.float32)
    big = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    rep = _report(f, x, big)
    assert rep.bytes < 64 * 512 * 4 * 8  # generous: ~8x the buffer, not 64x


def test_collectives_counted_with_ring_model():
    import subprocess, sys, os, textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "") + ":src"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.utils import hlo
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("d",))
        sh = NamedSharding(mesh, P("d"))
        def f(x):
            return jnp.sum(x) * jnp.ones_like(x)
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=sh, out_shardings=sh).lower(x).compile()
        rep = hlo.analyze(c.as_text())
        assert rep.collective_count >= 1, rep.coll_counts
        assert rep.collective_bytes > 0
        print("COLL", rep.coll_counts)
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "COLL" in out.stdout
