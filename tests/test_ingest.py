"""Cluster-trace ingest adapters (Google cluster-usage v2, Alibaba
cluster-trace-v2018): column mapping, binning, rack-weight derivation,
and the export -> ingest round-trips."""

import csv

import numpy as np
import pytest

from repro import workloads as wl
from repro.workloads.ingest import (ALIBABA_BATCH_TASK_COLUMNS,
                                    ALIBABA_CONTAINER_COLUMNS,
                                    GOOGLE_V2_SUBMIT,
                                    GOOGLE_V2_TASK_EVENT_COLUMNS,
                                    load_alibaba_cluster_csv,
                                    load_google_cluster_csv,
                                    save_alibaba_cluster_csv,
                                    save_google_cluster_csv)


def _write_events(path, rows):
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        for r in rows:
            w.writerow(r)


def _event(t_us, event_type=GOOGLE_V2_SUBMIT, machine=""):
    row = [t_us, 0, 1, 0, machine, event_type, "u", 0, 0, "", "", "", ""]
    assert len(row) == len(GOOGLE_V2_TASK_EVENT_COLUMNS)
    return row


def test_ingest_bins_submit_events(tmp_path):
    p = tmp_path / "task_events.csv"
    s = 1_000_000  # one second in microseconds
    _write_events(p, [
        _event(0), _event(10 * s), _event(59 * s),        # interval 0
        _event(60 * s), _event(61 * s),                   # interval 1
        _event(130 * s),                                  # interval 2
        _event(65 * s, event_type=1),                     # SCHEDULE: ignored
    ])
    tr = load_google_cluster_csv(p, interval=60.0)
    assert tr.interval == 60.0
    np.testing.assert_array_equal(tr.arrivals, [3, 2, 1])
    assert tr.rack_weights is None
    # the result is an ordinary Trace: it compiles and replays
    scn = wl.trace_to_scenario(tr, max_segments=8)
    assert abs(scn.mean_lam_mult - 1.0) < 1e-9


def test_ingest_rejects_malformed_rows(tmp_path):
    p = tmp_path / "bad.csv"
    _write_events(p, [[123, 0, 1]])  # too few columns
    with pytest.raises(ValueError, match="columns"):
        load_google_cluster_csv(p)
    # a non-numeric first row is tolerated as a header, so the malformed
    # timestamp must sit past line 1 to be a hard error
    _write_events(p, [_event(0), _event("not-a-time")])
    with pytest.raises(ValueError, match="unparseable"):
        load_google_cluster_csv(p)
    _write_events(p, [_event(0, event_type=5)])
    with pytest.raises(ValueError, match="no events"):
        load_google_cluster_csv(p)  # nothing submits
    with pytest.raises(FileNotFoundError):
        load_google_cluster_csv(tmp_path / "missing.csv")


def test_ingest_derives_rack_weights_from_machines(tmp_path):
    p = tmp_path / "placed.csv"
    s = 1_000_000
    # all interval-0 events on one machine; interval 1 has no machine ids
    _write_events(p, [
        _event(0, machine="m-a"), _event(1 * s, machine="m-a"),
        _event(70 * s), _event(71 * s),
    ])
    tr = load_google_cluster_csv(p, interval=60.0, num_racks=4)
    assert tr.rack_weights.shape == (2, 4)
    # interval 0: all mass on m-a's rack; interval 1: uniform fallback
    assert sorted(tr.rack_weights[0].tolist(), reverse=True)[0] == 1.0
    np.testing.assert_allclose(tr.rack_weights[1], 0.25)


def test_google_csv_roundtrip(tmp_path):
    """Export -> ingest reproduces arrivals exactly, and rack weights
    whenever the weights are empirical frequencies of the counts."""
    arr = np.array([4.0, 0.0, 8.0, 2.0])
    rw = np.array([[0.25, 0.75], [0.5, 0.5], [0.5, 0.5], [1.0, 0.0]])
    tr = wl.Trace("g", interval=300.0, arrivals=arr, rack_weights=rw)
    p = tmp_path / "export.csv"
    save_google_cluster_csv(tr, p)
    back = load_google_cluster_csv(p, interval=300.0, num_racks=2,
                                   num_intervals=4)
    np.testing.assert_array_equal(back.arrivals, arr)
    # interval 1 had no events -> uniform fallback; others exact
    np.testing.assert_allclose(back.rack_weights[0], rw[0])
    np.testing.assert_allclose(back.rack_weights[2], rw[2])
    np.testing.assert_allclose(back.rack_weights[3], rw[3])
    np.testing.assert_allclose(back.rack_weights[1], 0.5)


def test_google_roundtrip_without_weights(tmp_path):
    rng = np.random.default_rng(0)
    tr = wl.Trace("plain", interval=60.0,
                  arrivals=rng.poisson(20.0, 16).astype(np.float64))
    p = tmp_path / "plain.csv"
    save_google_cluster_csv(tr, p)
    back = load_google_cluster_csv(p, interval=60.0, num_intervals=16)
    np.testing.assert_array_equal(back.arrivals, tr.arrivals)
    # and the full loop closes: ingest -> compile -> simulate
    from repro.core import locality as loc, simulator as sim
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        max_arrivals=16, horizon=400, warmup=100)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 2.0, est, seed=0,
                       scenario=wl.trace_to_scenario(back))
    assert np.isfinite(out["mean_delay"])


# ------------------------------------------------------------- alibaba ----

def _batch_task(start_time, instances=1, status="Terminated"):
    try:
        end = float(start_time) + 5
    except (TypeError, ValueError):
        end = ""
    row = [f"t_{start_time}", instances, "j_1", 1, status,
           start_time, end, 100, 0.5]
    assert len(row) == len(ALIBABA_BATCH_TASK_COLUMNS)
    return row


def _container(time_stamp, machine):
    row = [f"c_{time_stamp}", machine, time_stamp, "du_1", "started",
           4, 4, 1.0]
    assert len(row) == len(ALIBABA_CONTAINER_COLUMNS)
    return row


def test_alibaba_bins_batch_tasks(tmp_path):
    p = tmp_path / "batch_task.csv"
    _write_events(p, [
        _batch_task(1), _batch_task(30), _batch_task(59),   # interval 0
        _batch_task(61),                                    # interval 1
        _batch_task(130, instances=7),                      # interval 2
        _batch_task(0),      # never started: skipped
        _batch_task(""),     # no start time: skipped
    ])
    tr = load_alibaba_cluster_csv(p, interval=60.0)
    np.testing.assert_array_equal(tr.arrivals, [3, 1, 1])
    assert tr.rack_weights is None
    # instance-weighted arrivals count every instance of a task
    tr2 = load_alibaba_cluster_csv(p, interval=60.0, use_instances=True)
    np.testing.assert_array_equal(tr2.arrivals, [3, 1, 7])
    # the result is an ordinary Trace: it compiles
    scn = wl.trace_to_scenario(tr, max_segments=8)
    assert abs(scn.mean_lam_mult - 1.0) < 1e-9


def test_alibaba_container_rack_weights(tmp_path):
    bt = tmp_path / "batch_task.csv"
    ct = tmp_path / "container.csv"
    _write_events(bt, [_batch_task(10), _batch_task(70), _batch_task(80)])
    # all interval-0 containers on one machine; interval 1 has none
    _write_events(ct, [_container(5, "ali-m1"), _container(6, "ali-m1")])
    tr = load_alibaba_cluster_csv(bt, container_path=ct, interval=60.0,
                                  num_racks=4)
    assert tr.rack_weights.shape == (2, 4)
    assert sorted(tr.rack_weights[0].tolist(), reverse=True)[0] == 1.0
    np.testing.assert_allclose(tr.rack_weights[1], 0.25)
    with pytest.raises(ValueError, match="num_racks"):
        load_alibaba_cluster_csv(bt, container_path=ct, interval=60.0)


def test_alibaba_rejects_malformed_rows(tmp_path):
    p = tmp_path / "bad.csv"
    _write_events(p, [["t_1", 1, "j_1"]])  # too few columns
    with pytest.raises(ValueError, match="columns"):
        load_alibaba_cluster_csv(p)
    _write_events(p, [_batch_task(1), _batch_task("not-a-time")])
    with pytest.raises(ValueError, match="unparseable"):
        load_alibaba_cluster_csv(p)
    _write_events(p, [_batch_task(0)])
    with pytest.raises(ValueError, match="no started"):
        load_alibaba_cluster_csv(p)  # nothing ever starts
    with pytest.raises(FileNotFoundError):
        load_alibaba_cluster_csv(tmp_path / "missing.csv")
    # tolerated header row (both name columns non-numeric)
    with open(p, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(ALIBABA_BATCH_TASK_COLUMNS)
        w.writerow(_batch_task(10))
    tr = load_alibaba_cluster_csv(p, interval=60.0)
    np.testing.assert_array_equal(tr.arrivals, [1])


def test_alibaba_csv_roundtrip(tmp_path):
    arr = np.array([4.0, 0.0, 8.0, 2.0])
    rw = np.array([[0.25, 0.75], [0.5, 0.5], [0.5, 0.5], [1.0, 0.0]])
    tr = wl.Trace("ali", interval=300.0, arrivals=arr, rack_weights=rw)
    bt = tmp_path / "batch_task.csv"
    ct = tmp_path / "container.csv"
    # weights without a container path would be silently dropped: refuse
    with pytest.raises(ValueError, match="container_path"):
        save_alibaba_cluster_csv(tr, bt)
    save_alibaba_cluster_csv(tr, bt, container_path=ct)
    back = load_alibaba_cluster_csv(bt, container_path=ct, interval=300.0,
                                    num_racks=2, num_intervals=4)
    np.testing.assert_array_equal(back.arrivals, arr)
    # interval 1 had no arrivals -> uniform fallback; others exact
    np.testing.assert_allclose(back.rack_weights[0], rw[0])
    np.testing.assert_allclose(back.rack_weights[2], rw[2])
    np.testing.assert_allclose(back.rack_weights[3], rw[3])
    np.testing.assert_allclose(back.rack_weights[1], 0.5)


def test_alibaba_roundtrip_without_weights_and_replay(tmp_path):
    rng = np.random.default_rng(1)
    tr = wl.Trace("plain-ali", interval=60.0,
                  arrivals=rng.poisson(15.0, 12).astype(np.float64))
    bt = tmp_path / "batch_task.csv"
    save_alibaba_cluster_csv(tr, bt)
    back = load_alibaba_cluster_csv(bt, interval=60.0, num_intervals=12)
    np.testing.assert_array_equal(back.arrivals, tr.arrivals)
    # the full loop closes: ingest -> compile -> simulate
    from repro.core import locality as loc, simulator as sim
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        max_arrivals=16, horizon=400, warmup=100)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 2.0, est, seed=0,
                       scenario=wl.trace_to_scenario(back))
    assert np.isfinite(out["mean_delay"])


def test_alibaba_subsecond_interval_roundtrip(tmp_path):
    """Regression: the exporter used to clamp every start_time to >= 1s,
    corrupting any trace with interval <= 1."""
    tr = wl.Trace("fast", interval=0.5,
                  arrivals=np.array([2.0, 3.0, 0.0, 1.0]))
    bt = tmp_path / "batch_task.csv"
    save_alibaba_cluster_csv(tr, bt)
    back = load_alibaba_cluster_csv(bt, interval=0.5, num_intervals=4)
    np.testing.assert_array_equal(back.arrivals, tr.arrivals)
