"""Flash-attention kernel vs. ref.mha: shape/dtype/feature sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # b, hq, hkv, tq, tk, d, causal, window, softcap
    (2, 4, 2, 128, 128, 64, True, 0, 0.0),
    (1, 8, 1, 200, 200, 64, True, 0, 0.0),     # GQA kv=1, padding
    (1, 4, 4, 64, 192, 64, True, 0, 0.0),      # chunked prefill (tq < tk)
    (1, 4, 2, 1, 256, 64, True, 0, 0.0),       # pure decode (tq = 1)
    (2, 4, 2, 256, 256, 64, True, 128, 0.0),   # sliding window
    (1, 2, 2, 128, 128, 64, True, 0, 50.0),    # gemma2-style softcap
    (1, 2, 2, 96, 96, 32, False, 0, 0.0),      # non-causal (encoder)
    (1, 2, 1, 256, 256, 128, True, 64, 30.0),  # window + softcap + GQA
]


@pytest.mark.parametrize("case", CASES)
def test_flash_matches_ref_f32(case):
    b, hq, hkv, tq, tk, d, causal, window, cap = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, hq, tq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, tk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, tk, d)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=causal, window=window, softcap=cap)
    o2 = ref.mha(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 2e-2), (jnp.float32, 2e-5)])
def test_flash_dtypes(dtype, tol):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), dtype)
    o1 = ops.flash_attention(q, k, v)
    o2 = ref.mha(q, k, v)
    assert o1.dtype == dtype
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol, rtol=tol)


def test_flash_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    o2 = ops.flash_attention(q, k, v, block_q=64, block_k=256)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=2e-6, rtol=2e-6)


def test_flash_window_equals_full_when_wide():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    o_full = ops.flash_attention(q, k, v, window=0)
    o_win = ops.flash_attention(q, k, v, window=4096)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_win),
                               atol=1e-6, rtol=1e-6)
