"""Shape/dtype sweeps for the scheduler kernels vs. their pure-jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RATES = np.array([0.5, 0.45, 0.25], np.float32)


def _fleet(rng, m, rack):
    wl = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile(RATES, (m, 1)) * rng.uniform(0.8, 1.2, (m, 3)),
                     jnp.float32)
    sr = jnp.asarray(np.arange(m) // rack, jnp.int32)
    return wl, er, sr


@pytest.mark.parametrize("m,b,rack", [
    (64, 8, 8),          # single tile
    (300, 50, 25),       # padding on both axes
    (1024, 256, 32),     # multi-tile servers
    (4096, 512, 64),     # fleet scale-ish
])
def test_wwl_route_matches_oracle(m, b, rack):
    rng = np.random.default_rng(m + b)
    wl, er, sr = _fleet(rng, m, rack)
    tl = jnp.sort(jnp.asarray(
        np.stack([rng.choice(m, 3, replace=False) for _ in range(b)]),
        jnp.int32), axis=1)
    s1, t1, sc1 = ops.wwl_route(wl, er, sr, tl)
    s2, t2, sc2 = ref.wwl_route(wl, er, sr, tl)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(sc1), np.asarray(sc2), rtol=1e-6)


def test_wwl_route_prefers_idle_local():
    """Semantics spot-check: an idle local server must win."""
    m = 256
    wl = jnp.full((m,), 10.0).at[7].set(0.0)
    er = jnp.tile(jnp.asarray(RATES)[None], (m, 1))
    sr = jnp.asarray(np.arange(m) // 16, jnp.int32)
    tl = jnp.asarray([[7, 20, 40]], jnp.int32)
    server, tier, _ = ops.wwl_route(wl, er, sr, tl)
    assert int(server[0]) == 7 and int(tier[0]) == 0


@pytest.mark.parametrize("n,b,rack", [(64, 8, 8), (300, 37, 25), (2048, 200, 64)])
def test_maxweight_claim_matches_oracle(n, b, rack):
    rng = np.random.default_rng(n * 7 + b)
    q = jnp.asarray(rng.integers(0, 5, n), jnp.float32)
    qr = jnp.asarray(np.arange(n) // rack, jnp.int32)
    ids = jnp.asarray(rng.choice(n, b, replace=False), jnp.int32)
    ir = qr[ids]
    er = jnp.asarray(np.tile(RATES, (b, 1)) * rng.uniform(0.8, 1.2, (b, 3)),
                     jnp.float32)
    q1, s1 = ops.maxweight_claim(q, qr, ids, ir, er)
    q2, s2 = ref.maxweight_claim(q, qr, ids, ir, er)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_maxweight_all_empty_scores_neginf():
    n = 128
    q = jnp.zeros((n,), jnp.float32)
    qr = jnp.zeros((n,), jnp.int32)
    ids = jnp.asarray([3, 5], jnp.int32)
    er = jnp.tile(jnp.asarray(RATES)[None], (2, 1))
    _, score = ops.maxweight_claim(q, qr, ids, qr[ids], er)
    assert (np.asarray(score) < -1e30).all()


def test_kernel_router_consistent_with_core_router():
    """The production kernel and the numpy cluster router agree on routing
    decisions (snapshot semantics, unique minima)."""
    from repro.core import ClusterSpec, BalancedPandasRouter
    rng = np.random.default_rng(0)
    spec = ClusterSpec(num_workers=32, workers_per_pod=8)
    router = BalancedPandasRouter(spec, RATES, seed=1)
    router.q = rng.integers(0, 6, (32, 3)).astype(np.int64)

    wl = jnp.asarray(router.workload(), jnp.float32)
    er = jnp.asarray(router._est(), jnp.float32)
    sr = jnp.asarray(spec.pod_of, jnp.int32)
    tasks = np.sort(np.stack([rng.choice(32, 3, replace=False)
                              for _ in range(16)]), axis=1)
    servers, tiers, scores = ops.wwl_route(wl, er, sr,
                                           jnp.asarray(tasks, jnp.int32))
    for i, task in enumerate(tasks):
        tier = router.tiers(task)
        rate = np.take_along_axis(router._est(), tier[:, None], 1)[:, 0]
        score = router.workload() / rate
        mins = np.flatnonzero(np.isclose(score, score.min(), rtol=1e-6))
        assert int(servers[i]) in mins
        assert int(tiers[i]) == tier[int(servers[i])]
