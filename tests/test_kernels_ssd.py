"""SSD chunked-scan kernel vs. the sequential-scan oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # b, t, h, p, n
    (1, 128, 2, 32, 16),
    (2, 200, 3, 16, 32),    # t not a chunk multiple
    (1, 64, 1, 8, 8),       # single small chunk
    (1, 512, 4, 64, 64),    # multi-chunk, square state
]


def _inputs(case, dtype=jnp.float32):
    b, t, h, p, n = case
    rng = np.random.default_rng(hash(case) % 2**31)
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), dtype)
    a = jnp.asarray(-rng.uniform(0.01, 0.2, size=(b, t, h)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(b, t, n)) * 0.3, dtype)
    c = jnp.asarray(rng.normal(size=(b, t, n)) * 0.3, dtype)
    return x, a, bmat, c


@pytest.mark.parametrize("case", CASES)
def test_ssd_matches_ref(case):
    x, a, b, c = _inputs(case)
    y1, h1 = ops.ssd(x, a, b, c)
    y2, h2 = ref.ssd(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=3e-4, rtol=3e-4)


def test_ssd_initial_state_threading():
    """Splitting a sequence in two and carrying the state must equal one pass
    — this is exactly the decode-from-cache invariant for SSM serving."""
    case = (1, 256, 2, 16, 16)
    x, a, b, c = _inputs(case)
    y_full, h_full = ops.ssd(x, a, b, c)
    y1, h1 = ops.ssd(x[:, :128], a[:, :128], b[:, :128], c[:, :128])
    y2, h2 = ops.ssd(x[:, 128:], a[:, 128:], b[:, 128:], c[:, 128:],
                     init_state=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, :128]), np.asarray(y1),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(y_full[:, 128:]), np.asarray(y2),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=3e-4, rtol=3e-4)


def test_ssd_chunk_size_independence():
    case = (1, 256, 2, 16, 16)
    x, a, b, c = _inputs(case)
    y1, h1 = ops.ssd(x, a, b, c, block_t=64)
    y2, h2 = ops.ssd(x, a, b, c, block_t=256)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=3e-4, rtol=3e-4)


def test_ssd_bf16_inputs():
    case = (1, 128, 2, 16, 16)
    x, a, b, c = _inputs(case, jnp.bfloat16)
    y1, h1 = ops.ssd(x, a, b, c)
    y2, h2 = ref.ssd(x, a, b, c)
    assert y1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=3e-2, rtol=3e-2)


def test_ssd_zero_decay_accumulates():
    """a_log = 0 (decay 1) -> state is a running sum of x_s b_s^T."""
    b, t, h, p, n = 1, 32, 1, 4, 4
    x = jnp.ones((b, t, h, p), jnp.float32)
    a = jnp.zeros((b, t, h), jnp.float32)
    bmat = jnp.ones((b, t, n), jnp.float32)
    c = jnp.ones((b, t, n), jnp.float32)
    y, hT = ops.ssd(x, a, bmat, c, block_t=16)
    np.testing.assert_allclose(np.asarray(hT), np.full((b, h, p, n), t),
                               rtol=1e-6)
    # y_t = t * n (state h_t = t after t steps, dotted with ones over n)
    np.testing.assert_allclose(np.asarray(y[0, -1, 0]), np.full((p,), t * n),
                               rtol=1e-6)
