"""Launcher CLIs and example entry points run end-to-end (subprocesses)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cmd(args, timeout=900, devices=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_train_cli():
    out = run_cmd(["-m", "repro.launch.train", "--arch", "mamba2_13b",
                   "--steps", "2", "--seq-len", "32", "--global-batch", "2"])
    assert "loss" in out


def test_serve_cli():
    out = run_cmd(["-m", "repro.launch.serve", "--arch", "granite_moe_1b",
                   "--requests", "4"])
    assert "drained 4 requests" in out


def test_dryrun_cli_single_cell():
    out = run_cmd(["-m", "repro.launch.dryrun", "--arch", "gemma3_1b",
                   "--shape", "decode_32k", "--mesh", "single",
                   "--out", "/tmp/dryrun_test"], timeout=1200)
    assert "done; 0 failures" in out


@pytest.mark.slow
def test_elastic_restart_example():
    out = run_cmd(["examples/elastic_restart.py"], devices=8, timeout=1500)
    assert "elastic restart OK" in out
