"""Unit + property tests for the rack/locality model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import locality as loc

TOPO = loc.Topology(24, 6)
RACK_OF = jnp.asarray(TOPO.rack_of, jnp.int32)


def brute_force_masks(task, rack_of, m):
    local = np.zeros(m, bool)
    local[list(task)] = True
    racks = {rack_of[s] for s in task}
    rack = np.array([rack_of[i] in racks for i in range(m)]) & ~local
    return local, rack


@given(st.lists(st.integers(0, 23), min_size=3, max_size=3, unique=True))
@settings(max_examples=50, deadline=None)
def test_locality_masks_match_bruteforce(task):
    task = sorted(task)
    local, rack = loc.locality_masks(jnp.array(task, jnp.int32), RACK_OF)
    bl, br = brute_force_masks(task, np.asarray(TOPO.rack_of), 24)
    np.testing.assert_array_equal(np.asarray(local), bl)
    np.testing.assert_array_equal(np.asarray(rack), br)


def test_rate_vector_tiers():
    task = jnp.array([0, 1, 6], jnp.int32)  # racks 0, 0, 1
    rates3 = jnp.array([0.5, 0.45, 0.25])
    rv = np.asarray(loc.rate_vector(task, RACK_OF, rates3))
    assert rv[0] == rv[1] == rv[6] == pytest.approx(0.5)      # locals
    assert rv[2] == rv[7] == pytest.approx(0.45)              # racks 0 and 1
    assert rv[12] == rv[23] == pytest.approx(0.25)            # racks 2, 3


def test_class_of():
    task = jnp.array([0, 1, 2], jnp.int32)
    assert int(loc.class_of(task, RACK_OF, jnp.int32(0))) == loc.LOCAL
    assert int(loc.class_of(task, RACK_OF, jnp.int32(5))) == loc.RACK_LOCAL
    assert int(loc.class_of(task, RACK_OF, jnp.int32(12))) == loc.REMOTE


def test_capacity_formula():
    rates = loc.Rates(0.5, 0.45, 0.25)
    # Known value from the derivation in locality.py docstring.
    assert loc.capacity_hot_rack(TOPO, rates, 0.5) == pytest.approx(10.0)
    # p_hot = 0: everything local -> M * alpha.
    assert loc.capacity_hot_rack(TOPO, rates, 0.0) == pytest.approx(12.0)
    # Capacity decreases with hotter traffic.
    caps = [loc.capacity_hot_rack(TOPO, rates, p) for p in (0.3, 0.5, 0.8, 1.0)]
    assert all(a >= b for a, b in zip(caps, caps[1:]))


def _fluid_lp_capacity(m, mr, p_hot, rates):
    """Brute-force fluid LP for the hot-rack pattern (independent of the
    closed form in `capacity_hot_rack`).

    Variables: x0/x1 = hot traffic served by rack-0 (alpha) / other racks
    (gamma); y0/y1 = uniform traffic served by rack-0 / other racks (alpha
    everywhere — uniform types have local replicas anywhere).  Dominated
    service options (hot at beta inside rack 0, uniform off-tier) can never
    raise the optimum, so they are omitted.  Maximize Lambda subject to
    flow conservation and per-pool utilisation <= capacity.
    """
    import scipy.optimize as sopt
    a, g = rates.alpha, rates.gamma
    # vars: [Lam, x0, x1, y0, y1]; minimize -Lam
    c = [-1.0, 0.0, 0.0, 0.0, 0.0]
    a_eq = [[-p_hot, 1.0, 1.0, 0.0, 0.0],
            [-(1.0 - p_hot), 0.0, 0.0, 1.0, 1.0]]
    b_eq = [0.0, 0.0]
    a_ub = [[0.0, 1.0 / a, 0.0, 1.0 / a, 0.0],
            [0.0, 0.0, 1.0 / g, 0.0, 1.0 / a]]
    b_ub = [float(mr), float(m - mr)]
    res = sopt.linprog(c, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq,
                       bounds=[(0, None)] * 5)
    assert res.success, res.message
    return -res.fun


@pytest.mark.parametrize("m,mr,p_hot", [
    (12, 4, 0.5), (24, 6, 0.1), (24, 6, 0.2), (24, 6, 0.5), (24, 6, 0.9),
    (18, 6, 0.7), (48, 8, 0.35),
])
def test_capacity_matches_bruteforce_fluid_lp(m, mr, p_hot):
    pytest.importorskip("scipy")
    rates = loc.Rates(0.5, 0.45, 0.25)
    closed = loc.capacity_hot_rack(loc.Topology(m, mr), rates, p_hot)
    lp = _fluid_lp_capacity(m, mr, p_hot, rates)
    assert closed == pytest.approx(lp, rel=1e-6)


def test_rates_validation_and_ht_condition():
    assert loc.Rates(0.5, 0.45, 0.25).heavy_traffic_optimal  # beta^2 > a*g
    assert not loc.Rates(0.9, 0.5, 0.4).heavy_traffic_optimal
    with pytest.raises(ValueError):
        loc.Rates(0.5, 0.6, 0.25)  # beta > alpha


def test_rates_scaled_clamps_uniformly():
    r = loc.Rates(0.5, 0.45, 0.25)
    down = r.scaled(0.8)
    assert (down.alpha, down.beta, down.gamma) == \
        pytest.approx((0.4, 0.36, 0.2))
    up = r.scaled(1.9)
    assert up.alpha == pytest.approx(0.95)
    assert up.gamma == pytest.approx(0.475)
    assert r.scaled(2.0).alpha == 1.0  # clamped into the valid (0, 1] range
    with pytest.raises(ValueError):
        r.scaled(2.5)  # clamp collapses alpha == beta: ordering invalid


def test_traffic_validation():
    loc.Traffic(lam_total=5.0, p_hot=0.0)
    loc.Traffic(lam_total=5.0, p_hot=1.0)
    with pytest.raises(ValueError):
        loc.Traffic(lam_total=5.0, p_hot=-0.1)
    with pytest.raises(ValueError):
        loc.Traffic(lam_total=5.0, p_hot=1.5)
    with pytest.raises(ValueError):
        loc.Traffic(lam_total=5.0, max_arrivals=0)
    with pytest.raises(ValueError):
        loc.Traffic(lam_total=-1.0)
    # traced / array-valued knobs skip host-side validation (jit path)
    loc.Traffic(lam_total=jnp.float32(3.0), p_hot=jnp.float32(0.5))


def test_sample_task_types_distinct_sorted_and_hot():
    traffic = loc.Traffic(lam_total=5.0, p_hot=1.0)
    types = loc.sample_task_types(jax.random.PRNGKey(0), TOPO, traffic, 256)
    t = np.asarray(types)
    assert (t[:, 0] < t[:, 1]).all() and (t[:, 1] < t[:, 2]).all()
    assert (t < TOPO.servers_per_rack).all()  # hot -> all in rack 0
    traffic = loc.Traffic(lam_total=5.0, p_hot=0.0)
    t = np.asarray(loc.sample_task_types(jax.random.PRNGKey(1), TOPO, traffic, 512))
    assert (t[:, 0] < t[:, 1]).all() and (t[:, 1] < t[:, 2]).all()
    assert t.max() >= TOPO.servers_per_rack  # uniform spreads beyond rack 0


def test_random_argmin_breaks_ties_uniformly():
    score = jnp.array([1.0, 0.0, 0.0, 5.0])
    picks = [int(loc.random_argmin(jax.random.PRNGKey(i), score))
             for i in range(200)]
    assert set(picks) == {1, 2}
    frac = picks.count(1) / len(picks)
    assert 0.3 < frac < 0.7


def test_topology_validation():
    with pytest.raises(ValueError):
        loc.Topology(25, 6)
    # racks smaller than the replication factor are fine as a host fleet
    # (the serving engine runs pods of 2); the hot-rack *sampler* is what
    # needs 3 servers per rack, so SimConfig enforces it instead
    from repro.core import simulator as sim
    loc.Topology(4, 2)
    with pytest.raises(ValueError):
        sim.SimConfig(topo=loc.Topology(4, 2), true_rates=loc.Rates())
