"""Serving-path correctness: prefill + incremental decode == full forward;
ring-buffer windowed KV cache; MoE dispatch vs dense oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import layers as L, params as P, transformer as T
from repro.models.config import LayerSpec, ModelConfig, uniform_stages

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_prefill_decode_matches_full(arch):
    cfg = registry.get_smoke_config(arch)
    prm = P.init_params(cfg, KEY)
    b, t0, tpre = 2, 12, 8
    tokens = jax.random.randint(KEY, (b, t0), 0, cfg.vocab_size)
    enc_out = None
    if cfg.is_encdec:
        frames = jax.random.normal(KEY, (b, cfg.num_audio_frames, cfg.d_model),
                                   jnp.float32)
        enc_out = T.encode(prm, cfg, frames)

    logits_full, _, _ = T.forward(prm, cfg, tokens, enc_out=enc_out,
                                  remat=False)
    caches = T.init_caches(cfg, b, max_len=32, dtype=cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(tpre, dtype=jnp.int32)[None], (b, tpre))
    logits_pre, caches, _ = T.forward(prm, cfg, tokens[:, :tpre],
                                      positions=pos, caches=caches,
                                      enc_out=enc_out, remat=False)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, :tpre]),
                               atol=2e-3, rtol=2e-3)
    step = jax.jit(lambda tok, ln, c: T.decode_step(prm, cfg, tok, ln, c))
    for t in range(tpre, t0):
        lengths = jnp.full((b,), t, jnp.int32)
        lg, caches = step(tokens[:, t:t + 1], lengths, caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=5e-3, rtol=5e-3)


def _tiny_window_cfg(window):
    return ModelConfig(
        name="tiny-swa", family="dense", d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64,
        stages=uniform_stages(2, LayerSpec(kind="attn", window=window)),
        dtype="float32")


def test_ring_buffer_window_cache_matches_full():
    """Decode far past the window: the ring buffer (size=window) must
    reproduce full-sequence windowed attention exactly."""
    window = 8
    cfg = _tiny_window_cfg(window)
    prm = P.init_params(cfg, KEY)
    b, t0 = 1, 24
    tokens = jax.random.randint(KEY, (b, t0), 0, cfg.vocab_size)
    logits_full, _, _ = T.forward(prm, cfg, tokens, remat=False)

    caches = T.init_caches(cfg, b, max_len=t0)
    # Cache buffers must be the ring (window) size, not t0:
    assert caches["stage0"]["sub0"]["kv"]["k"].shape[3] == window
    tpre = 4
    pos = jnp.arange(tpre, dtype=jnp.int32)[None]
    _, caches, _ = T.forward(prm, cfg, tokens[:, :tpre], positions=pos,
                             caches=caches, remat=False)
    for t in range(tpre, t0):
        lg, caches = T.decode_step(prm, cfg, tokens[:, t:t + 1],
                                   jnp.full((b,), t, jnp.int32), caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=f"step {t}")


def test_prefill_longer_than_window():
    """Prefilling 3x the window through the ring cache, then decoding."""
    window = 8
    cfg = _tiny_window_cfg(window)
    prm = P.init_params(cfg, KEY)
    b, t0 = 1, 28
    tokens = jax.random.randint(KEY, (b, t0), 0, cfg.vocab_size)
    logits_full, _, _ = T.forward(prm, cfg, tokens, remat=False)
    caches = T.init_caches(cfg, b, max_len=t0)
    tpre = 24  # 3x window
    pos = jnp.arange(tpre, dtype=jnp.int32)[None]
    _, caches, _ = T.forward(prm, cfg, tokens[:, :tpre], positions=pos,
                             caches=caches, remat=False)
    for t in range(tpre, t0):
        lg, caches = T.decode_step(prm, cfg, tokens[:, t:t + 1],
                                   jnp.full((b,), t, jnp.int32), caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------- MoE ---

def _dense_moe_oracle(p, cfg, x):
    """All-experts dense computation, no capacity: ground truth for the
    dispatch machinery when no tokens are dropped."""
    n, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    top_w, top_e = jax.lax.top_k(logits, cfg.moe.top_k)
    top_w = jax.nn.softmax(top_w, axis=-1)
    h_gate = jnp.einsum("nd,edf->nef", x, p["w_gate"])
    h_up = jnp.einsum("nd,edf->nef", x, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    ye = jnp.einsum("nef,efd->ned", h, p["w_down"])  # (N, E, d)
    y = jnp.zeros_like(x)
    for j in range(cfg.moe.top_k):
        sel = jnp.take_along_axis(ye, top_e[:, j][:, None, None], 1)[:, 0]
        y = y + top_w[:, j:j + 1] * sel
    return y


def test_moe_dispatch_matches_dense_oracle():
    cfg = registry.get_smoke_config("granite_moe_1b")
    prm = P.init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[0], prm["stages"]["stage0"]["sub0"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = L.moe_mlp(moe_p, cfg, x)
    y_ref = _dense_moe_oracle(moe_p, cfg, x.reshape(32, -1)).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With no_drop_threshold=0 and tight capacity, overflow tokens must be
    dropped (their contribution is exactly zero)."""
    cfg = registry.get_smoke_config("granite_moe_1b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, no_drop_threshold=0,
                                     capacity_factor=0.5))
    prm = P.init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[0], prm["stages"]["stage0"]["sub0"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, cfg.d_model),
                          jnp.float32)
    y, _ = L.moe_mlp(moe_p, cfg, x)
    y_ref = _dense_moe_oracle(moe_p, cfg, x.reshape(128, -1)).reshape(x.shape)
    # Some tokens dropped -> not allclose to the no-drop oracle...
    assert not np.allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    # ...but never NaN and never larger-magnitude than the oracle path.
    assert bool(jnp.isfinite(y).all())
