"""Per-architecture smoke tests: reduced same-family configs, one forward
(and for a representative subset one backward) on CPU; asserts shapes and
finiteness.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import params as P, transformer as T
from repro.models.config import param_count

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16):
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.where(jax.random.bernoulli(KEY, 0.9, (b, t)),
                                 tokens, -1)}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            KEY, (b, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.num_audio_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward(arch):
    cfg = registry.get_smoke_config(arch)
    prm = P.init_params(cfg, KEY)
    batch = _batch(cfg)
    enc_out = T.encode(prm, cfg, batch["frames"]) if cfg.is_encdec else None
    logits, _, aux = T.forward(prm, cfg, batch["tokens"],
                               frontend=batch.get("frontend"),
                               enc_out=enc_out, remat=False)
    t_extra = cfg.num_frontend_tokens if cfg.frontend == "vision" else 0
    assert logits.shape == (2, 16 + t_extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ["chatglm3_6b", "granite_moe_1b",
                                  "mamba2_13b", "whisper_medium",
                                  "jamba15_large"])
def test_smoke_train_step(arch):
    """One loss+grad evaluation: finite loss, finite nonzero grads."""
    cfg = registry.get_smoke_config(arch)
    prm = P.init_params(cfg, KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = T.lm_loss(p, cfg, batch, remat=True)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(prm)
    assert bool(jnp.isfinite(loss)), arch
    assert float(metrics["ce"]) > 0
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_exact_config_matches_assignment(arch):
    """The full config must carry the exact assigned hyperparameters."""
    spec = {
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "codeqwen15_7b": (32, 4096, 32, 32, 13440, 92416),
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "jamba15_large": (72, 8192, 64, 8, 24576, 65536),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "granite_moe_1b": (24, 1024, 16, 8, 512, 49155),
        "mamba2_13b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    cfg = registry.get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_param_counts_match_published():
    expected = {  # billions, tolerance band
        "chatglm3_6b": (6.0, 6.5), "gemma3_1b": (0.9, 1.1),
        "codeqwen15_7b": (7.0, 8.5), "gemma2_2b": (2.4, 2.8),
        "internvl2_2b": (1.7, 2.1), "jamba15_large": (390, 405),
        "whisper_medium": (0.7, 0.95), "mixtral_8x22b": (135, 145),
        "granite_moe_1b": (1.2, 1.45), "mamba2_13b": (1.2, 1.45),
    }
    for arch, (lo, hi) in expected.items():
        n = param_count(registry.get_config(arch)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


def test_moe_configs():
    assert registry.get_config("mixtral_8x22b").moe.num_experts == 8
    assert registry.get_config("mixtral_8x22b").moe.top_k == 2
    assert registry.get_config("granite_moe_1b").moe.num_experts == 32
    assert registry.get_config("granite_moe_1b").moe.top_k == 8
    assert registry.get_config("jamba15_large").moe.num_experts == 16
    assert registry.get_config("jamba15_large").moe.top_k == 2


def test_jamba_interleave_ratio():
    cfg = registry.get_config("jamba15_large")
    kinds = [sl.kind for st in cfg.stages for _ in range(st.repeats)
             for sl in st.block]
    assert len(kinds) == 72
    assert kinds.count("attn") == 9   # 1:7 attention:mamba
    assert kinds.count("mamba") == 63
    moes = [sl.moe for st in cfg.stages for _ in range(st.repeats)
            for sl in st.block]
    assert sum(moes) == 36            # MoE every other layer


def test_smoke_config_param_structure_matches_full():
    """Reduced configs must preserve the structural pattern (same pytree
    keys) so smoke tests exercise the same code paths as production."""
    for arch in registry.ARCH_IDS:
        full = jax.tree.structure(
            P.logical_axes(registry.get_config(arch)))
        smoke = jax.tree.structure(
            P.logical_axes(registry.get_smoke_config(arch)))
        assert full == smoke, arch
