"""AdamW optimizer: convergence, clipping, schedules, moment dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(peak_lr=0.1, weight_decay=0.0, warmup_steps=5,
                            decay_steps=200)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(cfg, params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clipping_caps_update():
    cfg = adamw.AdamWConfig(peak_lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(cfg, params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # clipped: effective step bounded by lr * 1/sqrt(v_hat-ish) ~ O(lr)


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                            min_lr_frac=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 60, 110, 500)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=0.01)
    assert lrs[5] == pytest.approx(0.1, abs=0.01)


def test_moment_dtype_bf16():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    state = adamw.init(cfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    params, state, _ = adamw.update(cfg, grads, state, params)
    assert state.nu["w"].dtype == jnp.bfloat16
    assert params["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(params["w"].astype(jnp.float32)).all())


def test_no_weight_decay_on_1d_params():
    cfg = adamw.AdamWConfig(peak_lr=1e-2, weight_decay=1.0, warmup_steps=0,
                            grad_clip=0.0)
    params = {"scale": jnp.ones(4), "w": jnp.ones((4, 4))}
    state = adamw.init(cfg, params)
    zero = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw.update(cfg, zero, state, params)
    np.testing.assert_allclose(np.asarray(new["scale"]), 1.0)  # no decay
    assert float(new["w"][0, 0]) < 1.0  # decayed


def test_abstract_state_matches_init():
    cfg = adamw.AdamWConfig()
    params = {"a": jnp.zeros((3, 5)), "b": {"c": jnp.zeros(7)}}
    concrete = adamw.init(cfg, params)
    abstract = adamw.abstract_state(
        cfg, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          params))
    assert (jax.tree.structure(concrete) == jax.tree.structure(abstract))
    for c, a in zip(jax.tree.leaves(concrete), jax.tree.leaves(abstract)):
        assert c.shape == a.shape and c.dtype == a.dtype
