"""Replica-placement subsystem: bitwise uniform pins on both substrates,
structural properties of every policy at K=2/3/4 (heterogeneous racks
included), the host placement map, the popularity rebalance step, the
placement-capacity LP, and end-to-end runs through the simulator, the
kernels, the serving engine and the data pipeline.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import locality as loc, robustness as rb, simulator as sim
from repro.placement import (PlacementConfig, available_placements,
                             make_placement, placement_capacity)
from repro.placement.policies import chunk_replicas, hrw_ranking

ALL_PLACEMENTS = ("uniform", "hdfs", "spread", "hot_aware")
TOPOS = {
    "k2": loc.Topology(24, ()),
    "k3": loc.Topology(24, 6),
    "k4": loc.Topology(24, (4, 12)),
    "k3het": loc.Topology(24, ((6, 6, 4, 4, 4),)),
}


def test_registry_surface():
    assert set(ALL_PLACEMENTS) <= set(available_placements())
    from repro.placement import placement_descriptions
    descs = placement_descriptions()
    assert all(descs[p] for p in ALL_PLACEMENTS)
    with pytest.raises(ValueError):
        make_placement("nope")
    p = make_placement(PlacementConfig("hot_aware", {"r_hot": 5}))
    assert p.r_hot == 5
    with pytest.raises(ValueError):
        make_placement(PlacementConfig("hot_aware", {"r_hot": 2}))
    with pytest.raises(ValueError):
        make_placement(PlacementConfig("hot_aware", {"hot_frac": 0.0}))


# -------------------------------------------------- bitwise uniform pins --

ALGOS = ("balanced_pandas", "jsq_maxweight", "priority", "fifo",
         "pandas_po2", "blind_pandas")


@pytest.mark.parametrize("algo", ALGOS)
def test_uniform_placement_is_bitwise_default_sim(algo):
    """placement="uniform" must reproduce the placement-less sample path
    EXACTLY for every policy (the placement-less path itself is pinned to
    the pre-refactor bits by tests/test_topology.py)."""
    from repro.core.policy import PolicyConfig
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        p_hot=0.5, max_arrivals=16, horizon=800, warmup=200)
    policy = PolicyConfig("blind_pandas", {"prior": loc.Rates().values}) \
        if algo == "blind_pandas" else algo
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    base = sim.simulate(policy, cfg, 0.8 * cap, est, seed=3)
    unif = sim.simulate(policy, cfg, 0.8 * cap, est, seed=3,
                        placement="uniform")
    assert base == unif


def test_uniform_sampler_is_bitwise_classic_draw():
    topo = loc.Topology(24, 6)
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    sampler = make_placement("uniform").build_sampler(topo)
    for seed in range(3):
        key = jax.random.PRNGKey(seed)
        want = loc.sample_task_types_at(key, rack_of, 0.5, jnp.int32(1), 64)
        got = sampler(key, 0.5, jnp.int32(1), 64)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # the weighted path too
    w = jnp.asarray([0.2, 0.5, 0.3, 0.0], jnp.float32)
    key = jax.random.PRNGKey(7)
    want = loc.sample_task_types_at(key, rack_of, 0.5, jnp.int32(0), 64,
                                    rack_weights=w)
    got = sampler(key, 0.5, jnp.int32(0), 64, w)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_uniform_host_placement_is_bitwise_chunk_replicas():
    from repro.data import pipeline as pl
    topo = loc.Topology(16, 8)
    u = make_placement("uniform")
    for seed in (0, 1):
        for c in range(64):
            want = pl.chunk_replicas(c, 16, 3, seed)
            assert u.replicas(topo, c, 3, seed) == want
            assert chunk_replicas(c, 16, 3, seed) == want
            assert sorted(hrw_ranking(c, 16, seed)[:3]) == want


# --------------------------------------------------- sampler properties --

@pytest.mark.parametrize("topo_name", sorted(TOPOS))
@pytest.mark.parametrize("name", ALL_PLACEMENTS)
def test_sampler_valid_distinct_in_range(topo_name, name):
    topo = TOPOS[topo_name]
    sampler = make_placement(name).build_sampler(topo)
    for p_hot, hot_rack in ((0.0, 0), (0.6, 1), (1.0, topo.num_racks - 1)):
        t = np.asarray(sampler(jax.random.PRNGKey(hash((name, p_hot)) %
                                                  (2**31)),
                               jnp.float32(p_hot), jnp.int32(hot_rack), 256))
        assert t.shape == (256, loc.NUM_REPLICAS) and t.dtype == np.int32
        assert (t >= 0).all() and (t < topo.num_servers).all()
        assert (np.diff(t, axis=1) > 0).all()  # sorted AND distinct


@pytest.mark.parametrize("name", ALL_PLACEMENTS)
def test_sampler_honors_rack_weights(name):
    """With p_hot=1 and one-hot rack weights on rack 2: uniform
    concentrates every replica there, hdfs keeps primary+second there,
    spread keeps the primary there; hot_aware deliberately lets sets
    escape (the rebalanced extras) but must still over-represent it."""
    topo = loc.Topology(12, 4)
    sampler = make_placement(name).build_sampler(topo)
    w = jnp.asarray([0.0, 0.0, 1.0], jnp.float32)
    t = np.asarray(sampler(jax.random.PRNGKey(0), jnp.float32(1.0),
                           jnp.int32(0), 128, w))
    racks = np.asarray(topo.rack_of)[t]
    if name == "hot_aware":
        # uniform draws would put 1/3 of replicas in rack 2; the weighted
        # hot pool puts half its replica mass there
        assert (racks == 2).mean() > 0.45
    else:
        assert (racks == 2).any(axis=1).all()


def test_hdfs_sampler_structure_k3():
    """Hot hdfs types: primary+second in the hot rack, third off-rack —
    exactly two racks covered, one of them the hot one."""
    topo = loc.Topology(24, 6)
    sampler = make_placement("hdfs").build_sampler(topo)
    t = np.asarray(sampler(jax.random.PRNGKey(1), jnp.float32(1.0),
                           jnp.int32(2), 256))
    racks = np.asarray(topo.rack_of)[t]
    assert ((racks == 2).sum(axis=1) == 2).all()
    assert np.array([len(set(r)) for r in racks.tolist()] ==
                    np.full(256, 2)).all()
    # cold tasks: still exactly 2 replicas share the primary's rack
    t = np.asarray(sampler(jax.random.PRNGKey(2), jnp.float32(0.0),
                           jnp.int32(0), 256))
    racks = np.asarray(topo.rack_of)[t]
    assert all(len(set(r)) == 2 for r in racks.tolist())


def test_hdfs_sampler_degrades_to_uniform_when_inexpressible():
    """K=2 (no racks): hdfs falls back to the uniform draw bitwise."""
    topo = loc.Topology(24, ())
    h = make_placement("hdfs").build_sampler(topo)
    u = make_placement("uniform").build_sampler(topo)
    key = jax.random.PRNGKey(0)
    np.testing.assert_array_equal(
        np.asarray(h(key, jnp.float32(0.5), jnp.int32(0), 64)),
        np.asarray(u(key, jnp.float32(0.5), jnp.int32(0), 64)))


def test_spread_sampler_anti_affinity():
    # K=3: three distinct racks
    topo = loc.Topology(24, 6)
    s = make_placement("spread").build_sampler(topo)
    t = np.asarray(s(jax.random.PRNGKey(0), jnp.float32(0.4), jnp.int32(0),
                     256))
    racks = np.asarray(topo.rack_of)[t]
    assert all(len(set(r)) == 3 for r in racks.tolist())
    # K=4 with 2 pods: replicas still land in 3 distinct racks, and the
    # second pick crosses pods whenever it can (max distance first)
    topo4 = loc.Topology(24, (4, 12))
    s4 = make_placement("spread").build_sampler(topo4)
    t = np.asarray(s4(jax.random.PRNGKey(1), jnp.float32(0.4), jnp.int32(0),
                      256))
    racks = np.asarray(topo4.rack_of)[t]
    pods = np.asarray(topo4.ancestors[1])[t]
    assert all(len(set(r)) == 3 for r in racks.tolist())
    assert all(len(set(p)) == 2 for p in pods.tolist())  # both pods covered


def test_hot_aware_sampler_widens_hot_pool():
    """r_hot=3 keeps every hot replica in the hot rack; r_hot=6 leaks some
    replicas off-rack (the rebalanced extras)."""
    topo = loc.Topology(12, 4)
    tight = make_placement(PlacementConfig("hot_aware", {"r_hot": 3}))
    wide = make_placement(PlacementConfig("hot_aware", {"r_hot": 6}))
    kt = jax.random.PRNGKey(3)
    t_tight = np.asarray(tight.build_sampler(topo)(
        kt, jnp.float32(1.0), jnp.int32(1), 256))
    t_wide = np.asarray(wide.build_sampler(topo)(
        kt, jnp.float32(1.0), jnp.int32(1), 256))
    racks_t = np.asarray(topo.rack_of)[t_tight]
    racks_w = np.asarray(topo.rack_of)[t_wide]
    assert (racks_t == 1).all()
    assert (racks_w != 1).any() and (racks_w == 1).any()


# ------------------------------------------------------ host projections --

@pytest.mark.parametrize("topo_name", sorted(TOPOS))
@pytest.mark.parametrize("name", ALL_PLACEMENTS)
def test_host_replicas_valid_and_deterministic(topo_name, name):
    topo = TOPOS[topo_name]
    p = make_placement(name)
    for c in range(32):
        locs = p.replicas(topo, c, 3, seed=5)
        assert locs == sorted(set(locs))
        assert all(0 <= h < topo.num_servers for h in locs)
        assert len(locs) >= 3
        assert locs == make_placement(name).replicas(topo, c, 3, seed=5)


def test_hdfs_host_structure():
    topo = loc.Topology(24, 6)
    rack = np.asarray(topo.rack_of)
    h = make_placement("hdfs")
    for c in range(64):
        locs = h.replicas(topo, c, 3, 0)
        prim = hrw_ranking(c, 24, 0)[0]
        assert prim in locs
        assert len(set(rack[locs].tolist())) == 2  # two fault domains
        assert (rack[locs] == rack[prim]).sum() == 2


def test_spread_host_structure():
    topo = loc.Topology(24, 6)
    rack = np.asarray(topo.rack_of)
    s = make_placement("spread")
    for c in range(64):
        locs = s.replicas(topo, c, 3, 0)
        assert len(set(rack[locs].tolist())) == 3
    # more replicas than racks: fills by rank after racks run out
    locs = s.replicas(loc.Topology(8, 4), 0, 3, 0)
    assert len(locs) == 3 and len(set(locs)) == 3


def test_placement_map_padding_and_mask():
    topo = loc.Topology(24, 6)
    ha = make_placement(PlacementConfig("hot_aware",
                                        {"r_hot": 6, "hot_frac": 0.25}))
    ids, mask = ha.placement_map(topo, 64, 3, seed=0)
    assert ids.shape == (64, 6) and mask.shape == (64, 6)
    assert ids.dtype == np.int32 and mask.dtype == bool
    sizes = mask.sum(axis=1)
    assert set(sizes.tolist()) <= {3, 6} and (sizes > 3).any()
    # mask prefix-true; pad slots replicate a valid host id
    assert (np.diff(mask.astype(int), axis=1) <= 0).all()
    assert (ids >= 0).all() and (ids < 24).all()
    for c in range(64):
        assert ids[c, ~mask[c]].tolist() == [ids[c, 0]] * int((~mask[c]).sum())
    # uniform map is exactly the classic assignment, all-true mask
    ids_u, mask_u = make_placement("uniform").placement_map(topo, 16, 3, 0)
    assert mask_u.all()
    for c in range(16):
        assert ids_u[c].tolist() == chunk_replicas(c, 24, 3, 0)


def test_hot_aware_rebalance_is_deterministic_and_reacts_to_counts():
    topo = loc.Topology(24, 6)
    ha = make_placement(PlacementConfig("hot_aware",
                                        {"r_hot": 6, "hot_frac": 0.25}))
    # chunk 7 becomes the single observed hotspot
    for _ in range(10):
        ha.note_read(7)
    for c in (1, 2, 3):
        ha.note_read(c)
    changed = ha.rebalance()
    assert changed >= 1
    assert len(ha.replicas(topo, 7, 3, 0)) == 6    # hot: widened
    assert len(ha.replicas(topo, 2, 3, 0)) == 3    # cold: base
    # replaying the same history gives the same hot set (determinism)
    hb = make_placement(PlacementConfig("hot_aware",
                                        {"r_hot": 6, "hot_frac": 0.25}))
    for _ in range(10):
        hb.note_read(7)
    for c in (1, 2, 3):
        hb.note_read(c)
    hb.rebalance()
    for c in range(16):
        assert ha.replicas(topo, c, 3, 0) == hb.replicas(topo, c, 3, 0)
    # a hotspot shift moves the wide replica set on the next rebalance
    for _ in range(50):
        ha.note_read(11)
    assert ha.rebalance() >= 1
    assert len(ha.replicas(topo, 11, 3, 0)) == 6


# ------------------------------------------------------------- capacity --

def test_placement_capacity_uniform_matches_water_filling():
    pytest.importorskip("scipy")
    topo, rates = loc.Topology(24, 6), loc.Rates()
    closed = loc.capacity_hot_rack(topo, rates, 0.5)
    mc = placement_capacity(topo, rates, 0.5, "uniform", n_samples=4000)
    assert mc == pytest.approx(closed, rel=0.05)  # Monte-Carlo p_hot noise
    # rack-aware placements un-confine hot traffic: capacity can only grow
    for name in ("hdfs", "spread"):
        assert placement_capacity(topo, rates, 0.5, name,
                                  n_samples=1000) >= closed - 1e-6


# ------------------------------------------------- end-to-end: all layers --

NONDEFAULT = ("hdfs", "spread", "hot_aware")


@pytest.mark.parametrize("topo,rates", [
    (loc.Topology(12, 4), loc.Rates()),
    (loc.Topology(24, (4, 12)), loc.Rates((0.5, 0.45, 0.35, 0.25))),
])
@pytest.mark.parametrize("name", NONDEFAULT)
def test_placement_runs_through_simulate_and_sweep(topo, rates, name):
    cfg = sim.SimConfig(topo=topo, true_rates=rates, p_hot=0.5,
                        max_arrivals=16, horizon=600, warmup=150)
    cap = loc.capacity_hot_rack(topo, rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 0.6 * cap, est, seed=0,
                       placement=name)
    assert np.isfinite(out["mean_delay"])
    assert out["throughput"] == pytest.approx(0.6 * cap, rel=0.2)
    swept = sim.sweep("jsq_maxweight", cfg,
                      np.array([0.4, 0.6], np.float32) * cap, est[None],
                      np.arange(2), placement=name)
    assert swept["mean_delay"].shape == (2, 1, 2)
    assert np.isfinite(swept["mean_delay"]).all()


@pytest.mark.parametrize("name", NONDEFAULT)
def test_placement_types_feed_both_kernels(name):
    """The sampled task_locals drive wwl_route and maxweight_claim
    unchanged (kernel vs oracle on placement-sampled types)."""
    from repro.kernels import ops, ref
    topo = loc.Topology(24, (4, 12))
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    k = topo.num_tiers
    tl = jnp.asarray(make_placement(name).build_sampler(topo)(
        jax.random.PRNGKey(0), jnp.float32(0.5), jnp.int32(0), 9), jnp.int32)
    rng = np.random.default_rng(3)
    m, b = 24, 9
    wlv = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile([0.5, 0.45, 0.35, 0.25], (m, 1)), jnp.float32)
    s1, t1, sc1 = ops.wwl_route(wlv, er, anc, tl)
    s2, t2, sc2 = ref.wwl_route(wlv, er, anc, tl)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    q = jnp.asarray(rng.integers(0, 5, m), jnp.float32)
    ids = jnp.asarray(rng.choice(m, b, replace=False), jnp.int32)
    er2 = jnp.asarray(np.tile([0.5, 0.45, 0.35, 0.25], (b, 1)), jnp.float32)
    q1, sv1 = ops.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    q2, sv2 = ref.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@pytest.mark.parametrize("name", NONDEFAULT)
def test_placement_runs_through_pipeline(name):
    from repro.data.pipeline import DataPipeline, PipelineConfig
    for topo, rates in ((loc.Topology(16, 8), (1.0, 0.8, 0.4)),
                        (loc.Topology(8, (2, 4)), (1.0, 0.8, 0.6, 0.4))):
        pipe = DataPipeline(PipelineConfig(
            topology=topo, tier_rates=rates, num_chunks=32,
            tokens_per_chunk=2048, seq_len=64, global_batch=2,
            placement=name, rebalance_every=4))
        for _ in range(4):
            batch = next(pipe)
        assert batch["tokens"].shape == (2, 64)
        assert pipe.metrics["tier_reads"].sum() == pipe.metrics["reads"]


@pytest.mark.parametrize("name", NONDEFAULT)
def test_placement_runs_through_engine(name):
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    for topo, rates in ((loc.Topology(4, 2), (1.0, 0.7, 0.4)),
                        (loc.Topology(4, (2, 4)), (1.0, 0.7, 0.55, 0.4))):
        ecfg = EngineConfig(topology=topo, tier_rates=rates,
                            slots_per_replica=2, max_len=64,
                            prefill_buckets=(16,), placement=name)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=2, prefix_id=i % 3) for i in range(4)]
        eng = ServingEngine(cfg, prm, ecfg)
        out = eng.run_until_drained(reqs, max_steps=200)
        assert all(r.finish_time > 0 for r in out)
        assert sum(eng.assign_tiers.values()) == len(reqs)


def test_engine_uniform_placement_is_bitwise_old_locs():
    """The engine's default placement reproduces the retired
    chunk_replicas call for every prefix."""
    from repro.data.pipeline import chunk_replicas as old
    from repro.serve.engine import EngineConfig
    topo = loc.Topology(4, 2)
    p = make_placement(EngineConfig().placement)
    for prefix in range(32):
        assert p.replicas(topo, prefix, 3, 0) == old(prefix, 4, 3, 0)


# -------------------------------------------------------- study driver ---

def test_placement_study_shapes_and_stability():
    cfg = rb.StudyConfig(
        sim=sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                          max_arrivals=16, horizon=1000, warmup=250),
        seeds=(0,))
    study = rb.placement_study(cfg, placements=("uniform", "hdfs"),
                               policies=("balanced_pandas",),
                               scenarios=("hot_shift",), load=0.6,
                               capacity_samples=300)
    assert study["placements"] == ("uniform", "hdfs")
    lam = study["load"] * study["capacity_uniform"]
    for plc in study["placements"]:
        d = study["delay"][plc]["hot_shift"]["balanced_pandas"]
        assert d.shape == (1,) and np.isfinite(d).all()
        thr = float(study["throughput"][plc]["hot_shift"]
                    ["balanced_pandas"].mean())
        assert thr > 0.85 * lam
    table = rb.summarize_placement(study)
    assert "hot_shift" in table and "hdfs" in table


# ----------------------------------------------- checkpoint / rebalance ---

def test_pipeline_checkpoint_restores_placement_state():
    """A restored pipeline must place and rebalance exactly like the
    uninterrupted run (the popularity state and the reads counter are part
    of state_dict; regression: they used to be dropped)."""
    from repro.data.pipeline import DataPipeline, PipelineConfig

    def make():
        return DataPipeline(PipelineConfig(
            num_hosts=16, hosts_per_pod=8, num_chunks=24,
            tokens_per_chunk=512, seq_len=32, global_batch=2,
            placement=PlacementConfig("hot_aware", {"hot_frac": 0.25}),
            rebalance_every=4))

    straight = make()
    for _ in range(8):
        next(straight)

    first = make()
    for _ in range(4):
        next(first)
    saved = first.state_dict()
    resumed = make()
    resumed.load_state_dict(saved)
    for _ in range(4):
        next(resumed)

    assert resumed.metrics["reads"] == straight.metrics["reads"]
    assert resumed.placement.state_dict() == straight.placement.state_dict()
    topo = straight.spec
    for c in range(24):
        assert resumed.placement.replicas(topo, c, 3, 0) == \
            straight.placement.replicas(topo, c, 3, 0)
    # stateless placements refuse foreign state, accept their own
    u = make_placement("uniform")
    assert u.state_dict() == {}
    u.load_state_dict({})
    with pytest.raises(ValueError):
        u.load_state_dict({"counts": [1]})


def test_engine_rebalance_cadence():
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,),
                        placement=PlacementConfig("hot_aware",
                                                  {"hot_frac": 0.5}),
                        rebalance_every=2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=1, prefix_id=i % 2) for i in range(4)]
    eng = ServingEngine(cfg, prm, ecfg)
    eng.run_until_drained(reqs, max_steps=100)
    assert eng.routed == 4
    assert eng.placement._hot is not None  # rebalance actually ran
    with pytest.raises(ValueError):
        ServingEngine(cfg, prm,
                      EngineConfig(num_replicas=4, replicas_per_pod=2,
                                   rebalance_every=-1))
