"""Unified SchedulerPolicy API: registry behaviour and JAX-policy vs
host-router decision cross-checks on scripted arrival traces.

The cross-checks extend the old balanced_pandas-only kernel/router check to
every registered policy that has a router counterpart: both implementations
see identical queue state and must agree on the routing score surface and
pick score-minimal servers.  Tie-breaks are RNG-dependent (and the host
router deliberately refines them, see EXPERIMENTS.md), so after each
arrival the router's bookkeeping is re-synced to the JAX choice — the two
sample paths then stay comparable for the whole trace.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import balanced_pandas as bp
from repro.core import claiming, fifo as fifo_mod, locality as loc, pandas_po2
from repro.core import simulator as sim
from repro.core.cluster import ClusterSpec
from repro.core.policy import (
    PolicyConfig, Router, SlotPolicy, available_policies, available_routers,
    make_policy, make_router, register_policy, register_router,
)
from repro.core import policy as policy_mod

M, PER_RACK = 12, 4
TOPO = loc.Topology(M, PER_RACK)
SPEC = ClusterSpec(M, PER_RACK)
RACK_OF = jnp.asarray(TOPO.rack_of, jnp.int32)
RATES = [0.5, 0.45, 0.25]
EST = jnp.tile(jnp.asarray(RATES, jnp.float32)[None], (M, 1))


def scripted_trace(n=40, seed=5):
    rng = np.random.default_rng(seed)
    return [sorted(rng.choice(M, 3, replace=False).tolist())
            for _ in range(n)]


# ----------------------------------------------------------------- registry -

def test_every_policy_and_router_is_registered():
    assert set(available_policies()) == {
        "balanced_pandas", "jsq_maxweight", "priority", "fifo", "pandas_po2",
        "blind_pandas", "slo_pandas"}
    assert set(available_routers()) == {
        "balanced_pandas", "jsq_maxweight", "fifo", "pandas_po2"}


def test_duplicate_policy_registration_raises():
    with pytest.raises(ValueError, match="duplicate"):
        @register_policy
        class Dup(SlotPolicy):  # noqa: F811 — never bound
            name = "balanced_pandas"


def test_duplicate_router_registration_raises():
    with pytest.raises(ValueError, match="duplicate"):
        register_router(type("DupRouter", (Router,), {"name": "fifo"}))


def test_unknown_names_rejected_with_listing():
    with pytest.raises(ValueError, match="registered"):
        make_policy("no_such_policy")
    with pytest.raises(ValueError, match="registered"):
        make_router("no_such_router", SPEC, RATES)


def test_policy_config_options_reach_the_policy():
    pol = make_policy(PolicyConfig("pandas_po2", {"d": 5}))
    assert pol.d == 5
    pol = make_policy(PolicyConfig("fifo", {"cap": 64}))
    state = pol.init_state(TOPO)
    assert state.buf.shape[0] == 64
    with pytest.raises(ValueError):
        make_policy(PolicyConfig("pandas_po2", {"d": 0}))


def test_new_policy_lands_once_and_is_instantly_sweepable():
    """The extensibility claim: registering a policy makes it available to
    simulate()/sweep() with zero simulator edits."""

    @register_policy
    class TestOnlyPolicy(bp.BalancedPandasPolicy):
        name = "test_only_pandas_clone"

    try:
        cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), p_hot=0.5,
                            max_arrivals=8, horizon=200, warmup=50)
        est = sim.make_estimates(cfg, "network", 0.0, -1)
        out = sim.simulate("test_only_pandas_clone", cfg, 2.0, est, seed=0)
        assert np.isfinite(out["mean_delay"])
        swept = sim.sweep("test_only_pandas_clone", cfg,
                          np.array([1.0, 2.0], np.float32), est[None],
                          np.arange(2))
        assert swept["mean_delay"].shape == (2, 1, 2)
    finally:
        policy_mod._POLICIES.pop("test_only_pandas_clone")


def test_extra_metrics_flow_through_simulator():
    cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), p_hot=0.5,
                        max_arrivals=8, horizon=300, warmup=50)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate(PolicyConfig("fifo", {"cap": 16}), cfg, 4.0, est, 0)
    assert out["drops"] > 0  # tiny buffer at saturating load must drop
    out = sim.simulate("balanced_pandas", cfg, 2.0, est, 0)
    assert "drops" not in out


# -------------------------------------------- decision cross-checks (trace) -

def _occupancy(s: bp.PandasState) -> np.ndarray:
    return np.asarray(s.q.sum(axis=1))


@pytest.mark.parametrize("name", ["balanced_pandas", "pandas_po2"])
def test_pandas_family_router_matches_policy_on_trace(name):
    """Router and JAX policy agree on the score surface and both pick
    score-minimal servers; pandas_po2 runs with d=M so its candidate set is
    the full fleet and the comparison is exact."""
    opts = {"d": M} if name == "pandas_po2" else {}
    router = make_router(name, SPEC, RATES, seed=0, **opts)
    state = bp.init_state(TOPO)
    key = jax.random.PRNGKey(0)

    for t, task in enumerate(scripted_trace()):
        taskj = jnp.asarray(task, jnp.int32)
        # identical queue state by construction
        np.testing.assert_array_equal(
            router.q.sum(axis=1), _occupancy(state))
        # identical score surface
        tier = router.tiers(task)
        rate = np.take_along_axis(router._est(), tier[:, None], 1)[:, 0]
        score_np = router.workload() / rate
        local, rack = loc.locality_masks(taskj, RACK_OF)
        est_rate = jnp.where(local, EST[:, 0],
                             jnp.where(rack, EST[:, 1], EST[:, 2]))
        score_jx = np.asarray(bp.workload(state, EST)) / np.asarray(est_rate)
        np.testing.assert_allclose(score_np, score_jx, rtol=1e-5, atol=1e-6)

        decision = router.route(task)
        kt = jax.random.fold_in(key, t)
        if name == "balanced_pandas":
            state2 = bp.route_one(state, kt, taskj, jnp.bool_(True), EST,
                                  RACK_OF)
        else:
            state2 = pandas_po2.route_one_po_d(state, kt, taskj,
                                               jnp.bool_(True), EST,
                                               RACK_OF, d=M)
        m_jax = int(np.argmax(_occupancy(state2) - _occupancy(state)))
        mins = np.flatnonzero(score_np <= score_np.min() + 1e-6)
        assert decision.worker in mins
        assert m_jax in mins
        # re-sync the router to the JAX tie-break so the paths stay aligned
        router.q[decision.worker, tier[decision.worker]] -= 1
        router.q[m_jax, tier[m_jax]] += 1
        state = state2


def test_jsq_router_matches_policy_on_trace():
    router = make_router("jsq_maxweight", SPEC, RATES, seed=0)
    q = jnp.zeros((M,), jnp.int32)

    for t, task in enumerate(scripted_trace(seed=7)):
        qv = np.asarray(q)
        np.testing.assert_array_equal(router.q, qv)
        decision = router.route(task)
        q2 = claiming.jsq_route_one(q, jax.random.PRNGKey(t),
                                    jnp.asarray(task, jnp.int32),
                                    jnp.bool_(True))
        m_jax = int(np.argmax(np.asarray(q2) - qv))
        shortest = {task[j]
                    for j in np.flatnonzero(qv[task] == qv[task].min())}
        assert decision.worker in shortest
        assert m_jax in shortest
        router.q[decision.worker] -= 1
        router.q[m_jax] += 1
        q = q2


def test_fifo_router_defers_and_tracks_backlog():
    router = make_router("fifo", SPEC, RATES, seed=0)
    trace = scripted_trace(n=10, seed=3)
    for task in trace:
        d = router.route(task)
        assert d.deferred and d.worker == -1

    # same arrivals through the JAX policy; all servers busy (near-zero true
    # rates keep them busy through the slot), so the ring buffer holds
    # exactly the router's backlog
    s = fifo_mod.init_state(TOPO, cap=64)
    s = s._replace(serving_tier=jnp.full((M,), 3, jnp.int32))
    types = jnp.asarray(trace, jnp.int32)
    active = jnp.ones((len(trace),), bool)
    s, _ = fifo_mod.slot_step(s, jax.random.PRNGKey(0), types, active, EST,
                              jnp.full((3,), 1e-9, jnp.float32), RACK_OF)
    assert int(s.count) == len(router.queue) == len(trace)

    claims = 0
    while router.claim(worker=claims % M) is not None:
        claims += 1
    assert claims == len(trace)


# ------------------------------------------------------- uniform semantics -

def test_all_routers_share_uniform_constructor_and_estimator():
    """Satellite fix: FIFO used to silently drop its estimator; now every
    router stores it and feeds observations through on_complete."""
    from repro.core.estimator import EwmaRateEstimator
    for name in available_routers():
        est = EwmaRateEstimator(M, np.asarray(RATES))
        r = make_router(name, SPEC, RATES, estimator=est, seed=1)
        assert r.estimator is est
        r.on_complete(0, 0, 3.0)
        assert est.sample_counts[0, 0] == 1


def test_pandas_po_d_routes_within_candidates_and_conserves():
    """With small d the po-d router must still behave sanely: idle fleet
    routes local (locals are always candidates), and bookkeeping conserves
    tasks."""
    router = make_router("pandas_po2", SPEC, RATES, seed=0, d=2)
    locs = [0, 1, 2]
    first = router.route(locs)
    assert first.worker in locs and first.tier == 0
    for _ in range(50):
        router.route(locs)
    assert router.q.sum() == 51
    # the JAX policy with d=2: idle fleet routes local as well
    state = bp.init_state(TOPO)
    state = pandas_po2.route_one_po_d(state, jax.random.PRNGKey(0),
                                      jnp.asarray(locs, jnp.int32),
                                      jnp.bool_(True), EST, RACK_OF, d=2)
    assert int(state.q[:, 0].sum()) == 1 and int(state.q[:, 2].sum()) == 0


def test_pandas_po_d_large_d_matches_full_pandas_statistically():
    """d >= M makes pandas_po2 the full-scan policy; a short simulation must
    produce identical trajectories under common random numbers is too strong
    (tie-break keys differ), but delay must be statistically indistinguishable
    at this horizon while d=1 pays a visible locality penalty."""
    cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), p_hot=0.5,
                        max_arrivals=16, horizon=3000, warmup=800)
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    d_full = sim.simulate("balanced_pandas", cfg, 0.8 * cap, est, 0)
    d_big = sim.simulate(PolicyConfig("pandas_po2", {"d": M}), cfg,
                         0.8 * cap, est, 0)
    assert d_big["mean_delay"] == pytest.approx(d_full["mean_delay"],
                                                rel=0.25)
