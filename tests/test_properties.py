"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ClusterSpec, BalancedPandasRouter
from repro.core import locality as loc
from repro.models import layers as L


# ---------------------------------------------------------- capacity model --

@given(st.integers(2, 6), st.integers(4, 10),
       st.floats(0.05, 1.0), st.floats(0.3, 0.95))
@settings(max_examples=60, deadline=None)
def test_capacity_monotonicity(n_racks, per_rack, p_hot, gamma_frac):
    """Capacity never increases with hot fraction, never decreases with
    gamma, and is bounded by the all-local optimum M*alpha."""
    topo = loc.Topology(n_racks * per_rack, per_rack)
    alpha = 0.5
    beta = 0.45
    gamma = min(gamma_frac * beta, beta - 1e-3)
    rates = loc.Rates(alpha, beta, gamma)
    cap = loc.capacity_hot_rack(topo, rates, p_hot)
    assert 0 < cap <= topo.num_servers * alpha + 1e-6
    cap_hotter = loc.capacity_hot_rack(topo, rates, min(p_hot + 0.1, 1.0))
    assert cap_hotter <= cap + 1e-6
    faster = loc.Rates(alpha, beta, min(gamma * 1.1, beta - 1e-4))
    assert loc.capacity_hot_rack(topo, faster, p_hot) >= cap - 1e-6


# -------------------------------------------------- router scale invariance --

@given(st.floats(0.2, 5.0), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_numpy_router_scale_invariance(c, seed):
    """Scaling all estimated rates by c never changes any routing decision
    (the analytical robustness result; see balanced_pandas.py)."""
    rng = np.random.default_rng(seed)
    spec = ClusterSpec(12, 4)
    r1 = BalancedPandasRouter(spec, [0.5, 0.45, 0.25], seed=seed)
    r2 = BalancedPandasRouter(spec, [0.5 * c, 0.45 * c, 0.25 * c], seed=seed)
    for _ in range(25):
        locs = sorted(rng.choice(12, 3, replace=False).tolist())
        assert r1.route(locs).worker == r2.route(locs).worker


# ----------------------------------------------------------- rope isometry --

@given(st.integers(0, 2**31 - 1), st.integers(1, 64),
       st.sampled_from([0.5, 1.0]))
@settings(max_examples=25, deadline=None)
def test_rope_preserves_norm_and_relativity(seed, offset, fraction):
    """RoPE is an isometry per position, and q.k depends only on relative
    position: shifting both positions by the same offset keeps all scores."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 2, 8, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 8, 32))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    for x in (q, k):
        rx = L.rope(x, pos, 10_000.0, fraction)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(rx), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=2e-5)
    s0 = jnp.einsum("bhqd,bhkd->bhqk", L.rope(q, pos, 1e4, fraction),
                    L.rope(k, pos, 1e4, fraction))
    s1 = jnp.einsum("bhqd,bhkd->bhqk",
                    L.rope(q, pos + offset, 1e4, fraction),
                    L.rope(k, pos + offset, 1e4, fraction))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               atol=2e-3, rtol=2e-3)


# ----------------------------------------------- cache commit equivalences --

@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_commit_kv_aligned_equals_scatter(seed, layers):
    """For slot-uniform positions, the aligned DUS commit and the batched
    scatter commit must produce identical caches."""
    key = jax.random.PRNGKey(seed)
    b, h, s, d, t = 2, 2, 16, 8, 1
    cache = {
        "k": jax.random.normal(key, (layers, b, h, s, d)),
        "v": jax.random.normal(jax.random.fold_in(key, 1),
                               (layers, b, h, s, d)),
        "pos": jnp.full((layers, b, s), -1, jnp.int32),
    }
    k_new = jax.random.normal(jax.random.fold_in(key, 2), (layers, b, h, t, d))
    v_new = jax.random.normal(jax.random.fold_in(key, 3), (layers, b, h, t, d))
    p0 = int(jax.random.randint(jax.random.fold_in(key, 4), (), 0, 40))
    positions = jnp.full((b, t), p0, jnp.int32)
    a = L.commit_kv(cache, k_new, v_new, positions, aligned=True)
    b_ = L.commit_kv(cache, k_new, v_new, positions, aligned=False)
    for kk in ("k", "v", "pos"):
        np.testing.assert_allclose(np.asarray(a[kk]), np.asarray(b_[kk]),
                                   atol=1e-6)


# ------------------------------------------------------- mha decode == full --

@given(st.integers(0, 2**31 - 1), st.sampled_from([0, 8]))
@settings(max_examples=15, deadline=None)
def test_mha_decode_matches_mha_xla(seed, window):
    """The two-piece (stale cache + self token) decode softmax equals
    attention over the cache WITH the token written."""
    key = jax.random.PRNGKey(seed)
    b, h, s, d = 1, 2, 16, 8
    cur = 10  # tokens 0..9 in cache, decoding token 10
    kc = jax.random.normal(key, (b, h, s, d))
    vc = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d))
    kpos = jnp.where(jnp.arange(s) < cur, jnp.arange(s), -1)[None]
    kn = jax.random.normal(jax.random.fold_in(key, 2), (b, h, 1, d))
    vn = jax.random.normal(jax.random.fold_in(key, 3), (b, h, 1, d))
    q = jax.random.normal(jax.random.fold_in(key, 4), (b, h, 1, d))
    qpos = jnp.full((b, 1), cur, jnp.int32)

    out_two = L.mha_decode(q, kc, vc, kn, vn, qpos, kpos, window=window,
                           softcap=0.0, scale=d ** -0.5)
    # reference: write the token, then plain masked attention
    kc2 = kc.at[:, :, cur].set(kn[:, :, 0])
    vc2 = vc.at[:, :, cur].set(vn[:, :, 0])
    kpos2 = kpos.at[:, cur].set(cur)
    out_full = L.mha_xla(q, kc2, vc2, qpos, kpos2, causal=True,
                         window=window, softcap=0.0, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out_two), np.asarray(out_full),
                               atol=1e-5, rtol=1e-5)


# -------------------------------------------------------- pipeline tokens ---

@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_chunk_tokens_deterministic_and_in_vocab(chunk_id):
    from repro.data.pipeline import PipelineConfig, chunk_tokens
    cfg = PipelineConfig(tokens_per_chunk=256, vocab_size=1000)
    a = chunk_tokens(cfg, chunk_id)
    b = chunk_tokens(cfg, chunk_id)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 1000
