"""Replication-lifecycle subsystem: registry surface, the scenario failure
track (down_servers / down_racks -> alive masks on both substrates),
bitwise `fixed` pins for every policy, repair / popularity properties,
the migration cost model, the host-side mirror (engine + pipeline),
kernel-vs-oracle on post-migration placements, the study driver, and the
two satellite regressions (scipy-optional placement import, hot_aware
checkpoint round-trip).
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import locality as loc, robustness as rb, simulator as sim
from repro.replication import (MigrationModel, ReplicationConfig,
                               available_replications, make_replication,
                               replication_descriptions)
from repro.workloads import (Segment, compile_schedule, host_playback,
                             make_scenario)

ALL_REPLICATIONS = ("fixed", "popularity", "repair")
ALGOS = ("balanced_pandas", "jsq_maxweight", "priority", "fifo",
         "pandas_po2", "blind_pandas")


def small_cfg(**kw):
    base = dict(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                p_hot=0.5, max_arrivals=16, horizon=800, warmup=200)
    base.update(kw)
    return sim.SimConfig(**base)


def _policy(algo):
    from repro.core.policy import PolicyConfig
    return PolicyConfig("blind_pandas", {"prior": loc.Rates().values}) \
        if algo == "blind_pandas" else algo


# ------------------------------------------------------------- registry --

def test_registry_surface():
    assert set(ALL_REPLICATIONS) <= set(available_replications())
    descs = replication_descriptions()
    assert all(descs[r] for r in ALL_REPLICATIONS)
    with pytest.raises(ValueError):
        make_replication("nope")
    # None -> fixed (the do-nothing controller)
    ctrl = make_replication(None)
    assert ctrl.name == "fixed" and ctrl.is_static
    assert not make_replication("repair").is_static
    c = make_replication(ReplicationConfig("popularity", {"r_hot": 6}))
    assert c.r_hot == 6 and c.max_target(3) == 6
    with pytest.raises(ValueError):
        make_replication(ReplicationConfig("popularity", {"r_hot": 1,
                                                          "r_cold": 3}))
    with pytest.raises(ValueError):
        make_replication(ReplicationConfig("popularity", {"hot_frac": 0.0}))
    with pytest.raises(ValueError):
        make_replication(ReplicationConfig("repair", {"lanes": 0}))
    # passing an instance through is identity; options then make no sense
    assert make_replication(ctrl) is ctrl
    with pytest.raises(ValueError):
        make_replication(ctrl, lanes=2)


def test_migration_model_cost_table():
    m = MigrationModel()  # chunk_size 8.0
    rates = np.asarray(loc.Rates().values)  # (0.5, 0.45, 0.25)
    tab = m.cost_table(rates)
    np.testing.assert_array_equal(tab, [16.0, 18.0, 32.0])
    assert m.cost(rates, 2) == 32.0
    with pytest.raises(ValueError):
        MigrationModel(chunk_size=0.0)
    with pytest.raises(ValueError):
        MigrationModel(contention=0.0)


# ------------------------------------------- scenario failure track ------

def test_segment_failure_fields_validate():
    s = Segment(start=0.0, down_servers=(np.int64(3), 1))
    assert s.down_servers == (3, 1)  # coerced to plain ints
    with pytest.raises(ValueError):
        Segment(start=0.0, down_servers=(-1,))
    with pytest.raises(ValueError):
        Segment(start=0.0, down_racks=(1.5,))


def test_failure_scenarios_registered_and_compile():
    from repro.workloads import available_scenarios
    assert {"server_loss", "rack_loss"} <= set(available_scenarios())
    topo = loc.Topology(12, 4)
    for name in ("server_loss", "rack_loss"):
        sched = compile_schedule(make_scenario(name), topo, 300, 0.5)
        assert sched.alive is not None
        alive = np.asarray(sched.alive)
        assert alive.shape == (3, 12)
        assert alive[0].all() and alive[2].all()  # healthy bookends
        assert not alive[1].all()                 # the loss window
    # static scenario carries no failure track at all
    assert compile_schedule(make_scenario(None), topo, 300, 0.5).alive is None


def test_rack_loss_needs_rack_structure():
    from repro.workloads.scenario import _dense_segments
    from repro.workloads import Scenario
    scn = make_scenario("rack_loss")
    with pytest.raises(ValueError):
        _dense_segments(scn, 12, 4, 0.5, num_tiers=3, rack_of=None)
    # killing every server is a scenario bug, not a simulation outcome
    scn_all = Scenario("suicide",
                       (Segment(start=0.0, down_servers=tuple(range(4))),))
    with pytest.raises(ValueError):
        _dense_segments(scn_all, 4, 4, 0.5, num_tiers=3,
                        rack_of=np.zeros(4, np.int64))


def test_host_playback_alive_mask():
    topo = loc.Topology(8, 4)
    pb = host_playback(make_scenario("server_loss"), 8, 100.0,
                       num_tiers=3, rack_of=np.asarray(topo.rack_of))
    assert pb.alive is not None
    t_mid = 100.0 * 0.5  # inside the default loss window [0.35, 0.65)
    assert not pb.alive_at(t_mid, 0)
    assert pb.alive_at(5.0, 0) and pb.alive_at(95.0, 0)
    mask = pb.alive_mask_at(t_mid)
    assert mask.shape == (8,) and not mask.all() and mask.any()
    # static playback: everything alive, everywhere
    pb0 = host_playback(make_scenario(None), 8, 100.0, num_tiers=3)
    assert pb0.alive is None and pb0.alive_mask_at(50.0).all()


# ------------------------------------------------ bitwise fixed pins -----

@pytest.mark.parametrize("algo", ALGOS)
def test_fixed_replication_is_bitwise_default_sim(algo):
    """replication="fixed" under a static scenario must reproduce the
    replication-less sample path EXACTLY for every policy (that path is
    itself pinned to the pre-refactor bits by tests/test_topology.py)."""
    cfg = small_cfg()
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    base = sim.simulate(_policy(algo), cfg, 0.8 * cap, est, seed=3)
    pinned = sim.simulate(_policy(algo), cfg, 0.8 * cap, est, seed=3,
                          replication="fixed")
    assert base == pinned
    # and the passthrough adds no metric keys
    assert set(pinned) == set(base)


# ---------------------------------------------- lifecycle properties -----

def test_repair_restores_replication_factor():
    cfg = small_cfg()
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    fixed = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                         scenario="server_loss", replication="fixed")
    repair = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                          scenario="server_loss", replication="repair")
    # the loss window wipes replicas; only the repair controller rebuilds
    assert fixed["final_replication"] < 3.0
    assert repair["final_replication"] == pytest.approx(3.0)
    assert repair["repair_moves"] > 0 and fixed["repair_moves"] == 0


def test_repair_respects_lane_cap():
    cfg = small_cfg()
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                       scenario="server_loss",
                       replication=ReplicationConfig("repair",
                                                     {"lanes": 2}))
    assert 0 < out["max_concurrent_moves"] <= 2
    wide = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                        scenario="server_loss",
                        replication=ReplicationConfig("repair",
                                                      {"lanes": 6}))
    assert wide["max_concurrent_moves"] <= 6
    # a tighter repair-bandwidth cap cannot finish repairs sooner
    assert out["repair_moves"] <= wide["repair_moves"] + 1


def test_popularity_widens_hot_chunks():
    cfg = small_cfg()
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                       replication=ReplicationConfig(
                           "popularity", {"r_hot": 5, "r_cold": 3}))
    # hot chunks grow toward 5, cold stay at 3: mean strictly above 3
    assert out["final_replication"] > 3.0
    assert out["repair_moves"] > 0


def test_rack_loss_can_lose_data_without_cross_rack_replicas():
    """A whole-rack loss under the `spread` placement (replicas scattered
    across racks) must lose nothing; the availability/data-loss metrics
    separate the two."""
    cfg = small_cfg()
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 3.0, est, seed=0,
                       scenario="rack_loss", placement="spread",
                       replication="repair")
    assert out["data_loss_frac"] == 0.0
    assert out["availability"] == pytest.approx(1.0)


def test_sweep_carries_replication_metrics():
    cfg = small_cfg(horizon=400, warmup=100)
    est = sim.make_estimates(cfg, "network", 0.0, -1)[None]
    res = sim.sweep("balanced_pandas", cfg, np.asarray([3.0, 4.0]),
                    est, np.asarray([0, 1]), scenario="server_loss",
                    replication="repair")
    for key in ("availability", "data_loss_frac", "mean_replication",
                "final_replication", "repair_moves"):
        assert res[key].shape == (2, 1, 2), key
    assert (res["availability"] <= 1.0).all()
    assert (res["repair_moves"] >= 0).all()


# ------------------------------------------------------- host mirror -----

def _host_ctrl(name="repair", m=8, chunks=16, **opts):
    topo = loc.Topology(m, m // 2)
    from repro.placement import make_placement
    ctrl = make_replication(ReplicationConfig(name, opts) if opts else name)
    host = ctrl.build_host(topo, make_placement(None), chunks, 3, 0,
                           np.asarray(loc.Rates().values))
    return host, topo


def test_host_repair_after_kill():
    host, topo = _host_ctrl()
    assert host.mean_replication() == pytest.approx(3.0)
    alive = np.ones(topo.num_servers, bool)
    alive[:3] = False
    host.observe(0.0, alive)
    wiped = host.mean_replication()
    assert wiped < 3.0
    # chunks whose whole replica set died are gone for good — repair can
    # only restore the rest to the target factor
    lost = sum(not host.replicas_for(c) for c in range(host.num_chunks))
    for t in range(1, 400):
        host.observe(float(t), alive)
    want = 3.0 * (host.num_chunks - lost) / host.num_chunks
    assert host.mean_replication() == pytest.approx(want)
    assert host.moves > 0
    assert host.data_loss_frac() == pytest.approx(lost / host.num_chunks)
    # repaired copies live only on surviving hosts
    for c in range(host.num_chunks):
        assert all(alive[h] for h in host.replicas_for(c))


def test_host_replicas_for_and_lost_reads():
    host, topo = _host_ctrl(name="fixed", m=4, chunks=4)
    locs = host.replicas_for(1)
    assert locs == sorted(locs) and len(locs) == 3
    host.observe(0.0, np.zeros(topo.num_servers, bool) | False)
    # all hosts dead -> no live replica, read is lost
    assert host.replicas_for(1) == []
    assert host.lost_reads == 1


def test_host_state_round_trip_is_json_safe():
    host, topo = _host_ctrl()
    alive = np.ones(topo.num_servers, bool)
    alive[0] = False
    host.observe(0.0, alive)
    host.note_read(3)
    state = json.loads(json.dumps(host.state_dict()))  # the manifest path
    host2, _ = _host_ctrl()
    host2.load_state_dict(state)
    assert host2.state_dict() == host.state_dict()
    # lanes survive: advancing both produces identical placements
    for t in range(1, 200):
        host.observe(float(t), alive)
        host2.observe(float(t), alive)
    np.testing.assert_array_equal(host.mask, host2.mask)


def test_post_migration_placements_feed_both_kernels():
    """Post-repair replica rows drive wwl_route / maxweight_claim
    unchanged (kernel vs oracle on lifecycle-produced task_locals)."""
    from repro.kernels import ops, ref
    from repro.placement import make_placement
    topo = loc.Topology(24, (4, 12))
    ctrl = make_replication("repair")
    host = ctrl.build_host(topo, make_placement(None), 16, 3, 0,
                           np.asarray([0.5, 0.45, 0.35, 0.25]))
    alive = np.ones(24, bool)
    alive[[0, 5, 7]] = False
    for t in range(200):
        host.observe(float(t), alive)
    rows = [host.replicas_for(c) for c in range(9)]
    assert all(len(r) == 3 for r in rows)
    tl = jnp.asarray(rows, jnp.int32)
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    rng = np.random.default_rng(3)
    m, b = 24, 9
    wlv = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile([0.5, 0.45, 0.35, 0.25], (m, 1)), jnp.float32)
    s1, t1, _ = ops.wwl_route(wlv, er, anc, tl)
    s2, t2, _ = ref.wwl_route(wlv, er, anc, tl)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    q = jnp.asarray(rng.integers(0, 5, m), jnp.float32)
    ids = jnp.asarray(rng.choice(m, b, replace=False), jnp.int32)
    er2 = jnp.asarray(np.tile([0.5, 0.45, 0.35, 0.25], (b, 1)), jnp.float32)
    q1, sv1 = ops.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    q2, sv2 = ref.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


# --------------------------------------------------- pipeline / engine ---

def test_pipeline_replication_gate_and_failure_run():
    from repro.data.pipeline import DataPipeline, PipelineConfig
    small = dict(num_hosts=8, hosts_per_pod=4, num_chunks=32, seq_len=64,
                 global_batch=2, seed=0)
    # static + fixed: machinery compiled out entirely (the bitwise gate)
    assert DataPipeline(PipelineConfig(**small)).replication_ctl is None
    assert DataPipeline(PipelineConfig(
        **small, replication_policy="fixed")).replication_ctl is None
    # a failure scenario engages the machinery even for fixed
    p_fix = DataPipeline(PipelineConfig(**small, scenario="server_loss"))
    assert p_fix.replication_ctl is not None
    p = DataPipeline(PipelineConfig(**small, scenario="server_loss",
                                    replication_policy="repair"))
    for _ in range(12):
        next(p)
    assert p.metrics["reads"] > 0
    assert p.replication_ctl.mean_replication() > 0


def test_pipeline_checkpoint_restores_replication_state():
    from repro.data.pipeline import DataPipeline, PipelineConfig
    from repro.train.trainer import _np_to_list
    kw = dict(num_hosts=8, hosts_per_pod=4, num_chunks=32, seq_len=64,
              global_batch=2, seed=0, scenario="server_loss",
              replication_policy="repair")
    p1 = DataPipeline(PipelineConfig(**kw))
    for _ in range(6):
        next(p1)
    # exactly what the trainer writes into the checkpoint manifest
    state = json.loads(json.dumps(_np_to_list(p1.state_dict())))
    p2 = DataPipeline(PipelineConfig(**kw))
    p2.load_state_dict(state)
    assert (p2.replication_ctl.state_dict()
            == p1.replication_ctl.state_dict())
    b1, b2 = next(p1), next(p2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # a manifest carrying lifecycle state needs a configured controller
    p3 = DataPipeline(PipelineConfig(num_hosts=8, hosts_per_pod=4,
                                     num_chunks=32, seq_len=64,
                                     global_batch=2, seed=0))
    with pytest.raises(ValueError):
        p3.load_state_dict(state)


def test_engine_replication_gate_and_repair():
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine
    CFG = registry.get_smoke_config("chatglm3_6b")
    PARAMS = P.init_params(CFG, jax.random.PRNGKey(0))
    base = dict(num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
                max_len=64, prefill_buckets=(16,))
    # static + fixed: no lifecycle object at all (bitwise by construction)
    assert ServingEngine(CFG, PARAMS, EngineConfig(
        **base, replication="fixed")).replication is None
    eng = ServingEngine(CFG, PARAMS, EngineConfig(
        **base, scenario="server_loss", replication="repair",
        scenario_horizon=12))
    assert eng.replication is not None
    rng = np.random.default_rng(3)
    outstanding = []
    for t in range(30):  # drip-feed through the loss window
        for _ in range(2):
            rid = len(outstanding)
            req = Request(rid=rid, max_new_tokens=2, prefix_id=rid % 6,
                          prompt=rng.integers(0, CFG.vocab_size,
                                              8).astype(np.int32))
            eng.submit(req)
            outstanding.append(req)
        eng.step()
    while any(r.finish_time == 0.0 for r in outstanding) and eng.steps < 200:
        eng.step()
    assert all(r.finish_time > 0 for r in outstanding)
    assert eng.replication.moves > 0  # the window forced repairs
    assert eng.replication.availability() == pytest.approx(1.0)


# ------------------------------------------------------- study driver ----

def test_replication_study_shapes_and_gates():
    cfg = rb.StudyConfig(sim=small_cfg(horizon=600, warmup=150), seeds=(0,))
    study = rb.replication_study(cfg, replications=("fixed", "repair"),
                                 scenarios=("server_loss",),
                                 policies=("balanced_pandas",),
                                 loads=(0.7,))
    a = study["availability"]["server_loss"]["repair"]["balanced_pandas"]
    assert a.shape == (1, 1)
    mv = study["repair_moves"]["server_loss"]
    assert float(mv["repair"]["balanced_pandas"].mean()) > 0
    assert float(mv["fixed"]["balanced_pandas"].mean()) == 0
    text = rb.summarize_replication(study)
    assert "server_loss" in text and "repair" in text


# ------------------------------------------------- satellite regressions --

def test_placement_package_imports_without_scipy():
    """repro.placement must import (and everything but the LP must run)
    when scipy is absent; placement_capacity raises a descriptive
    ImportError under strict=True and returns None under strict=False."""
    code = """
import sys
sys.modules["scipy"] = None
sys.modules["scipy.optimize"] = None
sys.modules["scipy.sparse"] = None
import repro.placement as P
from repro.core import locality as loc
try:
    P.placement_capacity(loc.Topology(8, 4), loc.Rates(), 0.5, "uniform",
                         n_samples=50, strict=True)
except ImportError as e:
    assert "scipy" in str(e) and "optional" in str(e), e
else:
    raise AssertionError("strict=True should raise without scipy")
out = P.placement_capacity(loc.Topology(8, 4), loc.Rates(), 0.5, "uniform",
                           n_samples=50, strict=False)
assert out is None, out
print("OK")
"""
    proc = subprocess.run([sys.executable, "-c", code], text=True,
                          capture_output=True)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_hot_aware_state_json_safe_with_numpy_ids():
    """np.int64 chunk ids (what the pipeline/engine actually pass) must not
    poison the checkpoint manifest: json.dumps of state_dict() works and
    the round trip preserves the counts."""
    from repro.placement import make_placement
    p = make_placement("hot_aware")
    for c in (np.int64(3), np.int32(5), 3):
        p.note_read(c)
    s = json.loads(json.dumps(p.state_dict()))
    assert s["count_ids"] == [3, 5] and s["counts"] == [2, 1]


def test_hot_aware_mid_run_save_load_same_rebalance():
    """Satellite regression: save/load mid-run must leave the *subsequent*
    rebalance() decisions identical (the popularity state round-trips
    through the trainer's JSON manifest path)."""
    from repro.placement import make_placement
    from repro.train.trainer import _np_to_list
    rng = np.random.default_rng(0)
    p1 = make_placement("hot_aware")
    for c in rng.integers(0, 32, 200):
        p1.note_read(c)  # numpy ints, like the real callers
    p1.rebalance()
    for c in rng.integers(0, 32, 100):
        p1.note_read(c)
    state = json.loads(json.dumps(_np_to_list(p1.state_dict())))
    p2 = make_placement("hot_aware")
    p2.load_state_dict(state)
    assert p1.rebalance() == p2.rebalance()
    assert p1.state_dict() == p2.state_dict()
    topo = loc.Topology(12, 4)
    for c in range(32):
        assert (p1.replicas(topo, c, 3, 0) == p2.replicas(topo, c, 3, 0))
