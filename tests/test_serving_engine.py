"""Serving engine end-to-end: output equivalence with direct greedy decoding,
drain behaviour, router comparisons, straggler response."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import params as P, transformer as T
from repro.serve.engine import EngineConfig, Request, ServingEngine

CFG = registry.get_smoke_config("chatglm3_6b")
PARAMS = P.init_params(CFG, jax.random.PRNGKey(0))
ECFG = EngineConfig(num_replicas=4, replicas_per_pod=2, slots_per_replica=2,
                    max_len=64, prefill_buckets=(16,))


def direct_greedy(prompt: np.ndarray, n_new: int):
    """Reference: plain prefill + greedy decode, no engine machinery."""
    caches = T.init_caches(CFG, 1, 64)
    t = len(prompt)
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    logits, caches, _ = T.forward(PARAMS, CFG, jnp.asarray(prompt)[None],
                                  positions=pos, caches=caches, remat=False)
    toks = [int(jnp.argmax(logits[0, -1]))]
    length = t
    for _ in range(n_new):
        lg, caches = T.decode_step(PARAMS, CFG,
                                   jnp.asarray([[toks[-1]]], jnp.int32),
                                   jnp.asarray([length], jnp.int32), caches)
        toks.append(int(jnp.argmax(lg[0, 0])))
        length += 1
    return toks


def test_engine_matches_direct_greedy():
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, CFG.vocab_size, 10).astype(np.int32)
               for _ in range(6)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4, prefix_id=i)
            for i, p in enumerate(prompts)]
    eng = ServingEngine(CFG, PARAMS, ECFG)
    out = eng.run_until_drained(reqs, max_steps=100)
    for r, p in zip(out, prompts):
        want = direct_greedy(p, 4)
        assert r.generated[:len(want)] == want, f"request {r.rid}"


def test_engine_continuous_batching_oversubscribed():
    """3x more requests than total slots: engine must drain them all."""
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3, prefix_id=i % 4)
            for i in range(24)]
    eng = ServingEngine(CFG, PARAMS, ECFG)
    out = eng.run_until_drained(reqs, max_steps=400)
    assert all(r.finish_time > 0 for r in out)
    assert all(len(r.generated) >= 3 for r in out)
    # every replica participated
    assert len({r.replica for r in out}) == ECFG.num_replicas


@pytest.mark.parametrize("scheduler", ["balanced_pandas", "pandas_po2",
                                       "jsq_maxweight", "fifo"])
def test_all_schedulers_drain(scheduler):
    rng = np.random.default_rng(3)
    ecfg = EngineConfig(num_replicas=2, replicas_per_pod=2,
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,), scheduler=scheduler)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, 6).astype(np.int32),
                    max_new_tokens=2, prefix_id=i % 3) for i in range(6)]
    eng = ServingEngine(CFG, PARAMS, ecfg)
    out = eng.run_until_drained(reqs, max_steps=200)
    assert all(r.finish_time > 0 for r in out)


def test_locality_preference_in_assignment():
    """With slack capacity the router should overwhelmingly pick local
    replicas (tier 0)."""
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, 6).astype(np.int32),
                    max_new_tokens=2, prefix_id=i) for i in range(8)]
    eng = ServingEngine(CFG, PARAMS, ECFG)
    eng.run_until_drained(reqs, max_steps=200)
    assert eng.assign_tiers[0] >= sum(eng.assign_tiers.values()) * 0.7


def test_engine_scenario_playback_feeds_estimator():
    """Scenario playback inflates observed service times during the
    straggler window, and the EWMA estimator sees it: the straggler
    replica's learned local rate must fall below a clean replica's."""
    from repro.workloads import make_scenario

    scn = make_scenario("stragglers", servers=(1,), factor=0.05,
                        start=0.01, width=0.98)
    ecfg = EngineConfig(num_replicas=4, replicas_per_pod=2,
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,), scenario=scn,
                        scenario_horizon=100)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, 6).astype(np.int32),
                    max_new_tokens=2, prefix_id=i % 4) for i in range(16)]
    eng = ServingEngine(CFG, PARAMS, ecfg)
    assert eng.playback.slowdown(50.0, 1) == pytest.approx(20.0)
    out = eng.run_until_drained(reqs, max_steps=300)
    assert all(r.finish_time > 0 for r in out)
    rates = eng.estimator.rates  # (R, 3)
    counts = eng.estimator.sample_counts
    if counts[1, 0] >= 1 and counts[0, 0] >= 1:
        assert rates[1, 0] < rates[0, 0]


def test_engine_trace_export(tmp_path):
    """The engine's event trace round-trips as Perfetto-loadable Chrome
    trace JSON: submit/route/admit instants, per-request and decode
    complete events, queue-depth counters, thread-name metadata."""
    import dataclasses

    from repro.telemetry import EventRecorder, load_trace

    tracer = EventRecorder()
    rng = np.random.default_rng(6)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, 6).astype(np.int32),
                    max_new_tokens=2, prefix_id=i) for i in range(4)]
    eng = ServingEngine(CFG, PARAMS, dataclasses.replace(ECFG, tracer=tracer))
    eng.run_until_drained(reqs, max_steps=100)
    doc = load_trace(tracer.save(tmp_path / "engine_trace.json"))
    by_ph = {}
    for ev in doc["traceEvents"]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"submit", "route", "admit", "queued", "decode"} <= names
    assert sum(e["name"] == "submit" for e in by_ph["i"]) == len(reqs)
    req_evs = [e for e in by_ph["X"] if e["cat"] == "request"]
    assert len(req_evs) == len(reqs)
    # virtual clock: request spans sit on the engine-step clock (1 step
    # == 1 ms == 1000 us), on the worker replica's thread lane
    for e in req_evs:
        assert e["ts"] % 1000.0 == 0.0 and e["dur"] >= 1000.0
        assert 1 <= e["tid"] <= eng.spec.num_servers
    assert any(e["name"] == "thread_name" for e in by_ph["M"])
