"""Simulator-level behaviour: stability, determinism, FIFO saturation."""

import numpy as np
import pytest

from repro.core import locality as loc, simulator as sim

CFG = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                    p_hot=0.5, max_arrivals=16, horizon=4000, warmup=1000)
CAP = loc.capacity_hot_rack(CFG.topo, CFG.true_rates, CFG.p_hot)
EXACT = sim.make_estimates(CFG, "network", 0.0, -1)


def test_capacity_small_topo():
    # M=12, M_R=4: (12-4+4*2)/(0.5/0.5+0.5/0.25)/... see locality.py
    assert CAP == pytest.approx((12 - 4 + 4 * 2) / (1 + 2))


@pytest.mark.parametrize("algo", ["balanced_pandas", "jsq_maxweight",
                                  "priority"])
def test_stable_at_moderate_load(algo):
    out = sim.simulate(algo, CFG, 0.7 * CAP, EXACT, seed=0)
    # throughput tracks arrivals; system does not diverge
    assert out["throughput"] == pytest.approx(0.7 * CAP, rel=0.1)
    assert out["final_n"] < 200
    # completion time is at least one service time (1/alpha slots)
    assert out["mean_delay"] >= 1.0 / CFG.true_rates.alpha


def test_fifo_saturates_inside_capacity_region():
    """FIFO is not throughput optimal on the rack model (paper §1): at a
    load the other algorithms sustain, its queue keeps growing."""
    out = sim.simulate("fifo", CFG, 0.85 * CAP, EXACT, seed=0)
    good = sim.simulate("balanced_pandas", CFG, 0.85 * CAP, EXACT, seed=0)
    assert out["final_n"] > 5 * good["final_n"]


def test_deterministic_given_seed():
    a = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3)
    b = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3)
    assert a == b
    c = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=4)
    assert a["mean_n"] != c["mean_n"]


def test_pandas_beats_jsq_mw_in_heavy_traffic():
    """Paper Fig. 2: heavy-traffic delay advantage of Balanced-PANDAS."""
    hi = 0.95 * CAP
    d_bp = np.mean([sim.simulate("balanced_pandas", CFG, hi, EXACT, s)
                    ["mean_delay"] for s in range(3)])
    d_mw = np.mean([sim.simulate("jsq_maxweight", CFG, hi, EXACT, s)
                    ["mean_delay"] for s in range(3)])
    assert d_bp < d_mw


def test_sweep_shapes():
    lam = np.array([0.6, 0.8], np.float32) * CAP
    ests = np.stack([EXACT, sim.make_estimates(CFG, "per_server", 0.3, 1)])
    out = sim.sweep("balanced_pandas", CFG, lam, ests, np.arange(2))
    assert out["mean_delay"].shape == (2, 2, 2)
    assert np.isfinite(out["mean_delay"]).all()


def test_make_estimates_modes():
    e_net = sim.make_estimates(CFG, "network", 0.2, -1)
    assert e_net.shape == (12, 3)
    np.testing.assert_allclose(e_net[:, 0], CFG.true_rates.alpha)
    np.testing.assert_allclose(e_net[:, 1], CFG.true_rates.beta * 0.8)
    e_ps = sim.make_estimates(CFG, "per_server", 0.2, 1, seed=1)
    assert (e_ps >= np.array([[0.5, 0.45, 0.25]])).all()
    assert (e_ps <= np.array([[0.5, 0.45, 0.25]]) * 1.2 + 1e-6).all()
    with pytest.raises(ValueError):
        sim.make_estimates(CFG, "bogus", 0.1, 1)
