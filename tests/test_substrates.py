"""Data pipeline, checkpointer, cluster router, estimator, elastic planning."""

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import ClusterSpec, BalancedPandasRouter, EwmaRateEstimator
from repro.data.pipeline import DataPipeline, PipelineConfig, chunk_replicas
from repro.launch.elastic import (HeartbeatMonitor, plan_elastic_mesh,
                                  rebalance_batch)


# ---------------------------------------------------------------- pipeline --

def test_pipeline_deterministic_and_reproducible():
    cfg = PipelineConfig(global_batch=4, seq_len=64, num_chunks=32,
                         tokens_per_chunk=1024, seed=7)
    a = [next(DataPipeline(cfg)) for _ in range(1)][0]
    b = [next(DataPipeline(cfg)) for _ in range(1)][0]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_pipeline_state_restore_resumes_identically():
    cfg = PipelineConfig(global_batch=2, seq_len=32, num_chunks=16,
                         tokens_per_chunk=512)
    p1 = DataPipeline(cfg)
    for _ in range(3):
        next(p1)
    snap = p1.state_dict()
    want = next(p1)
    p2 = DataPipeline(cfg)
    p2.load_state_dict(snap)
    got = next(p2)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_chunk_replication_stable_and_distinct():
    for c in range(50):
        locs = chunk_replicas(c, 16, 3, seed=0)
        assert len(set(locs)) == 3
        assert locs == chunk_replicas(c, 16, 3, seed=0)
    # different seeds shuffle placement
    assert any(chunk_replicas(c, 16, 3, 0) != chunk_replicas(c, 16, 3, 1)
               for c in range(20))


def test_pipeline_mostly_local_at_idle():
    cfg = PipelineConfig(global_batch=2, seq_len=64, num_chunks=64,
                         tokens_per_chunk=512)
    p = DataPipeline(cfg)
    for _ in range(8):
        next(p)
    local, rack, remote = p.locality_fractions
    assert local > 0.9  # idle fleet: router prefers local replicas


def test_pipeline_straggler_shedding():
    """A 10x-slow host must receive a sub-fair share of reads once the EWMA
    estimator learns its rate — the paper's robustness story, live."""
    cfg = PipelineConfig(global_batch=2, seq_len=64, num_chunks=256,
                         tokens_per_chunk=512, seed=3)
    slow_host = 5
    p = DataPipeline(cfg, slow_hosts={slow_host: 0.1})
    for _ in range(60):
        next(p)
    reads = p.metrics["host_reads"]
    fair = reads.sum() / cfg.num_hosts
    assert reads[slow_host] < fair  # sheds load relative to fair share


# ------------------------------------------------------------- checkpoint --

def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones(4, np.int32)}}
    for step in (10, 20, 30):
        ck.save(step, tree, metadata={"step": step})
    assert ck.latest_step() == 30
    template = {"a": np.zeros((2, 3), np.float32),
                "b": {"c": np.zeros(4, np.int32)}}
    out = ck.restore(template)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
    # retention: only 2 newest kept
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]
    assert ck.manifest()["metadata"]["step"] == 30


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": np.ones((2, 2), np.float32)})
    with pytest.raises(ValueError):
        ck.restore({"w": np.zeros((3, 3), np.float32)})


# ----------------------------------------------------------------- router --

def test_router_prefers_idle_local_then_balances():
    spec = ClusterSpec(8, 4)
    r = BalancedPandasRouter(spec, [1.0, 0.8, 0.4], seed=0)
    locs = [0, 1, 2]
    first = r.route(locs).worker
    assert first in locs  # idle fleet -> local
    # saturate the locals; next assignment must leave the local set
    for _ in range(40):
        r.route(locs)
    assert r.q.sum() == 41
    assert r.q[3:, :].sum() > 0  # spilled to rack-local/remote


def test_estimator_converges_to_true_rate():
    est = EwmaRateEstimator(4, np.array([1.0, 0.8, 0.4]), decay=0.9,
                            min_samples=4)
    rng = np.random.default_rng(0)
    for _ in range(300):
        est.observe(2, 0, rng.exponential(1 / 0.25))  # true local rate 0.25
    assert est.rates[2, 0] == pytest.approx(0.25, rel=0.3)
    # untouched entries keep the prior
    assert est.rates[1, 1] == pytest.approx(0.8)


# ---------------------------------------------------------------- elastic --

def test_heartbeat_failure_detection():
    hb = HeartbeatMonitor(4, timeout_s=10.0)
    now = 1000.0
    for w in range(4):
        hb.beat(w, t=now)
    hb.beat(2, t=now + 20)
    assert hb.failed(now=now + 15) == [0, 1, 3]
    assert hb.alive(now=now + 15) == [2]


def test_plan_elastic_mesh():
    shape, names = plan_elastic_mesh(512, model_axis=16)
    assert shape == (2, 16, 16) and names == ("pod", "data", "model")
    shape, names = plan_elastic_mesh(240, model_axis=16)
    assert shape == (15, 16) and names == ("data", "model")
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, model_axis=16)


def test_rebalance_batch_keeps_global_batch():
    gb, n_mb = rebalance_batch(256, old_dp=16, new_dp=8, microbatches=4)
    assert gb == 256
    assert 256 % n_mb == 0 and (256 // n_mb) % 8 == 0
    # dp that shares no factor with the batch is impossible: surface it
    with pytest.raises(RuntimeError):
        rebalance_batch(256, old_dp=16, new_dp=15, microbatches=4)
