"""Telemetry subsystem: in-scan recorder (bitwise purity, percentile
accuracy, downsampling, accounting) and host-side event tracing (Chrome
trace schema, engine/pipeline emission)."""

import json

import numpy as np
import pytest

from repro.core import locality as loc, simulator as sim
from repro.core.balanced_pandas import BalancedPandasPolicy
from repro.core.policy import available_policies, get_policy_cls
from repro.telemetry import (TELEMETRY_METRIC_KEYS, EventRecorder,
                             SimTelemetry, TelemetryConfig,
                             as_telemetry_config, fcfs_sojourns, load_trace,
                             maybe_span, percentiles_from_hist,
                             validate_chrome_trace)

TOPO = loc.Topology(12, 4)
CFG = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), max_arrivals=16,
                    horizon=500, warmup=100)
EST = sim.make_estimates(CFG, "network", 0.0, -1)


# -- in-scan recorder --------------------------------------------------------

@pytest.mark.parametrize("policy", available_policies())
def test_telemetry_is_pure_observation(policy):
    """Enabling telemetry must not perturb the sample path: the recorder
    consumes no RNG keys and mutates no policy state, so every metric of
    the plain run is bitwise identical with the recorder compiled in —
    and with it compiled out nothing telemetry-shaped appears at all."""
    if getattr(get_policy_cls(policy), "uses_signals", False):
        pytest.skip(f"{policy} opts into reading telemetry signals — the "
                    f"documented purity exception (tests/test_control.py)")
    off = sim.simulate(policy, CFG, 3.0, EST, seed=0)
    on = sim.simulate(policy, CFG, 3.0, EST, seed=0, telemetry=True)
    for k, v in off.items():
        assert np.array_equal(np.asarray(v), np.asarray(on[k])), (policy, k)
    for k in TELEMETRY_METRIC_KEYS:
        assert k in on and k not in off, (policy, k)


def test_percentiles_match_exact_fcfs_quantiles():
    """Width-1 bins + integer sojourns: the histogram quantile must sit
    within one bin width above the exact order statistic of the same
    FIFO-coupled sojourn multiset (reconstructed from the dense series)."""
    cfg = sim.SimConfig(topo=TOPO, true_rates=loc.Rates(), max_arrivals=16,
                        horizon=600, warmup=0)
    tcfg = TelemetryConfig(stride=1)
    res = sim.simulate("balanced_pandas", cfg, 3.2, EST, seed=1,
                       telemetry=tcfg)
    admitted = res["series"][:, 1]
    completions = res["series"][:, 2]
    soj = fcfs_sojourns(admitted, completions)
    assert len(soj) == int(res["delay_hist"].sum())
    s = np.sort(soj)
    for q, key in ((0.50, "delay_p50"), (0.95, "delay_p95"),
                   (0.99, "delay_p99")):
        # exact order statistic: smallest x with F(x) >= q
        exact = s[int(np.ceil(q * len(s))) - 1]
        est = res[key]
        assert 0.0 < est - exact <= tcfg.bin_width + 1e-6, (key, est, exact)
    # the numpy mirror agrees with the in-graph quantile
    ps = percentiles_from_hist(res["delay_hist"], tcfg.bin_width,
                               (0.5, 0.95, 0.99))
    np.testing.assert_allclose(
        ps, [res["delay_p50"], res["delay_p95"], res["delay_p99"]])


def test_downsampled_series_matches_dense():
    """stride=s point-samples the dense track: row i == dense row s*i."""
    dense = sim.simulate("balanced_pandas", CFG, 3.0, EST, seed=0,
                         telemetry=TelemetryConfig(stride=1))
    coarse = sim.simulate("balanced_pandas", CFG, 3.0, EST, seed=0,
                          telemetry=TelemetryConfig(stride=4))
    n = coarse["series"].shape[0]
    np.testing.assert_array_equal(coarse["series"],
                                  dense["series"][: 4 * n: 4])


def test_accounting_invariants_no_drops():
    """With an ample ring the pairing is lossless: every in-window
    completion is binned, nothing is dropped or unmatched, and the
    queue-length histogram covers exactly the measurement window."""
    res = sim.simulate("balanced_pandas", CFG, 3.0, EST, seed=0,
                       telemetry=TelemetryConfig(stride=1))
    window_completions = res["series"][CFG.warmup:, 2].sum()
    assert res["telemetry_dropped"] == 0.0
    assert res["telemetry_unmatched"] == 0.0
    assert res["delay_hist"].sum() == window_completions
    assert res["queue_len_hist"].sum() == CFG.horizon - CFG.warmup


def test_tiny_ring_drops_are_counted():
    """A deliberately tiny ring loses pairings but never miscounts:
    drops are reported and binned + unmatched still equals the window
    completion count (no silent truncation)."""
    tcfg = TelemetryConfig(stride=1, ring_capacity=16)
    res = sim.simulate("balanced_pandas", CFG, 5.0, EST, seed=0,
                       telemetry=tcfg)
    assert res["telemetry_dropped"] > 0.0
    window_completions = res["series"][CFG.warmup:, 2].sum()
    assert res["delay_hist"].sum() + res["telemetry_unmatched"] \
        == window_completions


def test_sweep_telemetry_shapes():
    """Telemetry metrics batch through the vmapped sweep like the core
    scalars: (L, E, S) scalars, (L, E, S, bins+1) histograms,
    (L, E, S, T_s, n_tracks) series."""
    tcfg = TelemetryConfig(stride=16, hist_bins=64, hist_max=64.0,
                           qhist_bins=32)
    res = sim.sweep("balanced_pandas", CFG, np.asarray([2.0, 3.0]),
                    EST[None], np.asarray([0, 1, 2]), telemetry=tcfg)
    assert res["delay_p99"].shape == (2, 1, 3)
    assert res["delay_hist"].shape == (2, 1, 3, 65)
    assert res["queue_len_hist"].shape == (2, 1, 3, 33)
    n_rows = -(-CFG.horizon // 16)
    assert res["series"].shape[:4] == (2, 1, 3, n_rows)


def test_metric_key_collision_raises():
    """A policy whose extra_metrics shadows a core metric key must fail
    loudly at trace time, not silently overwrite."""

    class ShadowingPolicy(BalancedPandasPolicy):
        def extra_metrics(self, s):
            return {"mean_delay": 0.0}

    with pytest.raises(ValueError, match="mean_delay"):
        sim.simulate(ShadowingPolicy(), CFG, 3.0, EST, seed=0)


def test_recorder_construction_guards():
    with pytest.raises(ValueError, match="ring_capacity"):
        SimTelemetry(TelemetryConfig(ring_capacity=4), 100, 0, 12, 16)
    with pytest.raises(ValueError, match="collide"):
        SimTelemetry(TelemetryConfig(), 100, 0, 4, 4,
                     extra_tracks=("admitted",))
    with pytest.raises(ValueError, match="duplicate"):
        SimTelemetry(TelemetryConfig(), 100, 0, 4, 4,
                     extra_tracks=("x", "x"))
    with pytest.raises(ValueError):
        TelemetryConfig(stride=0)
    with pytest.raises(TypeError):
        as_telemetry_config("yes")


# -- host-side event tracing -------------------------------------------------

def test_chrome_trace_roundtrip(tmp_path):
    tr = EventRecorder(capacity=64, pid=7)
    tr.metadata("process_name", name="test")
    tr.instant("hello", cat="t", ts_us=1000.0, tid=2, detail="x")
    tr.counter("depth", 3.0, ts_us=2000.0)
    tr.complete("work", ts_us=1000.0, dur_us=500.0, tid=1)
    with tr.span("wall", cat="host"):
        pass
    with maybe_span(None, "noop"):
        pass  # tracing off: must be a no-op context
    path = tr.save(tmp_path / "trace.json")
    doc = load_trace(path)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped"] == 0
    names = [e["name"] for e in doc["traceEvents"]]
    assert names == ["process_name", "hello", "depth", "work", "wall"]
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["hello"]["ph"] == "i"
    assert by_name["hello"]["args"] == {"detail": "x"}
    assert by_name["depth"]["args"] == {"value": 3.0}
    assert by_name["work"]["ph"] == "X" and by_name["work"]["dur"] == 500.0
    assert all(e["pid"] == 7 for e in doc["traceEvents"])


def test_ring_eviction_is_counted():
    tr = EventRecorder(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    assert tr.to_chrome()["otherData"]["emitted"] == 10


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace([])  # not an object
    with pytest.raises(ValueError, match="ts"):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "i", "pid": 0, "tid": 0}]})
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "Z", "ts": 0.0, "pid": 0, "tid": 0}]})
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0.0, "pid": 0, "tid": 0}]})
    validate_chrome_trace({"traceEvents": []})  # minimal valid doc


def test_pipeline_emits_trace_events(tmp_path):
    """Chunk reads, failure windows, and repair lifecycle all land in the
    trace on the virtual clock, and the export is Perfetto-valid."""
    from repro.data.pipeline import DataPipeline, PipelineConfig

    tr = EventRecorder()
    cfg = PipelineConfig(num_hosts=8, hosts_per_pod=4, num_chunks=32,
                         tokens_per_chunk=512, seq_len=64, global_batch=4,
                         scenario="server_loss", scenario_horizon=32.0,
                         replication_policy="repair", tracer=tr)
    pipe = DataPipeline(cfg)
    for _ in range(80):
        next(pipe)
    doc = json.loads(json.dumps(tr.to_chrome()))  # JSON-serializable
    validate_chrome_trace(doc)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"chunk_read", "server_down", "server_up",
            "repair_start", "repair_commit"} <= names
    reads = [e for e in doc["traceEvents"] if e["name"] == "chunk_read"]
    assert all(e["ph"] == "X" and e["dur"] > 0 for e in reads)
    # virtual clock convention: ts(µs) = 1000 x virtual clock
    assert max(e["ts"] for e in reads) <= pipe._clock * 1000.0


def test_untraced_pipeline_is_unchanged():
    """Tracing must leave the read path byte-identical (pure
    observation): same batches, same metrics, same virtual clock."""
    import dataclasses

    from repro.data.pipeline import DataPipeline, PipelineConfig

    base = PipelineConfig(num_hosts=8, hosts_per_pod=4, num_chunks=16,
                          tokens_per_chunk=512, seq_len=64, global_batch=2)
    a = DataPipeline(base)
    b = DataPipeline(dataclasses.replace(base, tracer=EventRecorder()))
    for _ in range(4):
        xa, xb = next(a), next(b)
        np.testing.assert_array_equal(xa["tokens"], xb["tokens"])
    assert a.metrics["reads"] == b.metrics["reads"]
    assert a._clock == b._clock
